"""Step builders: (arch x shape x mesh) -> jitted-and-shardable step fns.

Every (architecture x input shape) cell resolves to one of:
  * train_step(state, batch)           (train_4k)
  * prefill_step(params, inputs)       (prefill_32k)
  * serve_step(params, cache, tokens)  (decode_32k / long_500k)

with in_shardings derived from the logical-axis rule tables. Multi-pod mode
runs DP over the pod axis for train (gradient all-reduce across DCN) and,
for serving, either DP replication over pods or the paper-faithful
pipeline-parallel split (launch.pipeline) selected by ``serve_pp``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models import build_model, input_specs
from repro.sharding import rules as R
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (TrainState, choose_microbatches,
                                    init_train_state, make_train_step,
                                    train_state_specs)


def build_rules(cfg: ArchConfig, mesh: Mesh, train: bool,
                step: str = "", shape: Optional[ShapeSpec] = None) -> Dict:
    multipod = "pod" in mesh.shape
    if train:
        rules = dict(R.TRAIN_RULES_MULTIPOD if multipod else R.TRAIN_RULES)
    else:
        rules = dict(R.INFER_RULES_MULTIPOD if multipod else R.INFER_RULES)
    model_size = mesh.shape["model"]
    # Sequence-parallel KV cache: (a) mandatory fallback when KV heads do
    # not divide the model axis; (b) always for prefill — the cache is
    # write-only there, so sequence sharding halves peak memory without
    # introducing softmax-side collectives (the 32k-prefill cells of the
    # 70B/104B models exceeded the 16GB v5e HBM otherwise).
    if not train and cfg.n_kv_heads and (
            cfg.n_kv_heads % model_size != 0 or step == "prefill_step"):
        rules["cache_seq"] = ("model",)
    # Sequence-sharded activations for big prefills: when the per-chip
    # residual stream exceeds ~1GB, shard the seq axis over model too —
    # drops the 70B/104B 32k-prefill peak from ~30GB to ~14GB (fits v5e)
    # AND cuts the TP collective term (§Perf).
    if step == "prefill_step" and shape is not None:
        data_size = mesh.shape.get("data", 1)
        act_gb = (shape.global_batch * shape.seq_len * cfg.d_model * 2
                  / max(1, data_size) / 1e9)
        if act_gb > 1.0 and shape.seq_len % model_size == 0:
            rules["seq"] = ("model",)
    return rules


@dataclasses.dataclass
class BuiltStep:
    fn: Any                      # python callable (positional args)
    args_sds: Tuple[Any, ...]    # ShapeDtypeStructs per positional arg
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    trip_hints: Tuple[int, ...]  # while-loop nesting trip counts (hlo_utils)
    meta: Dict[str, Any]
    out_shardings: Any = None    # None => let GSPMD choose


def _shardings_for(tree_specs, tree_sds, mesh, rules):
    def one(names, sds):
        return NamedSharding(mesh, R.resolve(names, sds.shape, rules, mesh))
    return jax.tree.map(
        one, tree_specs, tree_sds,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _batch_specs(batch_sds, cfg: ArchConfig) -> Dict:
    """Logical names for input batches (leading batch dim; m-rope positions
    carry (3,B,S))."""
    def one(path_key, sds):
        nd = len(sds.shape)
        if nd >= 2 and sds.shape[0] == 3 and path_key == "positions":
            return (None, "batch") + (None,) * (nd - 2)
        return ("batch",) + (None,) * (nd - 1)
    return {k: one(k, v) for k, v in batch_sds.items()}


def n_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def build_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               serve_pp: bool = False, attn_chunk: int = 512,
               n_microbatches: Optional[int] = None,
               extra_rules: Optional[Dict] = None,
               gather_weights_once: bool = False,
               kv_cache_dtype: Optional[str] = None,
               weight_dtype: Optional[str] = None,
               remat_policy: Optional[str] = None) -> BuiltStep:
    train = shape.step == "train_step"
    rules = build_rules(cfg, mesh, train, step=shape.step, shape=shape)
    if extra_rules:
        rules.update(extra_rules)
    sharder = R.Sharder(mesh=mesh, rules=rules)
    model_kw = {}
    if remat_policy and not cfg.is_encdec:
        model_kw["remat_policy"] = remat_policy
    model = build_model(cfg, sharder=sharder, attn_chunk=attn_chunk,
                        remat=train, **model_kw)
    pspecs = model.param_specs()
    pshapes = model.param_shapes()
    specs = input_specs(cfg, shape)

    # while-loop nesting trip counts for hlo_utils.collective_bytes: the
    # layer scan (hybrid: group scan x inner period scan) sits below the
    # optional microbatch-accumulation scan.
    if cfg.family == "hybrid" and cfg.hybrid_period:
        layer_hints: Tuple[int, ...] = (cfg.n_layers // cfg.hybrid_period,
                                        cfg.hybrid_period)
    else:
        layer_hints = (cfg.n_layers,)

    if shape.step == "train_step":
        nm = n_microbatches or choose_microbatches(
            shape.global_batch, shape.seq_len, cfg.padded_vocab,
            n_chips(mesh))
        loss_model = model
        if gather_weights_once:
            # Perf lever (§Perf): re-constrain FSDP-sharded weights to their
            # TP-only (gathered-over-data) layout ONCE per step, outside the
            # microbatch scan — the scan then closes over loop-invariant
            # gathered weights instead of re-all-gathering them per
            # microbatch (fwd + remat'd bwd). Grads reduce-scatter back
            # through the constraint's transpose.
            gathered_rules = dict(rules, embed=None)

            class _GatherOnce:
                loss = None
                def __getattr__(self, name):
                    return getattr(model, name)

            def loss_gathered(params, batch):
                def g(leaf, names):
                    sh = NamedSharding(mesh, R.resolve(
                        names, leaf.shape, gathered_rules, mesh))
                    return jax.lax.with_sharding_constraint(leaf, sh)
                params2 = jax.tree.map(
                    g, params, pspecs,
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x))
                return model.loss(params2, batch)

            loss_model = _GatherOnce()
            loss_model.loss = loss_gathered
        step = make_train_step(loss_model, AdamWConfig(), n_microbatches=nm)
        state_sds = jax.eval_shape(
            lambda p: init_train_state(p), pshapes)
        state_specs = train_state_specs(pspecs)
        state_sh = _shardings_for(state_specs, state_sds, mesh, rules)
        batch_sh = _shardings_for(_batch_specs(specs, cfg), specs, mesh,
                                  rules)
        return BuiltStep(
            fn=step,
            args_sds=(state_sds, specs),
            in_shardings=(state_sh, batch_sh),
            donate_argnums=(0,),
            trip_hints=((nm,) + layer_hints if nm > 1 else layer_hints),
            meta={"n_microbatches": nm, "rules": rules})

    if shape.step == "prefill_step":
        def prefill_step(params, inputs):
            logits, cache = model.prefill(params, inputs,
                                          max_len=shape.seq_len)
            return model.sample_greedy(logits), cache
        param_sh = _shardings_for(pspecs, pshapes, mesh, rules)
        in_sh = _shardings_for(_batch_specs(specs, cfg), specs, mesh, rules)
        # pin the output cache sharding: the cache is created inside the
        # jit, so without out_shardings GSPMD may drop the cache_seq split
        # and materialize a 16x bigger output (21.3GB -> fits once pinned)
        cache_out_sds = jax.eval_shape(
            prefill_step, pshapes, specs)[1]
        cache_out_sh = _shardings_for(model.cache_specs(), cache_out_sds,
                                      mesh, rules)
        tok_out_sh = NamedSharding(mesh, R.resolve(
            ("batch",), (shape.global_batch,), rules, mesh))
        return BuiltStep(
            fn=prefill_step,
            args_sds=(pshapes, specs),
            in_shardings=(param_sh, in_sh),
            donate_argnums=(),
            trip_hints=layer_hints,
            meta={"rules": rules},
            out_shardings=(tok_out_sh, cache_out_sh))

    # serve_step
    if serve_pp and "pod" in mesh.shape:
        from repro.launch.pipeline import build_pp_serve_step
        return build_pp_serve_step(cfg, shape, mesh, rules,
                                   kv_cache_dtype=kv_cache_dtype)

    qw_dt = None
    if weight_dtype:
        qw_dt = {"float8_e4m3fn": jnp.float8_e4m3fn,
                 "float8_e5m2": jnp.float8_e5m2}[weight_dtype]

    def serve_step(params, cache, tokens):
        if qw_dt is not None:
            # f8-stored weights: upcast fuses into consumers (served models
            # read half the weight bytes per token — §Perf lever)
            params = jax.tree.map(
                lambda p: p.astype(model.dtype)
                if p.dtype == qw_dt else p, params)
        logits, cache = model.decode_step(params, cache, tokens)
        nxt = model.sample_greedy(logits)
        return nxt.astype(jnp.int32), cache

    cache_sds = specs["cache"]
    if qw_dt is not None:
        pshapes = jax.tree.map(
            lambda sds: jax.ShapeDtypeStruct(sds.shape, qw_dt)
            if sds.dtype == model.dtype and len(sds.shape) >= 2 else sds,
            pshapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if kv_cache_dtype:
        # Perf lever (§Perf): quantized KV cache — halves the decode-phase
        # HBM scan (the dominant roofline term for serve_step). Stored f8,
        # upcast on read inside attention (bf16 math unchanged).
        qdt = {"float8_e4m3fn": jnp.float8_e4m3fn,
               "float8_e5m2": jnp.float8_e5m2}[kv_cache_dtype]
        def maybe_q(sds):
            if sds.dtype == model.dtype and len(sds.shape) >= 5:
                return jax.ShapeDtypeStruct(sds.shape, qdt)
            return sds
        cache_sds = jax.tree.map(maybe_q, cache_sds,
                                 is_leaf=lambda x: isinstance(
                                     x, jax.ShapeDtypeStruct))
    cache_specs = model.cache_specs()
    param_sh = _shardings_for(pspecs, pshapes, mesh, rules)
    cache_sh = _shardings_for(cache_specs, cache_sds, mesh, rules)
    tok_sh = NamedSharding(mesh, R.resolve(
        ("batch", None), specs["tokens"].shape, rules, mesh))
    return BuiltStep(
        fn=serve_step,
        args_sds=(pshapes, cache_sds, specs["tokens"]),
        in_shardings=(param_sh, cache_sh, tok_sh),
        donate_argnums=(1,),
        trip_hints=layer_hints,
        meta={"rules": rules})


def lower_step(built: BuiltStep, mesh: Mesh):
    """jit + lower (no device allocation: args are ShapeDtypeStructs)."""
    kw = {}
    if built.out_shardings is not None:
        kw["out_shardings"] = built.out_shardings
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                     donate_argnums=built.donate_argnums, **kw)
    with mesh:
        return jitted.lower(*built.args_sds)
