import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis + collective bytes.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape decode_32k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json

Per cell we record:
  * compiled memory_analysis (bytes per device: args/outputs/temps/peak)
  * compiled cost_analysis  (HLO FLOPs / bytes accessed)
  * collective bytes parsed from HLO (trip-count weighted — hlo_utils)
  * wall times (lower / compile)
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import REGISTRY, get_config
from repro.configs.shapes import ALL_SHAPES, shapes_for
from repro.launch import hlo_costs, hlo_utils
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, lower_step


def _mem_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": repr(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_argument_size_in_bytes",
              "peak_memory_in_bytes"):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    return out or {"repr": repr(ma)}


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    return hlo_costs.normalize_cost_analysis(ca)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             serve_pp: bool = False, keep_hlo: bool = False,
             extra_rules: Optional[Dict] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "step": shape.step,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "serve_pp": bool(serve_pp and multi_pod),
    }
    t0 = time.perf_counter()
    built = build_step(cfg, shape, mesh, serve_pp=serve_pp,
                       extra_rules=extra_rules)
    lowered = lower_step(built, mesh)
    rec["lower_s"] = round(time.perf_counter() - t0, 2)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t1, 2)
    rec["memory_analysis"] = _mem_analysis_dict(compiled)
    rec["cost_analysis"] = _cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = hlo_utils.collective_bytes(
        hlo, trip_hints=built.trip_hints)
    # trip-weighted per-device flops/bytes (cost_analysis counts while
    # bodies once — see launch/hlo_costs.py)
    rec["tw_costs"] = hlo_costs.trip_weighted_costs(
        hlo, trip_hints=built.trip_hints)
    rec["trip_hints"] = list(built.trip_hints)
    rec["meta"] = {k: v for k, v in built.meta.items() if k != "rules"}
    if keep_hlo:
        rec["hlo_len"] = len(hlo)
    print(f"[dryrun] {arch} x {shape_name} on {rec['mesh']}"
          f"{' (PP)' if rec['serve_pp'] else ''}: "
          f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
    ma = rec["memory_analysis"]
    print("  memory_analysis:", json.dumps(ma))
    ca = rec["cost_analysis"]
    print(f"  cost_analysis: flops={ca.get('flops', float('nan')):.3e} "
          f"bytes={ca.get('bytes accessed', float('nan')):.3e}")
    print(f"  trip-weighted: flops={rec['tw_costs']['flops']:.3e} "
          f"bytes={rec['tw_costs']['bytes']:.3e}")
    print(f"  collective bytes (trip-weighted): "
          f"{rec['collectives'].get('total', 0):.3e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x applicable shape) cell")
    ap.add_argument("--serve-pp", action="store_true",
                    help="multi-pod serving uses pipeline parallelism over "
                         "the pod axis (paper-faithful) when supported")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    cells = []
    if args.all:
        for name, cfg in REGISTRY.items():
            for sh in shapes_for(cfg):
                cells.append((name, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records, failures = [], []
    for arch, shape_name in cells:
        for multi in meshes:
            pp = args.serve_pp and multi
            if pp:
                from repro.launch.pipeline import pp_supported
                cfg = get_config(arch)
                shape = {s.name: s for s in ALL_SHAPES}[shape_name]
                pp = pp_supported(cfg) and shape.step == "serve_step"
            try:
                records.append(run_cell(arch, shape_name, multi,
                                        serve_pp=pp))
            except Exception as e:
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape_name,
                                 "mesh": "multi" if multi else "single",
                                 "error": repr(e)})
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"records": records, "failures": failures}, f,
                          indent=1)
    print(f"\n[dryrun] {len(records)} cells OK, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
