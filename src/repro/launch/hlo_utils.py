"""HLO text analysis: collective traffic for the roofline collective term.

``compiled.cost_analysis()`` has FLOPs and bytes but NOT collective traffic,
so we parse the post-SPMD-partitioner HLO and account every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Two subtleties handled here:

1. Compiled CPU HLO references operands by name (no inline operand types),
   so sizes come from the op's *output* shape — which after partitioning is
   the PER-DEVICE shape — converted to per-chip ring wire bytes:
       all-reduce:        2 * N * (P-1)/P      (N = per-device bytes)
       all-gather:            N * (P-1)/P      (N = gathered output bytes)
       reduce-scatter:    N_out * (P-1)        (operand = out * P)
       all-to-all:            N * (P-1)/P
       collective-permute:    N                (one hop)
   P is parsed from replica_groups (iota ``[G,P]<=...`` or explicit).

2. Scan-over-layers lowers to ``while`` ops whose bodies appear once in the
   text but execute trip-count times. We walk computations from ENTRY with
   multiplicities; ``trip_hints`` supplies static trip counts by while-loop
   nesting depth (e.g. ``[n_microbatches, n_layers]`` for an accumulating
   train step).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
# computation headers look like "%region_1.2_spmd (param: (s32[], ...)) ->
# (...) {" — params may nest parens, so match loosely and require the line to
# open a block and not be an instruction (" = ").
_COMP_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")
_REF_SINGLE_RE = re.compile(
    r"\b(body|condition|to_apply|calls)=%([\w.\-]+)")
_REF_LIST_RE = re.compile(
    r"\b(branch_computations|called_computations|calls)=\{([^}]*)\}")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _participants(line: str, default: int = 2) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def _line_collective(line: str) -> Optional[Tuple[str, float, float]]:
    """Returns (kind, wire_bytes_per_chip, raw_output_bytes) or None."""
    m = _OP_RE.search(line)
    if not m or m.group(3) == "-done":
        return None
    kind = m.group(2)
    out_seg = m.group(1)
    out_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(out_seg))
    if kind == "collective-permute":
        return kind, float(out_bytes), float(out_bytes)
    p = _participants(line)
    if p <= 1:
        return kind, 0.0, float(out_bytes)
    if kind == "all-reduce":
        wire = 2.0 * out_bytes * (p - 1) / p
    elif kind == "all-gather":
        wire = out_bytes * (p - 1) / p
    elif kind == "reduce-scatter":
        wire = float(out_bytes) * (p - 1)
    else:  # all-to-all
        wire = out_bytes * (p - 1) / p
    return kind, wire, float(out_bytes)


class _Comp:
    def __init__(self, name):
        self.name = name
        self.coll: Dict[str, float] = defaultdict(float)
        self.raw: Dict[str, float] = defaultdict(float)
        self.counts: Dict[str, int] = defaultdict(int)
        self.while_bodies: List[str] = []
        self.plain_refs: List[str] = []


def _parse(hlo_text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    # headerless fragments (tests, partial dumps) land in an implicit
    # top-level computation; it is only counted when no ENTRY exists.
    cur: Optional[_Comp] = comps.setdefault("<toplevel>",
                                            _Comp("<toplevel>"))
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if " = " not in line.split("(", 1)[0]:
            header = _COMP_HEADER_RE.match(line)
            if header:
                name = header.group(2)
                cur = comps.setdefault(name, _Comp(name))
                if header.group(1):
                    entry = name
                continue
        if cur is None:
            continue
        got = _line_collective(line)
        if got:
            kind, wire, raw = got
            cur.coll[kind] += wire
            cur.raw[kind] += raw
            cur.counts[kind] += 1
        for attr, nm in _REF_SINGLE_RE.findall(line):
            if attr == "body":
                cur.while_bodies.append(nm)
            else:
                cur.plain_refs.append(nm)
        for _attr, names in _REF_LIST_RE.findall(line):
            cur.plain_refs.extend(_NAME_RE.findall(names))
    return comps, entry


def collective_bytes(hlo_text: str,
                     trip_hints: Sequence[int] = ()) -> Dict[str, float]:
    """Trip-count-weighted per-chip collective wire bytes by kind + total."""
    comps, entry = _parse(hlo_text)
    totals: Dict[str, float] = defaultdict(float)
    raws: Dict[str, float] = defaultdict(float)
    counts: Dict[str, float] = defaultdict(float)

    def accumulate(comp: _Comp, mult: float):
        for k, v in comp.coll.items():
            totals[k] += v * mult
            raws[k] += comp.raw[k] * mult
            counts[k] += comp.counts[k] * mult

    if entry is None:
        for c in comps.values():
            accumulate(c, 1.0)
    else:
        stack: List[str] = []

        def walk(name: str, mult: float, depth: int):
            comp = comps.get(name)
            if comp is None or name in stack:
                return
            stack.append(name)
            accumulate(comp, mult)
            for ref in comp.plain_refs:
                walk(ref, mult, depth)
            for body in comp.while_bodies:
                trip = trip_hints[depth] if depth < len(trip_hints) else 1
                walk(body, mult * max(1, trip), depth + 1)
            stack.pop()

        walk(entry, 1.0, 0)
    out = {k: float(v) for k, v in totals.items()}
    out["total"] = float(sum(totals.values()))
    out["raw_output_bytes"] = float(sum(raws.values()))
    out["counts"] = {k: float(v) for k, v in counts.items()}  # type: ignore
    return out
