"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

End-to-end training on the local device set (CPU here, TPU in production):
data pipeline -> jitted microbatched train_step -> checkpointing -> elastic
resume. XLA latency-hiding flags for real TPU runs are listed (not set on
CPU): --xla_tpu_enable_async_collective_fusion
      --xla_tpu_overlap_compute_collective_tc
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-friendly)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=True, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    n_params = model.param_count()
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"batch={args.batch} seq={args.seq} micro={args.microbatches}")

    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=args.lr), n_microbatches=args.microbatches),
        donate_argnums=(0,))
    data = SyntheticLM(cfg, DataConfig(args.batch, args.seq))

    start = 0
    if args.resume and args.ckpt_dir:
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state = ckpt.restore_checkpoint(args.ckpt_dir, last, state)
            start = last
            print(f"[train] resumed from step {last}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tok_s = (step - start + 1) * args.batch * args.seq / dt
            print(f"  step {step:5d} loss {loss:7.4f} "
                  f"({tok_s:9.0f} tok/s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save_checkpoint(args.ckpt_dir, step + 1, state)
    if args.ckpt_dir:
        ckpt.save_checkpoint(args.ckpt_dir, args.steps, state)
    print("[train] done")


if __name__ == "__main__":
    main()
