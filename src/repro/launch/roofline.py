"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, three terms in SECONDS from the compiled
SPMD module (cost_analysis/memory stats are PER-DEVICE — verified
empirically, see EXPERIMENTS.md §Roofline):

    compute    = HLO_flops_per_chip / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_chip / HBM_bw_per_chip
    collective = wire_bytes_per_chip / ICI_link_bw

Hardware: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI
(multi-pod DCN hops use ~25 GB/s/host for the pod axis; we conservatively
use the ICI figure so the collective term is a lower bound on goodness).

Also reports MODEL_FLOPS (analytic useful compute: 6·N_active·tokens for
training, 2·N_active·tokens for inference) and the usefulness ratio
MODEL_FLOPS / (HLO_flops_per_chip * chips), which exposes remat/redundant
compute.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

V5E_PEAK_FLOPS = 197e12
V5E_HBM_BPS = 819e9
V5E_ICI_BPS = 50e9


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    step: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_chip: float
    useful_ratio: float
    bottleneck: str
    peak_mem_bytes: float
    serve_pp: bool = False

    ideal_compute_s: float = 0.0
    ideal_memory_s: float = 0.0

    @property
    def total_s(self) -> float:
        # optimistic overlap model: terms overlap perfectly; the dominant
        # term is the floor
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """ideal_time / achieved_time, where ideal_time is the analytic
        roofline floor of the ALGORITHM on this hardware: max(useful FLOPs /
        peak, mandatory bytes / HBM bw). Decode is legitimately memory-bound
        — an MFU-style fraction would misgrade it; this fraction is 1.0 when
        the compiled program moves only the mandatory bytes and computes only
        the useful FLOPs at peak."""
        if self.total_s <= 0:
            return 0.0
        ideal = max(self.ideal_compute_s, self.ideal_memory_s)
        return min(1.0, ideal / self.total_s)


def n_chips(mesh: str) -> int:
    return {"16x16": 256, "2x16x16": 512}[mesh]


def model_flops_for(arch: str, shape_name: str) -> float:
    from repro.configs import get_config
    from repro.configs.shapes import ALL_SHAPES
    cfg = get_config(arch)
    spec = cfg.to_modelspec()
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    n_active = spec.params_active()
    if shape.step == "train_step":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill_step":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch       # one decode token


def analytic_min_bytes(arch: str, shape_name: str) -> float:
    """Mandatory GLOBAL memory traffic of the algorithm (weights scanned
    once per step + activations + KV/state), from the paper's Table 2 scan
    terms at d_tp=1. Train approximates fwd+bwd as 3x the forward scan."""
    from repro.configs import get_config
    from repro.configs.shapes import ALL_SHAPES
    from repro.core import roofline as rl
    cfg = get_config(arch)
    spec = cfg.to_modelspec()
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    b, s = shape.global_batch, shape.seq_len
    phase = "decode" if shape.step == "serve_step" else "prefill"
    s_in = s if phase == "prefill" else s - 1
    s_out = 1 if phase == "decode" else 0
    total = 0.0
    for l in spec.layers + spec.encoder_layers:
        for op in rl.layer_op_costs(l, phase, b, s_in, max(s_out, 1), 1,
                                    spec.dtype_bytes):
            total += op.scan_bytes
    total += rl.logits_op_cost(spec, phase, b, s_in, max(s_out, 1),
                               1).scan_bytes
    if shape.step == "train_step":
        total *= 3.0                      # fwd + backward weight/act reads
    return total


def analyze_record(rec: Dict[str, Any]) -> Optional[RooflineCell]:
    ca = rec.get("cost_analysis", {})
    if "flops" not in ca:
        return None
    chips = n_chips(rec["mesh"])
    # compute: trip-weighted dot flops from HLO text (cost_analysis counts
    # while bodies once); memory: the larger of XLA's floor and the analytic
    # Table-2 scan traffic (text-level byte estimates over-read fused
    # slices, so the analytic model is the per-iteration source of truth).
    tw = rec.get("tw_costs", {})
    flops = float(tw.get("flops", ca.get("flops", 0.0)))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    analytic_bytes = analytic_min_bytes(rec["arch"], rec["shape"]) / chips
    hbm_bytes = max(xla_bytes, analytic_bytes)
    coll = float(rec.get("collectives", {}).get("total", 0.0))
    compute_s = flops / V5E_PEAK_FLOPS
    memory_s = hbm_bytes / V5E_HBM_BPS
    collective_s = coll / V5E_ICI_BPS
    mf = model_flops_for(rec["arch"], rec["shape"])
    useful = mf / max(1.0, flops * chips)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    peak = float(rec.get("memory_analysis", {}).get(
        "peak_memory_in_bytes", 0.0))
    ideal_c = mf / (chips * V5E_PEAK_FLOPS)
    ideal_m = analytic_bytes / V5E_HBM_BPS
    return RooflineCell(
        rec["arch"], rec["shape"], rec["step"], rec["mesh"], compute_s,
        memory_s, collective_s, mf, flops, useful, bottleneck, peak,
        serve_pp=bool(rec.get("serve_pp")), ideal_compute_s=ideal_c,
        ideal_memory_s=ideal_m)


def analyze_file(path: str) -> List[RooflineCell]:
    with open(path) as f:
        data = json.load(f)
    cells = []
    for rec in data.get("records", []):
        c = analyze_record(rec)
        if c:
            cells.append(c)
    return cells


def whats_next(cell: RooflineCell) -> str:
    """One sentence: what moves the dominant term down (EXPERIMENTS.md)."""
    if cell.bottleneck == "compute":
        if cell.useful_ratio < 0.4:
            return ("compute-bound but mostly NON-useful FLOPs: cut remat "
                    "recompute / dense-replicated work (check scan policy)")
        return ("compute-bound near useful: raise MXU utilization (tile "
                "alignment, bf16 accumulation), or shard the dominant "
                "matmul over more axes")
    if cell.bottleneck == "memory":
        return ("HBM-bound: shrink the resident working set — shard the KV "
                "cache/weights over more axes, fuse elementwise chains, or "
                "quantize the cache")
    return ("collective-bound: change the sharding to cut all-gathers "
            "(FSDP prefetch overlap, sequence-sharded KV instead of "
            "softmax-side reductions, or bigger per-step compute)")
