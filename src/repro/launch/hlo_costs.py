"""Trip-weighted FLOP / HBM-byte accounting from HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified:
a lax.scan of 8 matmuls reports the flops of 1), so for scanned-layer models
it undercounts by the trip count. This module recomputes both terms from
the HLO text with the same reachability walk hlo_utils uses for collectives:

  * FLOPs: every ``dot`` = 2 * prod(output) * prod(lhs contracting dims)
    (operand shapes resolved via a per-computation symbol table built from
    instruction definitions and computation-header parameters), plus 1 flop
    per output element for elementwise arithmetic ops.
  * HBM bytes: operands + outputs of instructions OUTSIDE fusion
    computations (fusion internals live in registers/VMEM; the fusion call
    site's operands/outputs are the HBM traffic).

While bodies are weighted by ``trip_hints`` at their nesting depth; fusion
calls are descended for FLOPs but not for bytes.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.hlo_utils import _COMP_HEADER_RE, _DTYPE_BYTES

_SHAPE_TOK = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*([a-z0-9]+\[[0-9,]*\])")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_REF_SINGLE_RE = re.compile(r"\b(body|condition|to_apply|calls)=%([\w.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "logistic", "rsqrt", "sqrt", "log", "negate",
    "abs", "floor", "cosine", "sine", "select", "compare", "and", "or",
    "convert", "exponential-minus-one",
}

# Movement/aliasing ops: HBM traffic ~= output size only (a dynamic-slice
# reads a slice, not its whole operand; while/tuple carries alias in place).
_MOVEMENT_OPS = {
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter", "slice",
    "get-tuple-element", "tuple", "copy", "copy-start", "copy-done",
    "bitcast", "reshape", "broadcast", "transpose", "iota", "parameter",
    "constant", "while", "conditional", "call", "concatenate", "pad",
    "reverse", "convert", "optimization-barrier",
}


def _shape_elems(seg: str) -> int:
    total = 0
    for _, dims in _SHAPE_TOK.findall(seg):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n
    return max(total, 0)


def _shape_bytes_seg(seg: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(seg):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


class _CompCost:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.while_bodies: List[str] = []
        self.fusion_calls: List[str] = []
        self.other_refs: List[str] = []


def _operand_segment(line: str, start: int) -> str:
    depth = 1
    for i in range(start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return line[start:i]
    return line[start:]


def parse_costs(hlo_text: str) -> Tuple[Dict[str, _CompCost], Optional[str]]:
    comps: Dict[str, _CompCost] = {}
    symbols: Dict[str, Dict[str, str]] = defaultdict(dict)  # comp -> name -> shape seg
    cur: Optional[str] = "<toplevel>"
    comps["<toplevel>"] = _CompCost("<toplevel>")
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if " = " not in line.split("(", 1)[0]:
            header = _COMP_HEADER_RE.match(line)
            if header:
                cur = header.group(2)
                comps.setdefault(cur, _CompCost(cur))
                if header.group(1):
                    entry = cur
                for pname, pshape in _PARAM_RE.findall(line):
                    symbols[cur][pname] = pshape
                continue
        m = _INSTR_RE.match(line)
        if not m or cur is None:
            continue
        name, out_seg, opcode = m.groups()
        symbols[cur][name] = out_seg
        comp = comps[cur]
        operands_seg = _operand_segment(line, m.end())
        attrs_seg = line[m.end() + len(operands_seg):]
        # references
        for attr, ref in _REF_SINGLE_RE.findall(attrs_seg):
            if attr == "body":
                comp.while_bodies.append(ref)
            elif attr == "calls" and opcode == "fusion":
                comp.fusion_calls.append(ref)
            elif attr in ("condition", "to_apply", "calls"):
                comp.other_refs.append(ref)
        # flops
        if opcode == "dot":
            out_elems = _shape_elems(out_seg)
            contract = 1
            cm = _CONTRACT_RE.search(attrs_seg)
            ops = _OPERAND_RE.findall(operands_seg)
            if cm and ops:
                lhs_shape = symbols[cur].get(ops[0], "")
                tok = _SHAPE_TOK.search(lhs_shape)
                if tok:
                    dims = [int(d) for d in tok.group(2).split(",")
                            if d.strip()]
                    for ci in cm.group(1).split(","):
                        if ci.strip() and int(ci) < len(dims):
                            contract *= dims[int(ci)]
            comp.flops += 2.0 * out_elems * contract
        elif opcode in _ELEMENTWISE:
            comp.flops += _shape_elems(out_seg)
        # bytes: operands + output (fusion internals excluded by the walker;
        # movement/aliasing ops count output only)
        b = _shape_bytes_seg(out_seg)
        if opcode not in _MOVEMENT_OPS:
            for op_name in _OPERAND_RE.findall(operands_seg):
                seg = symbols[cur].get(op_name)
                if seg:
                    b += _shape_bytes_seg(seg)
        comp.bytes += b
    return comps, entry


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one flat dict; newer versions return a *list* of
    per-computation dicts (the entry computation first).  Either way the
    result is the entry computation's numeric properties as a plain dict.
    """
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def trip_weighted_costs(hlo_text: str, trip_hints: Sequence[int] = ()
                        ) -> Dict[str, float]:
    """Returns {'flops', 'bytes'}: per-device totals with while bodies
    weighted by trip_hints (by nesting depth)."""
    comps, entry = parse_costs(hlo_text)
    totals = {"flops": 0.0, "bytes": 0.0}
    if entry is None:
        for c in comps.values():
            totals["flops"] += c.flops
            totals["bytes"] += c.bytes
        return totals
    stack: List[str] = []

    def walk(name: str, mult: float, depth: int, in_fusion: bool):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.append(name)
        totals["flops"] += comp.flops * mult
        if not in_fusion:
            totals["bytes"] += comp.bytes * mult
        for ref in comp.other_refs:
            walk(ref, mult, depth, in_fusion)
        for ref in comp.fusion_calls:
            walk(ref, mult, depth, True)
        for body in comp.while_bodies:
            trip = trip_hints[depth] if depth < len(trip_hints) else 1
            walk(body, mult * max(1, trip), depth + 1, in_fusion)
        stack.pop()

    walk(entry, 1.0, 0, False)
    return totals
