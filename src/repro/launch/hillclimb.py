import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (§Perf): hypothesis -> change -> re-lower ->
re-analyze, per cell. Each variant names an optimization lever; the record
stores the three roofline terms before/after so EXPERIMENTS.md can report
confirmed/refuted hypotheses.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen3_train
"""

import argparse
import json
import time
from typing import Any, Dict, List, Optional

import jax

from repro.configs import get_config
from repro.configs.shapes import ALL_SHAPES
from repro.launch import hlo_costs, hlo_utils
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (V5E_HBM_BPS, V5E_ICI_BPS, V5E_PEAK_FLOPS,
                                   analytic_min_bytes, model_flops_for,
                                   n_chips)
from repro.launch.steps import build_step, lower_step


def measure(arch: str, shape_name: str, multi_pod: bool = False,
            **kw) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = n_chips(mesh_name)
    t0 = time.perf_counter()
    built = build_step(cfg, shape, mesh, **kw)
    compiled = lower_step(built, mesh).compile()
    wall = time.perf_counter() - t0
    hlo = compiled.as_text()
    coll = hlo_utils.collective_bytes(hlo, built.trip_hints)
    tw = hlo_costs.trip_weighted_costs(hlo, built.trip_hints)
    ca = hlo_costs.normalize_cost_analysis(compiled.cost_analysis())
    analytic = analytic_min_bytes(arch, shape_name) / chips
    hbm = max(float(ca.get("bytes accessed", 0.0)), analytic)
    mf = model_flops_for(arch, shape_name)
    terms = {
        "compute_s": tw["flops"] / V5E_PEAK_FLOPS,
        "memory_s": hbm / V5E_HBM_BPS,
        "collective_s": coll["total"] / V5E_ICI_BPS,
    }
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    ideal = max(mf / (chips * V5E_PEAK_FLOPS), analytic / V5E_HBM_BPS)
    ma = compiled.memory_analysis()
    return {
        **terms,
        "dominant": dominant,
        "total_s": total,
        "roofline_fraction": min(1.0, ideal / total) if total else 0.0,
        "collectives_by_kind": {k: v for k, v in coll.items()
                                if k not in ("counts",)},
        "peak_mem_gb": getattr(ma, "peak_memory_in_bytes", 0) / 1e9,
        "compile_wall_s": wall,
        "meta": {k: v for k, v in built.meta.items() if k != "rules"},
    }


# ---------------------------------------------------------------------------
# The three hillclimbed cells. Each variant: (name, hypothesis, kwargs).
# ---------------------------------------------------------------------------
CELLS: Dict[str, Dict[str, Any]] = {
    # most collective-bound cell
    "qwen3_train": {
        "arch": "qwen3-32b", "shape": "train_4k", "multi_pod": False,
        "variants": [
            ("baseline", "paper-faithful FSDP(data) x TP(model), remat, "
             "grad-accum microbatches", {}),
            ("gather_once",
             "HYPOTHESIS: the collective term is dominated by per-microbatch "
             "re-all-gathers of FSDP weight shards inside the accumulation "
             "scan (fwd+remat bwd => 2x per microbatch x16). Re-constraining "
             "weights to TP-only layout once per step should cut all-gather "
             "bytes ~16x at the cost of +weights/16 resident memory.",
             {"gather_weights_once": True}),
            ("gather_once_mb8",
             "HYPOTHESIS: with gathers hoisted, the activation all-reduce "
             "(Eq.3 term) dominates; fewer+larger microbatches don't change "
             "AR bytes but halve scan overhead collectives.",
             {"gather_weights_once": True, "n_microbatches": 8}),
            ("gather_once_save_ar",
             "HYPOTHESIS (iter 2): plain remat re-executes the forward TP "
             "all-reduces during the backward pass; a checkpoint policy "
             "that saves post-collective block outputs should cut the "
             "collective term another ~25-30% for ~2x activation memory "
             "(peak was 1.3GB — headroom is ample).",
             {"gather_weights_once": True,
              "remat_policy": "save_block_out"}),
        ],
    },
    # worst roofline fraction cell
    "granite_prefill": {
        "arch": "granite-moe-3b-a800m", "shape": "prefill_32k",
        "multi_pod": False,
        "variants": [
            ("baseline", "MoE dispatch with token-major cumsum + capacity "
             "scatter; experts replicated (40 % 16 != 0)", {}),
            ("seq_shard",
             "HYPOTHESIS: dispatch tensors (T,E one-hot cumsum, T*k gathers) "
             "are sharded only over data; spreading the token axis over "
             "model too (sequence sharding) cuts the per-chip dispatch "
             "traffic ~16x, at the price of attention-side gathers.",
             {"extra_rules": {"seq": ("model",)}}),
            ("expert_cap_shard",
             "HYPOTHESIS: the (E, C, H) expert buffers replicate over the "
             "model axis; sharding the capacity dim over model cuts the "
             "expert-matmul gather traffic without touching attention.",
             {"extra_rules": {"moe_cap": ("model",)}}),
            ("cap_plus_seq",
             "HYPOTHESIS (iter 2): capacity sharding cut expert-side "
             "traffic 24%; sequence sharding cut peak memory 7x but left "
             "collectives flat. Composed, the dispatch tensors shard over "
             "both axes — expect compounding on the collective term.",
             {"extra_rules": {"moe_cap": ("model",),
                              "seq": ("model",)}}),
        ],
    },
    # most representative of the paper's technique (decode serving)
    "qwen3_decode": {
        "arch": "qwen3-32b", "shape": "decode_32k", "multi_pod": False,
        "variants": [
            ("baseline", "TP(model) x DP(data) decode, bf16 KV cache", {}),
            ("kv_f8",
             "HYPOTHESIS: decode is HBM-bound on the KV scan; storing the "
             "cache in f8 (e4m3) halves cache bytes => memory term drops "
             "toward the weight-scan floor.",
             {"kv_cache_dtype": "float8_e4m3fn"}),
            ("kv_f8_w_f8",
             "HYPOTHESIS (iter 2): with the cache halved, the weight scan "
             "is the next memory driver; f8-stored weights (upcast fused "
             "into consumers) halve it too — memory term -> ~0.5x again.",
             {"kv_cache_dtype": "float8_e4m3fn",
              "weight_dtype": "float8_e4m3fn"}),
        ],
    },
    # the paper's own mechanism across pods (multi-pod serving)
    "llama_decode_pp": {
        "arch": "llama-3.1-70b", "shape": "decode_32k", "multi_pod": True,
        "variants": [
            ("dp_over_pods", "replicate the pipeline across pods (the "
             "optimizer's choice for small models)", {}),
            ("pp_over_pods",
             "HYPOTHESIS: the paper's PP-across-instances mechanism halves "
             "per-chip weight residency/scan (layers split across pods) and "
             "replaces DCN all-reduce with one ppermute hop per microbatch.",
             {"serve_pp": True}),
            ("pp_plus_kvf8",
             "HYPOTHESIS: PP + f8 KV cache compound — memory term drops to "
             "~0.5x of PP alone.",
             {"serve_pp": True, "kv_cache_dtype": "float8_e4m3fn"}),
        ],
    },
}


def run_cell(name: str) -> List[Dict[str, Any]]:
    cell = CELLS[name]
    out = []
    for vname, hypothesis, kw in cell["variants"]:
        print(f"[hillclimb] {name}/{vname} ...", flush=True)
        try:
            m = measure(cell["arch"], cell["shape"],
                        multi_pod=cell.get("multi_pod", False), **kw)
            rec = {"cell": name, "variant": vname,
                   "hypothesis": hypothesis, **m}
        except Exception as e:  # record refuted-by-crash variants too
            import traceback
            traceback.print_exc()
            rec = {"cell": name, "variant": vname,
                   "hypothesis": hypothesis, "error": repr(e)}
        out.append(rec)
        if "total_s" in rec:
            print(f"  compute={rec['compute_s']:.4g}s "
                  f"memory={rec['memory_s']:.4g}s "
                  f"collective={rec['collective_s']:.4g}s "
                  f"dominant={rec['dominant']} "
                  f"frac={rec['roofline_fraction']:.4f}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(CELLS) + [None])
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(CELLS)
    records = []
    for c in cells:
        records.extend(run_cell(c))
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    print(f"[hillclimb] wrote {len(records)} records to {args.out}")


if __name__ == "__main__":
    main()
