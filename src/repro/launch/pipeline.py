"""Pipeline-parallel serving across pods (the paper's technique on TPU).

The paper's core data-plane mechanism is PP across heterogeneous instances
with **uneven layer partitioning** chosen by the DP optimizer (§2.3, §4.2).
On TPU the pipeline boundary is the inter-pod DCN: we run a GPipe-style
microbatched decode step as ``jax.shard_map`` manual over the ``pod`` axis
(auto/GSPMD over ``data``/``model``), hidden states hopping stages via
``lax.ppermute``.

Uneven splits: stages may own different layer counts, but shard_map needs
equal per-pod shapes — stage parameter stacks are therefore padded to
``lmax = max(split)`` with inactive layers masked to identity. The split
itself comes from the same estimator the placement optimizer uses
(``pp_layer_split``), so heterogeneous pod profiles yield the paper's
asymmetric partitioning.

Supported families: dense / moe / vlm decode (full-attention KV caches).
SSM/hybrid/SWA/enc-dec fall back to DP-over-pods (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models import build_model, input_specs
from repro.sharding import rules as R


def pp_supported(cfg: ArchConfig) -> bool:
    return (cfg.family in ("dense", "moe", "vlm") and cfg.swa_window is None
            and not cfg.is_encdec)


def _shard_map(f, mesh, axis_names, in_specs, out_specs):
    """``jax.shard_map`` across JAX versions.

    Newer JAX exposes top-level ``jax.shard_map(axis_names=..., check_vma=...)``
    with partial-auto sharding: manual over ``axis_names``, GSPMD-auto over
    the rest.  Older releases only have
    ``jax.experimental.shard_map.shard_map``, whose partial-auto mode
    (``auto=``) trips an XLA SPMD-partitioner crash on replicated operands,
    so there we fall back to *fully manual* collectives: every mesh axis is
    manual and the specs' unmentioned axes are replicated.  Semantics are
    identical; only the intra-stage auto-TP sharding is lost on old JAX.
    The replication check is disabled either way (ppermute over uneven
    pipeline stages is not replication-checkable).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=set(axis_names),
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ---------------------------------------------------------------------------
# layer split (uneven, estimator-driven)
# ---------------------------------------------------------------------------
def pp_layer_split(cfg: ArchConfig, n_stages: int,
                   pod_flops: Optional[Sequence[float]] = None,
                   s_in: int = 32768, s_out: int = 1,
                   batch: int = 128) -> List[int]:
    """Balance per-stage decode latency across (possibly heterogeneous)
    pods. ``pod_flops`` are relative effective FLOP/s per pod (None =>
    homogeneous => near-even split)."""
    from repro.core.roofline import layer_latency
    from repro.hw.profiles import TPU_V5E, effective
    spec = cfg.to_modelspec()
    n = spec.n_layers
    if pod_flops is None:
        pod_flops = [1.0] * n_stages
    devs = [dataclasses.replace(effective(TPU_V5E),
                                flops_bf16=effective(TPU_V5E).flops_bf16 * f,
                                mem_bw=effective(TPU_V5E).mem_bw * f)
            for f in pod_flops]
    lat = [[layer_latency(spec.layers[i], d, "decode", batch, s_in, s_out,
                          16, spec.dtype_bytes) for i in range(n)]
           for d in devs]
    prefix = [[0.0] * (n + 1) for _ in range(n_stages)]
    for s in range(n_stages):
        for i in range(n):
            prefix[s][i + 1] = prefix[s][i] + lat[s][i]
    INF = math.inf
    dp = [[INF] * (n + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (n + 1) for _ in range(n_stages + 1)]
    dp[0][0] = 0.0
    for s in range(1, n_stages + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                seg = prefix[s - 1][i] - prefix[s - 1][j]
                v = max(dp[s - 1][j], seg)
                if v < dp[s][i]:
                    dp[s][i], cut[s][i] = v, j
    split, i = [], n
    for s in range(n_stages, 0, -1):
        j = cut[s][i]
        split.append(i - j)
        i = j
    return list(reversed(split))


# ---------------------------------------------------------------------------
# parameter / cache packing
# ---------------------------------------------------------------------------
def _pack_stacked(leaf_sds, split: Sequence[int]):
    """(L, ...) -> (n_stages, lmax, ...) shape (SDS only)."""
    lmax = max(split)
    return jax.ShapeDtypeStruct((len(split), lmax) + tuple(leaf_sds.shape[1:]),
                                leaf_sds.dtype)


def pack_pp_params(params: Dict, split: Sequence[int]) -> Dict:
    """Concrete packing (tests / real execution): pad each stage to lmax."""
    lmax = max(split)
    offs = np.cumsum([0] + list(split))

    def pack(leaf):
        stages = []
        for s, n in enumerate(split):
            sl = leaf[offs[s]:offs[s] + n]
            pad = [(0, lmax - n)] + [(0, 0)] * (leaf.ndim - 1)
            stages.append(jnp.pad(sl, pad))
        return jnp.stack(stages)

    out = dict(params)
    out["layers"] = jax.tree.map(pack, params["layers"])
    mask = np.zeros((len(split), lmax), np.bool_)
    for s, n in enumerate(split):
        mask[s, :n] = True
    out["pp_mask"] = jnp.asarray(mask)
    return out


def _pp_param_sds(model, split) -> Tuple[Dict, Dict]:
    """(SDS tree, logical-name tree) for PP-packed params."""
    shapes = model.param_shapes()
    specs = model.param_specs()
    shapes = dict(shapes)
    specs = dict(specs)
    shapes["layers"] = jax.tree.map(lambda s: _pack_stacked(s, split),
                                    shapes["layers"])
    specs["layers"] = jax.tree.map(
        lambda names: ("pp_stage",) + tuple(names),
        specs["layers"],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    shapes["pp_mask"] = jax.ShapeDtypeStruct((len(split), max(split)),
                                             jnp.bool_)
    specs["pp_mask"] = ("pp_stage", None)
    return shapes, specs


# ---------------------------------------------------------------------------
# the PP serve step
# ---------------------------------------------------------------------------
def build_pp_serve_step(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                        rules: Dict, n_microbatches: Optional[int] = None,
                        pod_flops: Optional[Sequence[float]] = None,
                        kv_cache_dtype: Optional[str] = None):
    from repro.launch.steps import BuiltStep  # circular-free at call time
    assert pp_supported(cfg), f"PP serve unsupported for {cfg.name}"
    n_stages = mesh.shape["pod"]
    split = pp_layer_split(cfg, n_stages, pod_flops=pod_flops,
                           s_in=shape.seq_len, batch=shape.global_batch)
    lmax = max(split)
    b, s_max = shape.global_batch, shape.seq_len
    m = n_microbatches or (min(2 * n_stages, b) if b >= 2 * n_stages else 1)
    assert b % m == 0, (b, m)
    mb = b // m

    rules = dict(rules)
    rules["pp_stage"] = ("pod",)
    rules["batch"] = ("data",)           # pod is used by PP, not DP
    model = build_model(cfg, sharder=R.Sharder(mesh=None), remat=False)
    pshapes, pspecs = _pp_param_sds(model, split)

    # cache: (n_stages, lmax, M, mb, S, nkv, hd)
    kv_dt = model.dtype
    if kv_cache_dtype:
        kv_dt = {"float8_e4m3fn": jnp.float8_e4m3fn,
                 "float8_e5m2": jnp.float8_e5m2}[kv_cache_dtype]
    kv_sds = jax.ShapeDtypeStruct(
        (n_stages, lmax, m, mb, s_max, cfg.n_kv_heads, cfg.hd), kv_dt)
    cache_sds = {"k": kv_sds, "v": kv_sds,
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    cache_specs = {"k": ("pp_stage", "layers", None, "batch", "cache_seq",
                         "kv_heads", "head_dim"),
                   "v": ("pp_stage", "layers", None, "batch", "cache_seq",
                         "kv_heads", "head_dim"),
                   "pos": ()}
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def _stage_apply(trunk, mask, x, ck_s, cv_s, pos):
        """Run this pod's (padded) layer stack on one microbatch."""
        def layer(h, xs):
            p_l, ck_l, cv_l, active = xs
            h2, ck2, cv2, _ = model._dense_layer_decode(p_l, h, pos, ck_l,
                                                        cv_l, None)
            h = jnp.where(active, h2, h)
            ck2 = jnp.where(active, ck2, ck_l)
            cv2 = jnp.where(active, cv2, cv_l)
            return h, (ck2, cv2)
        h, (ck_n, cv_n) = jax.lax.scan(layer, x, (trunk, ck_s, cv_s, mask))
        return h, ck_n, cv_n

    def _body(params, cache_k, cache_v, tokens_m, pos, stage_id):
        """shard_map body: manual over pod; tokens_m: (M, mb, 1)."""
        trunk = jax.tree.map(lambda a: a[0], params["layers"])   # strip pod
        mask = params["pp_mask"][0]
        ck, cv = cache_k[0], cache_v[0]            # (lmax, M, mb, S, nkv, hd)
        # the stage index arrives as a pod-sharded input rather than
        # lax.axis_index: axis_index lowers to a PartitionId HLO that the
        # SPMD partitioner rejects under partial-auto shard_map on older JAX
        p_idx = stage_id[0]
        last = n_stages - 1
        h_dim = cfg.d_model
        recv = jnp.zeros((mb, 1, h_dim), model.dtype)
        outs = jnp.zeros((m, mb), jnp.int32)

        def tick(t, carry):
            recv, outs, ck, cv = carry
            rel = t - p_idx
            mb_i = jnp.clip(rel, 0, m - 1)
            valid = (rel >= 0) & (rel < m)
            toks = jax.lax.dynamic_index_in_dim(tokens_m, mb_i, axis=0,
                                                keepdims=False)
            x0 = jnp.take(params["embed"]["tok"], toks, axis=0)
            x = jnp.where(p_idx == 0, x0, recv)
            ck_s = jax.lax.dynamic_index_in_dim(ck, mb_i, axis=1,
                                                keepdims=False)
            cv_s = jax.lax.dynamic_index_in_dim(cv, mb_i, axis=1,
                                                keepdims=False)
            h, ck_n, cv_n = _stage_apply(trunk, mask, x, ck_s, cv_s, pos)
            ck_n = jnp.where(valid, ck_n, ck_s)
            cv_n = jnp.where(valid, cv_n, cv_s)
            ck = jax.lax.dynamic_update_index_in_dim(ck, ck_n, mb_i, axis=1)
            cv = jax.lax.dynamic_update_index_in_dim(cv, cv_n, mb_i, axis=1)
            # last stage: norm + logits + greedy token
            hn = model.norm(h, params["final_norm"])
            logits = (hn @ params["embed"]["tok"].T
                      if cfg.tie_embeddings else hn @ params["lm_head"])
            tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1)[:, 0]
            write = jnp.where(valid & (p_idx == last), tok.astype(jnp.int32),
                              jax.lax.dynamic_index_in_dim(outs, mb_i, 0,
                                                           keepdims=False))
            outs = jax.lax.dynamic_update_index_in_dim(outs, write, mb_i,
                                                       axis=0)
            recv = jax.lax.ppermute(
                h, "pod", [(i, i + 1) for i in range(n_stages - 1)])
            return recv, outs, ck, cv

        recv, outs, ck, cv = jax.lax.fori_loop(
            0, m + n_stages - 1, tick, (recv, outs, ck, cv))
        outs = jax.lax.psum(
            jnp.where(p_idx == last, outs, jnp.zeros_like(outs)), "pod")
        return outs, ck[None], cv[None]

    def serve_step(params, cache, tokens):
        pod_sharded = {"layers": params["layers"],
                       "pp_mask": params["pp_mask"]}
        rest = {k: v for k, v in params.items()
                if k not in ("layers", "pp_mask")}
        tokens_m = tokens.reshape(m, mb, 1)

        def body_with_rest(pod_part, rest_part, ck, cv, toks, pos, sid):
            return _body({**pod_part, **rest_part}, ck, cv, toks, pos, sid)

        smapped = _shard_map(
            body_with_rest, mesh, ("pod",),
            in_specs=(jax.tree.map(lambda _: P("pod"), pod_sharded),
                      jax.tree.map(lambda _: P(), rest),
                      P("pod"), P("pod"), P(), P(), P("pod")),
            out_specs=(P(), P("pod"), P("pod")))
        stage_ids = jnp.arange(n_stages, dtype=jnp.int32)
        outs, ck, cv = smapped(pod_sharded, rest, cache["k"], cache["v"],
                               tokens_m, cache["pos"], stage_ids)
        new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + 1}
        return outs.reshape(b, 1), new_cache

    param_sh = R.tree_shardings(pspecs, pshapes, mesh, rules)
    cache_sh = R.tree_shardings(cache_specs, cache_sds, mesh, rules)
    tok_sh = NamedSharding(mesh, P())
    return BuiltStep(
        fn=serve_step,
        args_sds=(pshapes, cache_sds, tok_sds),
        in_shardings=(param_sh, cache_sh, tok_sh),
        donate_argnums=(1,),
        trip_hints=(m + n_stages - 1, lmax),
        meta={"rules": rules, "pp_split": split, "n_microbatches": m})
