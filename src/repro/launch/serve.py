"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Places the model on a (simulated or declared) cluster with the ShuntServe
optimizer, builds real engines per pipeline, serves a batched workload with
continuous batching, and optionally injects a spot interruption to exercise
output-preserving migration + concurrent initialization.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import Objective, populate_cluster
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.models import build_model
from repro.serving import GlobalServer, ServeRequest, TensorStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--interrupt-at", type=int, default=-1,
                    help="scheduling round to interrupt an instance at")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    exec_cfg = cfg.reduced() if args.reduced else cfg
    # control plane: ShuntServe placement for the FULL model on the paper's
    # cluster (what would run in production)
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(cfg.to_modelspec(), paper_cluster(), insts,
                            763, 232, beam_k=1)
    print(f"[serve] placement for {cfg.name}: {len(plan.pipelines)} "
          f"pipelines, est {plan.total_rps:.2f} rps")
    for p in plan.pipelines:
        print("   ", p.describe())

    # data plane: real engines on reduced config (CPU container)
    model = build_model(exec_cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    store = TensorStore()
    srv = GlobalServer(exec_cfg, store, max_batch=4, max_len=96)
    weights = plan.weights() or [1.0]
    for i, w in enumerate(weights[:2] or [1.0]):
        srv.add_pipeline(params, [f"inst-{i}-a", f"inst-{i}-b"], weight=w)
    rng = np.random.RandomState(0)
    reqs = [ServeRequest(
        prompt=rng.randint(0, exec_cfg.vocab, size=rng.randint(3, 8)).tolist(),
        max_new_tokens=args.max_new_tokens) for _ in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    rounds = 0
    while any(p.queue or p.engine.active() for p in srv.pipelines):
        if rounds == args.interrupt_at:
            print(f"[serve] interrupting inst-0-a at round {rounds}")
            srv.interrupt_instance("inst-0-a")
        srv.step()
        srv.clock += 0.01
        rounds += 1
        if rounds > 50_000:
            break
    dt = time.perf_counter() - t0
    done = [r for r in reqs if r.done]
    toks = sum(len(r.generated) for r in done)
    migrated = sum(1 for r in reqs if r.migrations)
    print(f"[serve] {len(done)}/{len(reqs)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s), {migrated} migrated, "
          f"{rounds} rounds")


if __name__ == "__main__":
    main()
