"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Places the model on a (simulated or declared) cluster with the ShuntServe
optimizer, builds real engines per pipeline, serves a batched workload with
continuous batching, and optionally injects a spot interruption to exercise
output-preserving migration + concurrent initialization.

Dispatch weights and the virtual-clock increment per round come from the
§4.1 estimator's stage latencies for each placed pipeline, so the reported
virtual throughput is consistent with the simulator, not a hardcoded
weight=1.0 / 0.01 s round.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import populate_cluster
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.models import build_model
from repro.serving import GlobalServer, ServeRequest, TensorStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--interrupt-at", type=int, default=-1,
                    help="scheduling round to interrupt an instance at")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill size (0 = single-shot admission)")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route decode/flash Pallas kernels (interpret "
                         "mode on CPU)")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    exec_cfg = cfg.reduced() if args.reduced else cfg
    # control plane: ShuntServe placement for the FULL model on the paper's
    # cluster (what would run in production)
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(cfg.to_modelspec(), paper_cluster(), insts,
                            763, 232, beam_k=1)
    print(f"[serve] placement for {cfg.name}: {len(plan.pipelines)} "
          f"pipelines, est {plan.total_rps:.2f} rps")
    for p in plan.pipelines:
        print("   ", p.describe())

    # data plane: real engines on reduced config (CPU container)
    model = build_model(exec_cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    store = TensorStore()
    srv = GlobalServer(exec_cfg, store, max_batch=4, max_len=96,
                       use_pallas=args.use_pallas,
                       prefill_chunk=args.prefill_chunk)
    for i, placement in enumerate(plan.pipelines[:2] or [None]):
        pipe = srv.add_pipeline(params, [f"inst-{i}-a", f"inst-{i}-b"],
                                placement=placement)
        print(f"[serve] p{pipe.pid}: est weight {pipe.weight:.3f} rps, "
              f"round {pipe.round_s*1e3:.2f} ms")
    rng = np.random.RandomState(0)
    reqs = [ServeRequest(
        prompt=rng.randint(0, exec_cfg.vocab,
                           size=rng.randint(3, 8)).tolist(),
        max_new_tokens=args.max_new_tokens) for _ in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    rounds = 0
    while srv.pending():
        if rounds == args.interrupt_at:
            print(f"[serve] interrupting inst-0-a at round {rounds}")
            srv.interrupt_instance("inst-0-a")
        srv.step()
        srv.tick()
        rounds += 1
        if rounds > 50_000:
            break
    dt = time.perf_counter() - t0
    done = [r for r in reqs if r.done]
    toks = sum(len(r.generated) for r in done)
    migrated = sum(1 for r in reqs if r.migrations)
    retraces = sum(p.engine.stats.prefill_retraces for p in srv.pipelines)
    print(f"[serve] {len(done)}/{len(reqs)} requests, {toks} tokens in "
          f"{dt:.1f}s wall ({toks/dt:.1f} tok/s), {migrated} migrated, "
          f"{rounds} rounds")
    print(f"[serve] virtual clock {srv.clock:.2f}s -> "
          f"{toks/max(srv.clock, 1e-9):.1f} tok/s simulated; "
          f"{retraces} prefill traces")


if __name__ == "__main__":
    main()
