"""Operation-level roofline cost model — paper §4.1.1, Table 2.

For every operation the paper tabulates FLOPs and memory-scan cost for the
prefill and decode phases; latency is the roofline max of compute time and
memory time (Eq. 1):

    L_ops = max(FLOPs / FLOPS, MemScanCost * E / MemBW)

We reproduce Table 2 row-for-row for dense GQA transformer layers and extend
it (see DESIGN.md §5) with MoE FFN rows (active-expert FLOPs, routed tokens),
sliding-window attention (scan term capped at the window) and Mamba2 SSD
blocks (attention-free; linear-time scan) so the estimator covers every
assigned architecture.

Decode rows sum over output iterations t = 1..S_out in closed form:
    sum_{t} (S_in + t) = S_out*S_in + S_out*(S_out+1)/2
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import numpy as np

from repro.core.modelspec import LayerSpec, ModelSpec
from repro.hw.profiles import DeviceProfile


@dataclasses.dataclass
class OpCost:
    name: str
    flops: float                # scalar, or ndarray over a batch axis
    scan_bytes: float           # MemScanCost * E  (already in bytes)

    def latency(self, dev: DeviceProfile) -> float:
        lc = self.flops / dev.flops_bf16
        lm = self.scan_bytes / dev.mem_bw
        return np.maximum(lc, lm)


def _decode_ctx_sum(s_in: int, s_out: int, window: Optional[int]) -> float:
    """sum_{t=1..S_out} ctx(t) where ctx = min(S_in + t, window or inf)."""
    if window is None or s_in + 1 <= window:
        if window is None or s_in + s_out <= window:
            return s_out * s_in + s_out * (s_out + 1) / 2.0
        # partially capped
        t_cap = max(0, window - s_in)          # steps before hitting window
        uncapped = t_cap * s_in + t_cap * (t_cap + 1) / 2.0
        capped = (s_out - t_cap) * window
        return uncapped + capped
    return float(s_out) * window


def layer_op_costs(l: LayerSpec, phase: str, batch: int, s_in: int,
                   s_out: int, d_tp: int, e: int = 2) -> List[OpCost]:
    """Paper Table 2 (+ extensions) for one layer, one phase.

    ``phase`` is "prefill" or "decode". Decode costs are totals over the
    whole S_out generation (matching Table 2's summed decode rows).
    """
    assert phase in ("prefill", "decode"), phase
    B, H = batch, l.hidden
    Hkv, Hq = l.kv_hidden, l.q_hidden
    ops: List[OpCost] = []

    if l.kind == "mamba2":
        return _mamba2_op_costs(l, phase, B, s_in, s_out, d_tp, e)

    if phase == "prefill":
        S = s_in
        # --- QKV projection -------------------------------------------------
        ops.append(OpCost(
            "qkv_proj",
            B * (2 * S * H * Hq + 4 * S * H * Hkv) / d_tp,
            (B * S * H + (H * Hq + 2 * H * Hkv) / d_tp) * e))
        # --- Attention (causal SDPA). SWA caps the key range. --------------
        ctx = S if l.window is None else min(S, l.window)
        ops.append(OpCost(
            "attention",
            4.0 * B * S * ctx * Hq / (2 * d_tp),   # causal => ~1/2 the pairs
            (B * S * Hq + 2 * B * min(S, ctx) * Hkv) / d_tp * e))
        # --- Output projection ---------------------------------------------
        ops.append(OpCost(
            "out_proj",
            2.0 * B * S * Hq * H / d_tp,
            (B * S * Hq + Hq * H / d_tp) * e))
        # --- FFN -------------------------------------------------------------
        ops.extend(_ffn_op_costs(l, B * S, d_tp, e, token_batch=B * S))
    else:
        So = s_out
        ops.append(OpCost(
            "qkv_proj",
            B * So * (2 * H * Hq + 4 * H * Hkv) / d_tp,
            So * (B * H + (H * Hq + 2 * H * Hkv) / d_tp) * e))
        ctx_sum = _decode_ctx_sum(s_in, So, l.window)
        ops.append(OpCost(
            "attention",
            4.0 * B * ctx_sum * Hq / d_tp,
            (So * B * Hq + 2 * B * ctx_sum * Hkv) / d_tp * e))
        ops.append(OpCost(
            "out_proj",
            2.0 * B * So * Hq * H / d_tp,
            So * (B * Hq + Hq * H / d_tp) * e))
        ops.extend(_ffn_op_costs(l, B * So, d_tp, e, token_batch=B,
                                 steps=So))
    return ops


def _ffn_op_costs(l: LayerSpec, total_tokens: float, d_tp: int, e: int,
                  token_batch: float, steps: int = 1) -> List[OpCost]:
    """FFN rows. For MoE: compute scales with top_k experts per token, while
    the weight *scan* term covers every expert that receives >=1 token —
    a decode batch of B tokens touches min(n_experts, B*top_k) experts."""
    H, F = l.hidden, l.ffn_dim
    up_mats = 2 if l.gated_ffn else 1
    if l.n_experts == 0:
        flops_up = 2.0 * up_mats * total_tokens * H * F / d_tp
        flops_dn = 2.0 * total_tokens * H * F / d_tp
        scan_up = (token_batch * H + up_mats * H * F / d_tp) * e * steps
        scan_dn = (token_batch * F / d_tp + H * F / d_tp) * e * steps
        return [OpCost("ffn_up_gate", flops_up, scan_up),
                OpCost("ffn_down", flops_dn, scan_dn)]
    # MoE
    k = l.top_k
    active_experts = np.minimum(l.n_experts, token_batch * k)
    flops_up = 2.0 * up_mats * total_tokens * k * H * F / d_tp
    flops_dn = 2.0 * total_tokens * k * H * F / d_tp
    router = 2.0 * total_tokens * H * l.n_experts
    scan_w = (up_mats + 1) * active_experts * H * F / d_tp * e * steps
    scan_act = (token_batch * (H + k * F / d_tp)) * e * steps
    return [OpCost("moe_router", router, token_batch * H * e * steps),
            OpCost("moe_ffn", flops_up + flops_dn, scan_w + scan_act)]


def _mamba2_op_costs(l: LayerSpec, phase: str, B: int, s_in: int,
                     s_out: int, d_tp: int, e: int) -> List[OpCost]:
    """Mamba2 SSD block — linear in sequence length.

    Per token: in_proj (H -> 2*d_inner + 2*N + heads), depthwise conv,
    SSD state update (heads * head_dim * N MACs), out_proj (d_inner -> H).
    """
    H = l.hidden
    d_inner = l.ssm_heads * l.ssm_head_dim
    N = l.ssm_state
    proj_in = H * (2 * d_inner + 2 * N + l.ssm_heads)
    proj_out = d_inner * H
    if phase == "prefill":
        T = B * s_in
        steps, token_batch = 1, B * s_in
    else:
        T = B * s_out
        steps, token_batch = s_out, B
    flops_proj = 2.0 * T * (proj_in + proj_out) / d_tp
    # SSD: dA state decay + B-outer-product update + C readout: ~6 MACs per
    # (head, head_dim, N) element per token.
    flops_ssd = 6.0 * T * l.ssm_heads * l.ssm_head_dim * N / d_tp
    flops_conv = 2.0 * T * l.conv_dim * (d_inner + 2 * N) / d_tp
    scan_w = (proj_in + proj_out) / d_tp * e * steps
    scan_state = token_batch * l.ssm_heads * l.ssm_head_dim * N / d_tp * e * steps
    scan_act = token_batch * (H + d_inner / d_tp) * e * steps
    return [OpCost("ssm_proj", flops_proj, scan_w + scan_act),
            OpCost("ssd_scan", flops_ssd + flops_conv,
                   scan_state + token_batch * d_inner / d_tp * e * steps)]


def logits_op_cost(spec: ModelSpec, phase: str, batch: int, s_in: int,
                   s_out: int, d_tp: int) -> OpCost:
    """Table 2 'Logits Calculation' row."""
    H, V, e = spec.hidden, spec.vocab, spec.dtype_bytes
    if phase == "prefill":
        # serving computes logits for the last position only in practice,
        # but the paper's table uses the full S_in; we follow the paper.
        flops = 2.0 * batch * s_in * H * V / d_tp
        scan = (batch * s_in * H + H * V / d_tp) * e
    else:
        flops = 2.0 * batch * s_out * H * V / d_tp
        scan = s_out * (batch * H + H * V / d_tp) * e
    return OpCost("logits", flops, scan)


@functools.lru_cache(maxsize=1 << 18)
def layer_latency(l: LayerSpec, dev: DeviceProfile, phase: str, batch: int,
                  s_in: int, s_out: int, d_tp: int, e: int = 2) -> float:
    """Memoized: uniform-layer models share one LayerSpec instance, so the
    DP's ~1e5 partial-placement evaluations hit this cache constantly."""
    return sum(op.latency(dev)
               for op in layer_op_costs(l, phase, batch, s_in, s_out, d_tp, e))


def layer_latency_array(l: LayerSpec, dev: DeviceProfile, phase: str,
                        batches: np.ndarray, s_in: int, s_out: int,
                        d_tp: int, e: int = 2) -> np.ndarray:
    """Vectorized :func:`layer_latency` over a batch-size axis.

    The Table 2 formulas are linear (or piecewise-linear, for MoE active
    experts) in the batch, so they broadcast directly over a numpy batch
    vector; one call evaluates the whole Eq. 6 batch grid that the
    placement-search prefix-sum tables (``repro.core.eval_engine``) need.
    """
    out = np.zeros_like(batches, dtype=np.float64)
    for op in layer_op_costs(l, phase, batches, s_in, s_out, d_tp, e):
        out += op.latency(dev)
    return out


def layer_flops(l: LayerSpec, phase: str, batch: int, s_in: int, s_out: int,
                d_tp: int = 1, e: int = 2) -> float:
    return sum(op.flops
               for op in layer_op_costs(l, phase, batch, s_in, s_out, d_tp, e))
