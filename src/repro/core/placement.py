"""Partitioned model placement optimizer — paper §4.2, Algorithm 1.

DP over (layers placed, stages used) with beam search: ``DP[l][s]`` holds the
top-k partial pipelines that place the first ``l`` layers on ``s`` stages.
Each extension assigns the next ``l - l'`` layers to a fresh stage drawn from
the available instance inventory (instance type x TP degree), computes the
max batch (Eq. 6) and estimated throughput (Eq. 4/5) of the *partial*
placement — the op-level estimator makes partial pipelines comparable, which
is what gives the problem (approximate) optimal substructure — and keeps the
beam's best k.

Inventory handling (beyond the paper's pseudocode, required for real
clusters): each candidate tracks devices consumed per instance type so a
stage can only be added while inventory remains; one *instance* may host
multiple stages (intra-node TP slices, cf. HexGen's 4xL4 = 4 stages) but an
instance never spans pipelines (paper §4.2.1 fault-isolation rule).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.estimator import (Placement, Stage, estimate,
                                  max_batch_size)
from repro.core.modelspec import ModelSpec
from repro.core.objective import Objective
from repro.hw.profiles import InstanceProfile


@dataclasses.dataclass(frozen=True)
class StageOption:
    """A way to build one stage: ``tp`` devices of one instance type."""

    instance: InstanceProfile
    tp: int

    @property
    def key(self) -> Tuple[str, int]:
        return (self.instance.name, self.tp)


def stage_options_for(instances: Sequence[InstanceProfile],
                      max_tp: Optional[int] = None) -> List[StageOption]:
    opts = []
    for inst in instances:
        d = 1
        while d <= inst.num_devices:
            if inst.num_devices % d == 0 and (max_tp is None or d <= max_tp):
                opts.append(StageOption(inst, d))
            d *= 2
    return opts


@dataclasses.dataclass(frozen=True)
class _Partial:
    """A partial pipeline in the DP table."""

    stages: Tuple[Stage, ...]
    used_devices: Tuple[Tuple[str, int], ...]   # (instance_name, devices)
    score: float

    def used(self) -> Dict[str, int]:
        return dict(self.used_devices)


@dataclasses.dataclass
class SearchResult:
    placement: Optional[Placement]
    score: float
    batch: int
    throughput_rps: float
    wall_time_s: float
    evaluated: int


class PlacementOptimizer:
    """Paper Algorithm 1."""

    def __init__(self, spec: ModelSpec, inventory: Dict[str, int],
                 instances: Dict[str, InstanceProfile], s_in: int,
                 s_out: int, objective: Optional[Objective] = None,
                 beam_k: int = 3, max_stages: Optional[int] = None,
                 max_tp: Optional[int] = None, batch_cap: int = 512):
        self.spec = spec
        # inventory in *device* units per instance type
        self.inventory = {
            name: count * instances[name].num_devices
            for name, count in inventory.items()}
        self.instances = instances
        self.s_in, self.s_out = s_in, s_out
        self.objective = objective or Objective()
        self.beam_k = beam_k
        self.max_stages = max_stages or min(spec.n_layers, 16)
        self.options = stage_options_for(
            [instances[n] for n in inventory], max_tp=max_tp)
        self.batch_cap = batch_cap
        self.evaluated = 0

    # -- scoring -----------------------------------------------------------
    def _evaluate(self, stages: Tuple[Stage, ...], n_layers_placed: int
                  ) -> Tuple[float, int, float]:
        """Score a (possibly partial) pipeline.

        Partial pipelines are scored on the layers placed so far with the
        last stage temporarily holding the LM head, mirroring the paper's
        'evaluating partial model placements within DP subproblems'.
        """
        spec = self.spec
        if n_layers_placed == spec.n_layers:
            pspec = spec
        else:
            pspec = dataclasses.replace(
                spec, layers=spec.layers[:n_layers_placed])
        stages = tuple(
            dataclasses.replace(s, first=(i == 0),
                                last=(i == len(stages) - 1))
            for i, s in enumerate(stages))
        placement = Placement(pspec, stages)
        perf = estimate(pspec, placement, self.s_in, self.s_out)
        self.evaluated += 1
        score = self.objective.score(placement, perf)
        return score, perf.batch, perf.throughput_rps

    # -- Algorithm 1 ---------------------------------------------------------
    def search(self) -> SearchResult:
        t0 = time.perf_counter()
        n_l = self.spec.n_layers
        # DP[l][s] -> beam (list of _Partial, best first)
        dp: Dict[Tuple[int, int], List[_Partial]] = {(0, 0): [
            _Partial((), (), 0.0)]}
        for l in range(1, n_l + 1):
            for lprime in range(0, l):
                l_new = l - lprime
                for s in range(0, min(lprime + 1, self.max_stages)):
                    beam = dp.get((lprime, s))
                    if not beam:
                        continue
                    s_new = s + 1
                    for cand, opt in itertools.product(beam[:self.beam_k],
                                                       self.options):
                        used = cand.used()
                        if (used.get(opt.instance.name, 0) + opt.tp
                                > self.inventory.get(opt.instance.name, 0)):
                            continue
                        stage = Stage(opt.instance, opt.tp, l_new)
                        stages = cand.stages + (stage,)
                        score, batch, _ = self._evaluate(stages, l)
                        if batch <= 0 and l == n_l:
                            continue
                        used[opt.instance.name] = (
                            used.get(opt.instance.name, 0) + opt.tp)
                        new = _Partial(stages, tuple(sorted(used.items())),
                                       score)
                        self._update(dp, (l, s_new), new)
        return self._extract(dp, t0)

    def _update(self, dp, key, cand: _Partial) -> None:
        beam = dp.setdefault(key, [])
        beam.append(cand)
        beam.sort(key=lambda c: -c.score)
        del beam[self.beam_k:]

    def _extract(self, dp, t0) -> SearchResult:
        n_l = self.spec.n_layers
        best: Optional[_Partial] = None
        for s in range(1, self.max_stages + 1):
            for cand in dp.get((n_l, s), []):
                if best is None or cand.score > best.score:
                    best = cand
        wall = time.perf_counter() - t0
        if best is None:
            return SearchResult(None, 0.0, 0, 0.0, wall, self.evaluated)
        stages = tuple(
            dataclasses.replace(st, first=(i == 0),
                                last=(i == len(best.stages) - 1))
            for i, st in enumerate(best.stages))
        placement = Placement(self.spec, stages)
        perf = estimate(self.spec, placement, self.s_in, self.s_out)
        return SearchResult(placement, best.score, perf.batch,
                            perf.throughput_rps, wall, self.evaluated)


def exhaustive_search(spec: ModelSpec, inventory: Dict[str, int],
                      instances: Dict[str, InstanceProfile], s_in: int,
                      s_out: int, objective: Optional[Objective] = None,
                      max_stages: int = 4) -> SearchResult:
    """Brute-force reference used by tests on tiny problems (the paper's
    'intractable exhaustive search' — only viable for a handful of layers)."""
    objective = objective or Objective()
    opts = stage_options_for([instances[n] for n in inventory])
    inv = {n: c * instances[n].num_devices for n, c in inventory.items()}
    n_l = spec.n_layers
    best, best_score = None, -1.0
    evaluated = 0
    t0 = time.perf_counter()

    def partitions(n, k):
        if k == 1:
            yield (n,)
            return
        for first in range(1, n - k + 2):
            for rest in partitions(n - first, k - 1):
                yield (first,) + rest

    for k in range(1, max_stages + 1):
        for part in partitions(n_l, k):
            for combo in itertools.product(opts, repeat=k):
                used: Dict[str, int] = {}
                ok = True
                for o in combo:
                    used[o.instance.name] = used.get(o.instance.name, 0) + o.tp
                    if used[o.instance.name] > inv.get(o.instance.name, 0):
                        ok = False
                        break
                if not ok:
                    continue
                stages = tuple(
                    Stage(o.instance, o.tp, nl, first=(i == 0),
                          last=(i == k - 1))
                    for i, (o, nl) in enumerate(zip(combo, part)))
                placement = Placement(spec, stages)
                perf = estimate(spec, placement, s_in, s_out)
                evaluated += 1
                sc = objective.score(placement, perf)
                if sc > best_score:
                    best, best_score = placement, sc
    wall = time.perf_counter() - t0
    if best is None:
        return SearchResult(None, 0.0, 0, 0.0, wall, evaluated)
    perf = estimate(spec, best, s_in, s_out)
    return SearchResult(best, best_score, perf.batch, perf.throughput_rps,
                        wall, evaluated)
