"""Partitioned model placement optimizer — paper §4.2, Algorithm 1.

DP over (layers placed, stages used) with beam search: ``DP[l][s]`` holds the
top-k partial pipelines that place the first ``l`` layers on ``s`` stages.
Each extension assigns the next ``l - l'`` layers to a fresh stage drawn from
the available instance inventory (instance type x TP degree), computes the
max batch (Eq. 6) and estimated throughput (Eq. 4/5) of the *partial*
placement — the op-level estimator makes partial pipelines comparable, which
is what gives the problem (approximate) optimal substructure — and keeps the
beam's best k.

Two scoring paths:

  * **fast** (default): the prefix-sum table engine
    (``repro.core.eval_engine``).  A ``_FastPartial`` carries incremental
    state — the running min of per-stage Eq. 6 batch bounds, the running
    max/sum of per-stage prefill/decode latency at the current batch —
    so extending a candidate by one stage composes scalars (O(1) table
    lookups) instead of re-walking every layer of every stage.  When the
    pipeline batch changes (a tighter stage appeared), the per-stage
    terms are rebuilt in O(stages) table lookups.  Beams additionally
    apply dominance pruning: a candidate whose score is no better and
    whose inventory use is no smaller (component-wise) than another's is
    dropped, which both dedups equivalent inventory states and frees
    beam slots for genuinely different candidates.

    ``HistogramCostObjective`` also runs on this path: the same
    incremental composition (``_extend_state``) is replayed once per
    populated traffic bucket against that bucket's own prefix-sum
    tables, and the per-bucket requests/s compose harmonically into the
    histogram $/token score.  Dominance pruning is disabled there — the
    single-point dominance quantities don't bound per-bucket score
    evolution, and the reference beam is score-only top-k.

  * **reference** (``use_fast=False``): the original per-layer
    ``estimator.estimate`` scoring, kept as the pinned source of truth
    (see ``tests/test_fast_engine.py``).

Inventory handling (beyond the paper's pseudocode, required for real
clusters): each candidate tracks devices consumed per instance type so a
stage can only be added while inventory remains; one *instance* may host
multiple stages (intra-node TP slices, cf. HexGen's 4xL4 = 4 stages) but an
instance never spans pipelines (paper §4.2.1 fault-isolation rule).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.buckets import HistogramCostObjective
from repro.core.estimator import (Placement, Stage, estimate,
                                  max_batch_size)
from repro.core.eval_engine import FastEstimator, StageTable
from repro.core.modelspec import ModelSpec
from repro.core.objective import Objective
from repro.hw.profiles import InstanceProfile


@dataclasses.dataclass(frozen=True)
class StageOption:
    """A way to build one stage: ``tp`` devices of one instance type."""

    instance: InstanceProfile
    tp: int

    @property
    def key(self) -> Tuple[str, int]:
        return (self.instance.name, self.tp)


def stage_options_for(instances: Sequence[InstanceProfile],
                      max_tp: Optional[int] = None) -> List[StageOption]:
    opts = []
    for inst in instances:
        d = 1
        while d <= inst.num_devices:
            if inst.num_devices % d == 0 and (max_tp is None or d <= max_tp):
                opts.append(StageOption(inst, d))
            d *= 2
    return opts


@dataclasses.dataclass(frozen=True)
class _Partial:
    """A partial pipeline in the reference-path DP table."""

    stages: Tuple[Stage, ...]
    used_devices: Tuple[Tuple[str, int], ...]   # (instance_name, devices)
    score: float

    def used(self) -> Dict[str, int]:
        return dict(self.used_devices)


class _FastPartial:
    """A partial pipeline carrying incremental fast-path state.

    The latency aggregates (``sum_pre``/``sum_dec``/``max_pre``/
    ``max_dec``) are per-stage *base* values at batch ``batch``: they
    include the stage's layer-segment roofline latency, TP collectives,
    the first-stage extras on stage 0, and the PP hand-off for every
    stage (the d_pp>1 convention of Eq. 2) — but exclude the LM-head
    (logits) extras, which belong to whichever stage is currently last
    and migrate on every extension (each extension folds them onto the
    freshly appended stage's locally computed base).  ``m_nonlast`` is
    the running min over stages of the Eq. 6 bound with *no* stage
    holding the head, from which the true pipeline batch is
    min(m_nonlast, last-stage-as-last bound): the head only ever
    tightens the last stage's own bound.
    """

    __slots__ = ("segs", "used_d", "score", "batch", "m_nonlast",
                 "sum_pre", "sum_dec", "max_pre", "max_dec", "cost",
                 "bstate")

    def __init__(self, segs, used_d, score, batch, m_nonlast, sum_pre,
                 sum_dec, max_pre, max_dec, cost, bstate=None):
        self.segs = segs            # tuple of (StageTable, lo, hi)
        self.used_d = used_d        # {instance_name: devices} — never mutated
        self.score = score
        self.batch = batch
        self.m_nonlast = m_nonlast
        self.sum_pre = sum_pre
        self.sum_dec = sum_dec
        self.max_pre = max_pre
        self.max_dec = max_dec
        self.cost = cost
        # histogram mode only: one (segs_b, batch, m_nonlast, sum_pre,
        # sum_dec, max_pre, max_dec) per populated traffic bucket, composed
        # against that bucket's own tables
        self.bstate = bstate


@dataclasses.dataclass
class SearchResult:
    placement: Optional[Placement]
    score: float
    batch: int
    throughput_rps: float
    wall_time_s: float
    evaluated: int


class PlacementOptimizer:
    """Paper Algorithm 1."""

    def __init__(self, spec: ModelSpec, inventory: Dict[str, int],
                 instances: Dict[str, InstanceProfile], s_in: int,
                 s_out: int, objective: Optional[Objective] = None,
                 beam_k: int = 3, max_stages: Optional[int] = None,
                 max_tp: Optional[int] = None, batch_cap: int = 512,
                 use_fast: bool = True, prune_dominated: bool = True,
                 engine: Optional[FastEstimator] = None):
        self.spec = spec
        # inventory in *device* units per instance type
        self.inventory = {
            name: count * instances[name].num_devices
            for name, count in inventory.items()}
        self.instances = instances
        self.s_in, self.s_out = s_in, s_out
        self.objective = objective or Objective()
        self.beam_k = beam_k
        self.max_stages = max_stages or min(spec.n_layers, 16)
        self.options = stage_options_for(
            [instances[n] for n in inventory], max_tp=max_tp)
        self.batch_cap = batch_cap
        # the fast path inlines the stock Eq. 7 objective and the histogram
        # $/token objective (per-bucket table composition); any other
        # subclassed objective falls back to the reference scorer.
        self.use_fast = use_fast and type(self.objective) in (
            Objective, HistogramCostObjective)
        self.prune_dominated = prune_dominated
        self.engine = engine
        self.evaluated = 0

    # -- scoring (reference path) ------------------------------------------
    def _evaluate(self, stages: Tuple[Stage, ...], n_layers_placed: int
                  ) -> Tuple[float, int, float]:
        """Score a (possibly partial) pipeline with the reference
        estimator.

        Partial pipelines are scored on the layers placed so far with the
        last stage temporarily holding the LM head, mirroring the paper's
        'evaluating partial model placements within DP subproblems'.
        """
        spec = self.spec
        if n_layers_placed == spec.n_layers:
            pspec = spec
        else:
            pspec = dataclasses.replace(
                spec, layers=spec.layers[:n_layers_placed])
        stages = tuple(
            dataclasses.replace(s, first=(i == 0),
                                last=(i == len(stages) - 1))
            for i, s in enumerate(stages))
        placement = Placement(pspec, stages)
        batch = max_batch_size(pspec, placement, self.s_in, self.s_out,
                               cap=self.batch_cap)
        perf = estimate(pspec, placement, self.s_in, self.s_out, batch=batch)
        self.evaluated += 1
        score = self.objective.score(placement, perf)
        return score, perf.batch, perf.throughput_rps

    # -- Algorithm 1 ---------------------------------------------------------
    def search(self) -> SearchResult:
        if self.use_fast:
            return self._search_fast()
        return self._search_reference()

    def _search_reference(self) -> SearchResult:
        t0 = time.perf_counter()
        n_l = self.spec.n_layers
        # DP[l][s] -> beam (list of _Partial, best first)
        dp: Dict[Tuple[int, int], List[_Partial]] = {(0, 0): [
            _Partial((), (), 0.0)]}
        for l in range(1, n_l + 1):
            for lprime in range(0, l):
                l_new = l - lprime
                for s in range(0, min(lprime + 1, self.max_stages)):
                    beam = dp.get((lprime, s))
                    if not beam:
                        continue
                    s_new = s + 1
                    for cand, opt in itertools.product(beam[:self.beam_k],
                                                       self.options):
                        used = cand.used()
                        if (used.get(opt.instance.name, 0) + opt.tp
                                > self.inventory.get(opt.instance.name, 0)):
                            continue
                        stage = Stage(opt.instance, opt.tp, l_new)
                        stages = cand.stages + (stage,)
                        score, batch, _ = self._evaluate(stages, l)
                        if batch <= 0 and l == n_l:
                            continue
                        used[opt.instance.name] = (
                            used.get(opt.instance.name, 0) + opt.tp)
                        new = _Partial(stages, tuple(sorted(used.items())),
                                       score)
                        self._update(dp, (l, s_new), new)
        return self._extract_reference(dp, t0)

    def _update(self, dp, key, cand: _Partial) -> None:
        beam = dp.setdefault(key, [])
        beam.append(cand)
        beam.sort(key=lambda c: -c.score)
        del beam[self.beam_k:]

    def _extract_reference(self, dp, t0) -> SearchResult:
        n_l = self.spec.n_layers
        best: Optional[_Partial] = None
        for s in range(1, self.max_stages + 1):
            for cand in dp.get((n_l, s), []):
                if best is None or cand.score > best.score:
                    best = cand
        wall = time.perf_counter() - t0
        if best is None:
            return SearchResult(None, 0.0, 0, 0.0, wall, self.evaluated)
        return self._finish(best.stages, best.score, wall)

    def _finish(self, stages: Tuple[Stage, ...], score: float,
                wall: float) -> SearchResult:
        stages = tuple(
            dataclasses.replace(st, first=(i == 0),
                                last=(i == len(stages) - 1))
            for i, st in enumerate(stages))
        placement = Placement(self.spec, stages)
        batch = max_batch_size(self.spec, placement, self.s_in, self.s_out,
                               cap=self.batch_cap)
        perf = estimate(self.spec, placement, self.s_in, self.s_out,
                        batch=batch)
        return SearchResult(placement, score, perf.batch,
                            perf.throughput_rps, wall, self.evaluated)

    # -- fast path ---------------------------------------------------------
    def _search_fast(self) -> SearchResult:
        t0 = time.perf_counter()
        if (self.engine is None
                or self.engine.spec is not self.spec
                or (self.engine.s_in, self.engine.s_out)
                != (self.s_in, self.s_out)
                or self.engine.batch_cap != self.batch_cap):
            self.engine = FastEstimator(self.spec, self.s_in, self.s_out,
                                        self.batch_cap)
        obj = self.objective
        spot = obj.spot_pricing
        tables = [self.engine.table(o.instance, o.tp) for o in self.options]
        opt_meta = [(t, o.instance.name, o.tp,
                     t.price_spot if spot else t.price_od)
                    for t, o in zip(tables, self.options)]
        # histogram mode: per populated bucket, that bucket's own tables
        # (one per option) from the SAME BucketEstimator the reference
        # scorer uses, so both paths hit one shared table cache
        hmeta = None
        if type(obj) is HistogramCostObjective:
            best = obj._estimator(self.spec)
            bk = best.buckets
            hmeta = []
            for bi in range(bk.n_in):
                for bo in range(bk.n_out):
                    w = obj.hist[bi][bo]
                    if w <= 0:
                        continue
                    fe = best.estimator(bi, bo)
                    hmeta.append((w, float(bk.rep(bi, bo)[1]), fe.batch_cap,
                                  tuple(fe.table(o.instance, o.tp)
                                        for o in self.options)))
        n_l = self.spec.n_layers
        cap = self.batch_cap
        root_b = (tuple(((), 0, cap_b, 0.0, 0.0, 0.0, 0.0)
                        for _, _, cap_b, _ in hmeta)
                  if hmeta is not None else None)
        root = _FastPartial((), {}, 0.0, 0, cap, 0.0, 0.0, 0.0, 0.0, 0.0,
                            root_b)
        dp: Dict[Tuple[int, int], List[_FastPartial]] = {(0, 0): [root]}
        inventory = self.inventory
        for l in range(1, n_l + 1):
            for lprime in range(0, l):
                for s in range(0, min(lprime + 1, self.max_stages)):
                    beam = dp.get((lprime, s))
                    if not beam:
                        continue
                    first = s == 0
                    key_new = (l, s + 1)
                    for oi, (table, name, tp, price) in enumerate(opt_meta):
                        inv_t = inventory.get(name, 0)
                        if tp > inv_t:
                            continue
                        nb_nl = table.bound(lprime, l, first, False)
                        nb_l = table.bound(lprime, l, first, True)
                        hb = None
                        if hmeta is not None:
                            hb = [(w, out_b, bt[oi],
                                   bt[oi].bound(lprime, l, first, False),
                                   bt[oi].bound(lprime, l, first, True))
                                  for w, out_b, _, bt in hmeta]
                        for cand in beam:
                            if cand.used_d.get(name, 0) + tp > inv_t:
                                continue
                            new = self._extend_fast(cand, table, lprime, l,
                                                    nb_nl, nb_l, price,
                                                    name, tp, hb)
                            self.evaluated += 1
                            if new.batch <= 0 and l == n_l:
                                continue
                            self._update_fast(dp, key_new, new)
        return self._extract_fast(dp, t0)

    def _extend_fast(self, cand: _FastPartial, table: StageTable, lo: int,
                     hi: int, nb_nl: int, nb_l: int, price: float,
                     name: str, tp: int, hb=None) -> _FastPartial:
        segs = cand.segs + ((table, lo, hi),)
        used_d = dict(cand.used_d)
        used_d[name] = used_d.get(name, 0) + tp
        cost = cand.cost + price
        state, terms = _extend_state(
            (cand.batch, cand.m_nonlast, cand.sum_pre, cand.sum_dec,
             cand.max_pre, cand.max_dec), segs, table, lo, hi, nb_nl, nb_l)
        batch, m_nonlast, sum_pre, sum_dec, max_pre, max_dec = state
        if hb is None:
            if terms is None:
                return _FastPartial(segs, used_d, 0.0, 0, m_nonlast, 0.0,
                                    0.0, 0.0, 0.0, cost)
            bn_pre, bn_dec, tot_pre, tot_dec = terms
            l_b = bn_pre + bn_dec
            rps = batch / l_b if l_b > 0 else 0.0
            score = self._score_fast(rps, tot_pre + tot_dec, cost)
            return _FastPartial(segs, used_d, score, batch, m_nonlast,
                                sum_pre, sum_dec, max_pre, max_dec, cost)
        # histogram mode: replay the composition per populated bucket with
        # that bucket's own tables, then compose harmonically
        # (histogram_tokens_per_s) — any infeasible bucket zeroes the score
        sec_per_req = 0.0
        tok_per_req = 0.0
        feasible = True
        bstate = []
        for (w, out_b, t_b, nbnl_b, nbl_b), prev_b in zip(hb, cand.bstate):
            segs_b = prev_b[0] + ((t_b, lo, hi),)
            st_b, terms_b = _extend_state(prev_b[1:], segs_b, t_b, lo, hi,
                                          nbnl_b, nbl_b)
            bstate.append((segs_b,) + st_b)
            if terms_b is None:
                feasible = False
                continue
            l_bb = terms_b[0] + terms_b[1]
            rps_b = st_b[0] / l_bb if l_bb > 0 else 0.0
            if rps_b <= 0:
                feasible = False
                continue
            sec_per_req += w / rps_b
            tok_per_req += w * out_b
        score = 0.0
        if feasible and sec_per_req > 0:
            tps = tok_per_req / sec_per_req
            if tps > 0:
                score = tps / cost
        return _FastPartial(segs, used_d, score, batch, m_nonlast, sum_pre,
                            sum_dec, max_pre, max_dec, cost, tuple(bstate))

    def _score_fast(self, rps: float, e2e: float, cost: float) -> float:
        """Inline of Objective.score (Eq. 7) on engine scalars."""
        obj = self.objective
        if rps <= 0:
            return 0.0
        base = rps / cost if obj.per_cost else rps
        if obj.tokens_per_req > 0:
            base *= obj.tokens_per_req
        if obj.gamma == 0.0 or math.isinf(obj.slo_s):
            return base
        violation = max(0.0, e2e / obj.slo_s - 1.0)
        if math.isinf(obj.gamma):
            return 0.0 if violation > 0 else base
        return base * max(0.0, 1.0 - obj.gamma * violation)

    def _update_fast(self, dp, key, cand: _FastPartial) -> None:
        beam = dp.setdefault(key, [])
        # histogram mode (bstate set) never prunes: the dominance
        # quantities are single-point and don't bound how the per-bucket
        # harmonic score evolves — a primary-point-dominated candidate can
        # still win on a long-context bucket. The reference beam for a
        # subclassed objective is score-only top-k; match it.
        if self.prune_dominated and cand.bstate is None:
            # b dominates cand iff b is weakly better on every quantity an
            # extension's score can depend on: current score, Eq. 6 batch
            # headroom (m_nonlast — without it a zero-score-but-recoverable
            # partial would be pruned by a zero-score permanently-infeasible
            # one), realized batch and base bottleneck latencies (the score
            # alone can be temporarily depressed by the migrating LM-head
            # extras), and per-type inventory use.
            for b in beam:
                if _dominates(b, cand):
                    return                      # cand is dominated
            beam[:] = [b for b in beam if not _dominates(cand, b)]
        beam.append(cand)
        beam.sort(key=_neg_score)
        del beam[self.beam_k:]

    def _extract_fast(self, dp, t0) -> SearchResult:
        n_l = self.spec.n_layers
        best: Optional[_FastPartial] = None
        for s in range(1, self.max_stages + 1):
            for cand in dp.get((n_l, s), []):
                if best is None or cand.score > best.score:
                    best = cand
        wall = time.perf_counter() - t0
        if best is None:
            return SearchResult(None, 0.0, 0, 0.0, wall, self.evaluated)
        stages = tuple(Stage(t.instance, t.tp, hi - lo)
                       for t, lo, hi in best.segs)
        return self._finish(stages, best.score, wall)


def _extend_state(prev, segs, table, lo, hi, nb_nl, nb_l):
    """Compose one appended stage onto cached per-stage aggregates.

    ``prev`` is (batch, m_nonlast, sum_pre, sum_dec, max_pre, max_dec)
    before the new stage; ``segs`` already includes the new
    ``(table, lo, hi)`` segment (needed for the batch-changed rebuild).
    Returns ``(state, terms)``: the updated 6-tuple plus
    ``(bn_pre, bn_dec, tot_pre, tot_dec)`` of the pipeline with the new
    stage holding the LM head, or ``terms=None`` when the Eq. 6 batch
    hits zero.  This is float-for-float the composition pinned against
    the reference estimator by tests/test_fast_engine.py; the histogram
    objective replays it per traffic bucket with that bucket's tables.
    """
    p_batch, p_m_nonlast, p_sum_pre, p_sum_dec, p_max_pre, p_max_dec = prev
    k = len(segs) - 1
    m_nonlast = nb_nl if nb_nl < p_m_nonlast else p_m_nonlast
    batch = nb_l if nb_l < p_m_nonlast else p_m_nonlast
    if batch <= 0:
        return (0, m_nonlast, 0.0, 0.0, 0.0, 0.0), None
    bidx = batch - 1
    if k == 0:
        base_pre = (table.seg_pre(lo, hi, bidx) + table.pp_pre[bidx]
                    + table.first_pre[bidx])
        base_dec = table.seg_dec(lo, hi, bidx) + table.pp_dec[bidx]
        sum_pre, sum_dec = base_pre, base_dec
        max_pre, max_dec = base_pre, base_dec
    elif batch == p_batch:
        # O(1) composition: every cached aggregate is valid at `batch`
        base_pre = table.seg_pre(lo, hi, bidx) + table.pp_pre[bidx]
        base_dec = table.seg_dec(lo, hi, bidx) + table.pp_dec[bidx]
        sum_pre = p_sum_pre + base_pre
        sum_dec = p_sum_dec + base_dec
        max_pre = base_pre if base_pre > p_max_pre else p_max_pre
        max_dec = base_dec if base_dec > p_max_dec else p_max_dec
    else:
        # the new stage changed the Eq. 6 batch: rebuild the per-stage
        # terms at the new batch (O(stages) table lookups, no layer loop)
        sum_pre = sum_dec = max_pre = max_dec = 0.0
        base_pre = base_dec = 0.0
        for j, (t, l0, l1) in enumerate(segs):
            bp = t.seg_pre(l0, l1, bidx) + t.pp_pre[bidx]
            bd = t.seg_dec(l0, l1, bidx) + t.pp_dec[bidx]
            if j == 0:
                bp += t.first_pre[bidx]
            sum_pre += bp
            sum_dec += bd
            if bp > max_pre:
                max_pre = bp
            if bd > max_dec:
                max_dec = bd
            base_pre, base_dec = bp, bd
    # score terms with the new stage holding the LM head
    lpre_x = table.last_pre[bidx]
    ldec_x = table.last_dec[bidx]
    if k == 0:
        # single-stage pipeline: no PP hand-off at all (Eq. 2)
        p0 = base_pre - table.pp_pre[bidx] + lpre_x
        d0 = base_dec - table.pp_dec[bidx] + ldec_x
        tot_pre, tot_dec = p0, d0
        bn_pre, bn_dec = p0, d0
    else:
        tot_pre = sum_pre + lpre_x
        tot_dec = sum_dec + ldec_x
        lp = base_pre + lpre_x
        ld = base_dec + ldec_x
        bn_pre = lp if lp > max_pre else max_pre
        bn_dec = ld if ld > max_dec else max_dec
    return ((batch, m_nonlast, sum_pre, sum_dec, max_pre, max_dec),
            (bn_pre, bn_dec, tot_pre, tot_dec))


def _neg_score(c) -> float:
    return -c.score


def _dominates(a: "_FastPartial", b: "_FastPartial") -> bool:
    """a dominates b: weakly better score, batch headroom, realized batch,
    base bottleneck latencies (comparable since a.batch >= b.batch and
    latency is monotone in batch) and inventory use."""
    return (a.score >= b.score and a.m_nonlast >= b.m_nonlast
            and a.batch >= b.batch
            and a.max_pre <= b.max_pre and a.max_dec <= b.max_dec
            and _used_leq(a.used_d, b.used_d))


def _used_leq(a: Dict[str, int], b: Dict[str, int]) -> bool:
    """True iff a uses no more devices than b of every instance type."""
    for name, d in a.items():
        if d > b.get(name, 0):
            return False
    return True


def exhaustive_search(spec: ModelSpec, inventory: Dict[str, int],
                      instances: Dict[str, InstanceProfile], s_in: int,
                      s_out: int, objective: Optional[Objective] = None,
                      max_stages: int = 4,
                      engine: Optional[FastEstimator] = None) -> SearchResult:
    """Brute-force reference used by tests on tiny problems (the paper's
    'intractable exhaustive search' — only viable for a handful of layers).

    Scoring goes through the prefix-sum engine, which makes the paper's
    Fig 11 'exhaustive' yardstick reach a few more layers before blowing up.
    """
    objective = objective or Objective()
    opts = stage_options_for([instances[n] for n in inventory])
    inv = {n: c * instances[n].num_devices for n, c in inventory.items()}
    engine = engine or FastEstimator(spec, s_in, s_out)
    n_l = spec.n_layers
    best, best_score = None, -1.0
    evaluated = 0
    t0 = time.perf_counter()

    def partitions(n, k):
        if k == 1:
            yield (n,)
            return
        for first in range(1, n - k + 2):
            for rest in partitions(n - first, k - 1):
                yield (first,) + rest

    for k in range(1, max_stages + 1):
        for part in partitions(n_l, k):
            for combo in itertools.product(opts, repeat=k):
                used: Dict[str, int] = {}
                ok = True
                for o in combo:
                    used[o.instance.name] = used.get(o.instance.name, 0) + o.tp
                    if used[o.instance.name] > inv.get(o.instance.name, 0):
                        ok = False
                        break
                if not ok:
                    continue
                stages = tuple(
                    Stage(o.instance, o.tp, nl, first=(i == 0),
                          last=(i == k - 1))
                    for i, (o, nl) in enumerate(zip(combo, part)))
                placement = Placement(spec, stages)
                perf = engine.estimate(placement)
                evaluated += 1
                sc = objective.score(placement, perf)
                if sc > best_score:
                    best, best_score = placement, sc
    wall = time.perf_counter() - t0
    if best is None:
        return SearchResult(None, 0.0, 0, 0.0, wall, evaluated)
    perf = estimate(spec, best, s_in, s_out)
    return SearchResult(best, best_score, perf.batch, perf.throughput_rps,
                        wall, evaluated)
