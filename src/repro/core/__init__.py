"""ShuntServe core: the paper's primary contribution.

  * roofline.py   — op-level FLOPs/bytes cost tables (paper Table 2)
  * comm.py       — alpha-beta communication model (Eqs. 2-3)
  * estimator.py  — static latency + throughput estimation (Eqs. 1, 4, 5)
  * eval_engine.py— prefix-sum cost tables: O(1) stage scoring for search
  * objective.py  — throughput-per-cost objective with SLO penalty (Eq. 7)
  * buckets.py    — length-bucket throughput tables + $/token objective
  * placement.py  — DP + beam-search placement optimizer (Algorithm 1)
  * cluster_opt.py— iterative pipeline extraction to populate a cluster
  * baselines.py  — vLLM / AlpaServe / HexGen-style placement baselines
  * modelspec.py  — analytical architecture description
"""

from repro.core.buckets import (BucketEstimator, BucketTable,
                                HistogramCostObjective, LengthBuckets,
                                bucket_table, histogram_cost_per_token,
                                workload_histogram)
from repro.core.cluster_opt import ClusterPlan, populate_cluster
from repro.core.estimator import PerfEstimate, Placement, Stage, estimate
from repro.core.eval_engine import FastEstimator, StageTable
from repro.core.modelspec import LayerSpec, ModelSpec, uniform_decoder
from repro.core.objective import Objective, cost_per_token
from repro.core.placement import PlacementOptimizer, SearchResult

__all__ = [
    "Placement", "PerfEstimate", "Stage", "estimate", "FastEstimator",
    "StageTable", "LayerSpec", "ModelSpec", "uniform_decoder", "Objective",
    "cost_per_token", "LengthBuckets", "BucketEstimator", "BucketTable",
    "bucket_table", "workload_histogram", "histogram_cost_per_token",
    "HistogramCostObjective", "PlacementOptimizer", "SearchResult",
    "ClusterPlan", "populate_cluster",
]
