"""Analytical model specification consumed by the roofline estimator.

This is the *estimator-side* view of an architecture: just enough structure
to evaluate paper Table 2 (FLOPs + memory-scan per operation) for every layer
and phase. The executable JAX modules live in ``repro.models``; both are
constructed from the same ``repro.configs`` entries so the analytical plane
and the execution plane can never drift apart.

Layer kinds:
  * "attn+ffn"   — standard transformer decoder layer (GQA dense FFN)
  * "attn+moe"   — transformer layer with top-k MoE FFN
  * "mamba2"     — Mamba2 SSD mixer block (attention-free)
  * "shared_attn"— Zamba2-style full transformer block spliced into the
                   Mamba2 trunk (own KV cache per application)
  * "enc"        — encoder self-attn layer (whisper encoder; no KV growth)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                     # see module docstring
    hidden: int                   # H
    n_heads: int
    n_kv_heads: int
    head_dim: int
    ffn_dim: int                  # dense FFN intermediate (0 for mamba2)
    gated_ffn: bool = True        # SwiGLU-style (up+gate) vs plain MLP
    window: Optional[int] = None  # sliding-window attention width
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    conv_dim: int = 4

    @property
    def kv_hidden(self) -> int:
        """H_kv in paper Table 2 = n_kv_heads * head_dim."""
        return self.n_kv_heads * self.head_dim

    @property
    def q_hidden(self) -> int:
        return self.n_heads * self.head_dim

    def weight_bytes(self, e: int = 2) -> float:
        """Parameter bytes of this layer (all experts counted — Eq 6 uses
        *capacity*, not active compute)."""
        h = self.hidden
        if self.kind == "mamba2":
            d_inner = self.ssm_heads * self.ssm_head_dim
            # in_proj (z,x,B,C,dt) + conv + out_proj + norms (approx exact
            # per mamba2 param layout).
            in_proj = h * (2 * d_inner + 2 * self.ssm_state + self.ssm_heads)
            conv = self.conv_dim * (d_inner + 2 * self.ssm_state)
            out_proj = d_inner * h
            return (in_proj + conv + out_proj + 2 * h) * e
        attn = h * self.q_hidden + 2 * h * self.kv_hidden + self.q_hidden * h
        if self.n_experts > 0:
            per_expert = (3 if self.gated_ffn else 2) * h * self.ffn_dim
            ffn = self.n_experts * per_expert + h * self.n_experts  # + router
        else:
            ffn = (3 if self.gated_ffn else 2) * h * self.ffn_dim
        return (attn + ffn + 2 * h) * e

    def kv_bytes_per_token(self, e: int = 2) -> float:
        """KV-cache bytes one token adds on this layer (Eq 6 denominator).

        mamba2 layers contribute 0 here — their state is constant-size and
        accounted separately via ``state_bytes_per_seq``.
        """
        if self.kind == "mamba2":
            return 0.0
        return 2 * self.kv_hidden * e

    def state_bytes_per_seq(self, e: int = 2) -> float:
        """Constant per-sequence state (SSM state + conv buffer)."""
        if self.kind != "mamba2":
            return 0.0
        d_inner = self.ssm_heads * self.ssm_head_dim
        return (self.ssm_heads * self.ssm_head_dim * self.ssm_state
                + self.conv_dim * (d_inner + 2 * self.ssm_state)) * e


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    layers: Tuple[LayerSpec, ...]
    hidden: int
    vocab: int
    dtype_bytes: int = 2
    tie_embeddings: bool = False
    encoder_layers: Tuple[LayerSpec, ...] = ()   # enc-dec models

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def weight_bytes_total(self) -> float:
        e = self.dtype_bytes
        emb = self.vocab * self.hidden * e
        head = 0 if self.tie_embeddings else self.vocab * self.hidden * e
        enc = sum(l.weight_bytes(e) for l in self.encoder_layers)
        return emb + head + enc + sum(l.weight_bytes(e) for l in self.layers)

    def params_total(self) -> float:
        return self.weight_bytes_total() / self.dtype_bytes

    def params_active(self) -> float:
        """Active params per token (MoE: top_k experts only)."""
        e = self.dtype_bytes
        tot = self.vocab * self.hidden * (1 if self.tie_embeddings else 2)
        for l in self.layers + self.encoder_layers:
            if l.n_experts > 0:
                per_expert = (3 if l.gated_ffn else 2) * l.hidden * l.ffn_dim
                dense = l.weight_bytes(e) / e - l.n_experts * per_expert
                tot += dense + l.top_k * per_expert
            else:
                tot += l.weight_bytes(e) / e
        return tot


def uniform_decoder(name: str, n_layers: int, hidden: int, n_heads: int,
                    n_kv_heads: int, ffn_dim: int, vocab: int,
                    head_dim: Optional[int] = None, gated: bool = True,
                    window: Optional[int] = None, n_experts: int = 0,
                    top_k: int = 0, dtype_bytes: int = 2,
                    tie_embeddings: bool = False) -> ModelSpec:
    hd = head_dim or hidden // n_heads
    kind = "attn+moe" if n_experts else "attn+ffn"
    layer = LayerSpec(kind, hidden, n_heads, n_kv_heads, hd, ffn_dim,
                      gated_ffn=gated, window=window, n_experts=n_experts,
                      top_k=top_k)
    return ModelSpec(name, (layer,) * n_layers, hidden, vocab,
                     dtype_bytes=dtype_bytes, tie_embeddings=tie_embeddings)
