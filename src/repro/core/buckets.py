"""Length-bucketed throughput/cost tables and the $/token placement
objective (Mélange-style, "Demystifying Cost-Efficiency in LLM Serving
over Heterogeneous GPUs" — PAPERS.md).

The §4.1 estimator scores a placement at ONE (s_in, s_out) workload
point, so dispatch weights and placement scores treat every request
alike.  But cost-efficiency on a heterogeneous cluster is decided by
*where each length class runs*: a low-HBM L4 pipeline is fine for short
chats and collapses (Eq. 6 batch bound) on long contexts that an L40S
absorbs.  This module generalizes the same prefix-sum engine
(``eval_engine.FastEstimator`` — one per bucket representative point,
tables shared per (instance, tp)) across a small grid of
(input-len, output-len) buckets:

  * :class:`LengthBuckets` — the bucket grid.  A request classifies by
    (prompt len, max_new_tokens); each bucket's *representative* point is
    its upper edge, so a placement is only credited throughput it can
    sustain for every request in the bucket (memory-conservative).
  * :func:`bucket_table` — per-bucket output tokens/s and $/token for one
    placement: the routing weight table ``GlobalServer`` dispatches on.
  * :func:`workload_histogram` — normalized bucket weights of a traffic
    mix.
  * :class:`HistogramCostObjective` — Eq. 7 generalized to a traffic
    histogram: maximize output tokens/s per $/hr over the mix (its
    reciprocal is $/token), with a bucket the placement cannot serve at
    all zeroing the score.  Plugs into ``PlacementOptimizer`` /
    ``exhaustive_search`` / ``populate_cluster`` unchanged, so the
    optimizer answers "which spot mix serves this traffic histogram
    cheapest".
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.estimator import DEFAULT_BATCH_CAP, Placement
from repro.core.eval_engine import FastEstimator
from repro.core.modelspec import ModelSpec
from repro.core.objective import Objective

# Azure-conversation-like traffic (workload.py): inputs clip to [16, 2048],
# outputs to [8, 1024] — three bands each cover short chat, the lognormal
# body, and the long-context tail.
DEFAULT_IN_EDGES = (128, 512, 2048)
DEFAULT_OUT_EDGES = (64, 256, 1024)


@dataclasses.dataclass(frozen=True)
class LengthBuckets:
    """A grid of (input-len, output-len) buckets.

    ``in_edges``/``out_edges`` are ascending *upper* bounds; lengths above
    the last edge clamp into the last bucket (the estimator is evaluated
    at the edge, so oversize requests are scored at the grid boundary
    rather than extrapolated)."""

    in_edges: Tuple[int, ...] = DEFAULT_IN_EDGES
    out_edges: Tuple[int, ...] = DEFAULT_OUT_EDGES

    @property
    def n_in(self) -> int:
        return len(self.in_edges)

    @property
    def n_out(self) -> int:
        return len(self.out_edges)

    def bucket_of(self, s_in: int, s_out: int) -> Tuple[int, int]:
        bi = bisect.bisect_left(self.in_edges, s_in)
        bo = bisect.bisect_left(self.out_edges, s_out)
        return (min(bi, self.n_in - 1), min(bo, self.n_out - 1))

    def rep(self, bi: int, bo: int) -> Tuple[int, int]:
        """The bucket's representative (s_in, s_out): its upper edge."""
        return (self.in_edges[bi], self.out_edges[bo])

    def pairs(self) -> Iterable[Tuple[int, int]]:
        for bi in range(self.n_in):
            for bo in range(self.n_out):
                yield (bi, bo)


class BucketEstimator:
    """One ``FastEstimator`` per bucket representative point, built lazily
    and shared across every placement scored through this instance (the
    underlying prefix-sum tables are additionally shared per
    (instance, tp) inside each FastEstimator)."""

    def __init__(self, spec: ModelSpec,
                 buckets: Optional[LengthBuckets] = None,
                 batch_cap: int = DEFAULT_BATCH_CAP):
        self.spec = spec
        self.buckets = buckets or LengthBuckets()
        self.batch_cap = batch_cap
        self._est: Dict[Tuple[int, int], FastEstimator] = {}

    def estimator(self, bi: int, bo: int) -> FastEstimator:
        key = (bi, bo)
        e = self._est.get(key)
        if e is None:
            s_in, s_out = self.buckets.rep(bi, bo)
            e = FastEstimator(self.spec, s_in, s_out, self.batch_cap)
            self._est[key] = e
        return e

    def perf(self, placement: Placement, bi: int, bo: int):
        return self.estimator(bi, bo).estimate(placement)

    def tok_s(self, placement: Placement, bi: int, bo: int) -> float:
        """Output tokens/s the placement sustains on bucket (bi, bo)
        traffic: Eq. 4/5 requests/s at the representative point times the
        representative output length. 0.0 when the bucket is infeasible
        (Eq. 6 batch bound hits zero)."""
        perf = self.perf(placement, bi, bo)
        if perf.batch <= 0 or perf.throughput_rps <= 0:
            return 0.0
        return perf.throughput_rps * self.buckets.rep(bi, bo)[1]


@dataclasses.dataclass
class BucketTable:
    """Per-bucket routing weights for ONE placement: output tokens/s and
    its price-normalized form (the dispatch-weight table)."""

    buckets: LengthBuckets
    tok_s: List[List[float]]            # [bi][bo] output tokens/s
    price_spot_hr: float
    price_ondemand_hr: float

    def cost_per_token(self, bi: int, bo: int, spot: bool = True) -> float:
        """$ per output token on bucket (bi, bo) traffic (inf when the
        placement cannot serve the bucket)."""
        t = self.tok_s[bi][bo]
        if t <= 0:
            return math.inf
        price = self.price_spot_hr if spot else self.price_ondemand_hr
        return price / 3600.0 / t

    def weight(self, bi: int, bo: int, policy: str = "cost",
               spot: bool = True) -> float:
        """Dispatch weight, higher is better.  ``"throughput"`` — output
        tokens/s; ``"cost"`` — tokens/s per $/hr (the reciprocal of
        $/token up to a constant)."""
        t = self.tok_s[bi][bo]
        if policy == "throughput":
            return t
        assert policy == "cost", policy
        price = self.price_spot_hr if spot else self.price_ondemand_hr
        return t / price if price > 0 else t


def bucket_table(placement: Placement,
                 buckets: Optional[LengthBuckets] = None,
                 est: Optional[BucketEstimator] = None) -> BucketTable:
    """Build the per-bucket throughput/cost table for one placement.
    Pass a shared ``BucketEstimator`` when tabling many placements of the
    same spec (e.g. every pipeline of a cluster plan)."""
    if est is None:
        est = BucketEstimator(placement.spec, buckets)
    bk = est.buckets
    tok = [[est.tok_s(placement, bi, bo) for bo in range(bk.n_out)]
           for bi in range(bk.n_in)]
    return BucketTable(bk, tok, placement.price_hr(spot=True),
                       placement.price_hr(spot=False))


def workload_histogram(pairs: Sequence[Tuple[int, int]],
                       buckets: Optional[LengthBuckets] = None
                       ) -> List[List[float]]:
    """Normalized bucket weights of a traffic mix given as
    (s_in, s_out) pairs."""
    bk = buckets or LengthBuckets()
    hist = [[0.0] * bk.n_out for _ in range(bk.n_in)]
    for s_in, s_out in pairs:
        bi, bo = bk.bucket_of(s_in, s_out)
        hist[bi][bo] += 1.0
    n = float(len(pairs))
    if n > 0:
        hist = [[w / n for w in row] for row in hist]
    return hist


def histogram_tokens_per_s(placement: Placement,
                           hist: Sequence[Sequence[float]],
                           est: BucketEstimator) -> float:
    """Output tokens/s one placement sustains serving the histogram mix,
    under time-sharing: a fraction ``w_b`` of requests draws from bucket
    ``b``, so mean seconds/request is ``sum_b w_b / rps_b`` (harmonic
    composition) and mean output tokens/request is ``sum_b w_b * out_b``.
    0.0 when any populated bucket is infeasible — a mix that cannot be
    served is not cheap, it is impossible."""
    bk = est.buckets
    sec_per_req = 0.0
    tok_per_req = 0.0
    for bi in range(bk.n_in):
        for bo in range(bk.n_out):
            w = hist[bi][bo]
            if w <= 0:
                continue
            perf = est.perf(placement, bi, bo)
            if perf.batch <= 0 or perf.throughput_rps <= 0:
                return 0.0
            sec_per_req += w / perf.throughput_rps
            tok_per_req += w * bk.rep(bi, bo)[1]
    if sec_per_req <= 0:
        return 0.0
    return tok_per_req / sec_per_req


def histogram_cost_per_token(placement: Placement,
                             hist: Sequence[Sequence[float]],
                             est: BucketEstimator,
                             spot: bool = True) -> float:
    """$ per output token serving the histogram mix on this placement."""
    tps = histogram_tokens_per_s(placement, hist, est)
    if tps <= 0:
        return math.inf
    return placement.price_hr(spot=spot) / 3600.0 / tps


class HistogramCostObjective(Objective):
    """Eq. 7 generalized to a traffic histogram: score is output tokens/s
    per $/hr over the (input-len, output-len) bucket mix — the reciprocal
    of $/token, so argmax score == argmin $/token.

    ``PlacementOptimizer`` recognizes this objective on its fast path:
    the incremental stage composition is replayed per populated bucket
    against that bucket's own prefix-sum tables (drawn from the same
    cached ``BucketEstimator`` the reference scorer uses), so histogram
    searches run at table-lookup speed rather than falling back to the
    per-candidate reference scorer.  Any *other* ``Objective`` subclass
    still routes to the reference path, where ``score`` is consulted per
    candidate; ``exhaustive_search`` and ``populate_cluster`` consume it
    unchanged."""

    def __init__(self, hist: Sequence[Sequence[float]],
                 buckets: Optional[LengthBuckets] = None,
                 spot_pricing: bool = True,
                 batch_cap: int = DEFAULT_BATCH_CAP):
        super().__init__(spot_pricing=spot_pricing)
        # Objective is a frozen dataclass; extra state goes around it
        object.__setattr__(self, "hist", [list(r) for r in hist])
        object.__setattr__(self, "buckets", buckets or LengthBuckets())
        object.__setattr__(self, "batch_cap", batch_cap)
        object.__setattr__(self, "_est", {})

    def _estimator(self, spec: ModelSpec) -> BucketEstimator:
        est = self._est.get(spec)
        if est is None:
            est = BucketEstimator(spec, self.buckets, self.batch_cap)
            self._est[spec] = est
        return est

    def tokens_per_s(self, placement: Placement) -> float:
        return histogram_tokens_per_s(placement, self.hist,
                                      self._estimator(placement.spec))

    def cost_per_token(self, placement: Placement) -> float:
        return histogram_cost_per_token(placement, self.hist,
                                        self._estimator(placement.spec),
                                        spot=self.spot_pricing)

    def score(self, placement: Placement, perf) -> float:
        # ``perf`` is the optimizer's single-point estimate; infeasible
        # there (batch 0) means infeasible everywhere deeper, and the
        # histogram scorer re-checks per-bucket feasibility itself.
        tps = self.tokens_per_s(placement)
        if tps <= 0:
            return 0.0
        return tps / placement.price_hr(self.spot_pricing)
