"""Cluster-level pipeline extraction — paper §4.2.1 (last paragraph).

"ShuntServe employs this efficient optimization process iteratively, allowing
it to greedily extract the desired number of pipeline configurations to
populate the serving system."

Each extracted pipeline consumes its instances from the inventory (whole
instances — the fault-isolation rule), then the optimizer re-runs on the
remainder until no feasible pipeline is left or ``max_pipelines`` is hit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.estimator import Placement
from repro.core.eval_engine import FastEstimator
from repro.core.modelspec import ModelSpec
from repro.core.objective import Objective
from repro.core.placement import PlacementOptimizer, SearchResult
from repro.hw.profiles import InstanceProfile


@dataclasses.dataclass
class ClusterPlan:
    pipelines: List[Placement]
    throughputs_rps: List[float]
    leftover_inventory: Dict[str, int]
    wall_time_s: float

    @property
    def total_rps(self) -> float:
        return sum(self.throughputs_rps)

    def price_hr(self, spot: bool = True) -> float:
        return sum(p.price_hr(spot) for p in self.pipelines)

    def weights(self) -> List[float]:
        """Weighted round-robin dispatch weights (paper §3)."""
        tot = self.total_rps
        if tot <= 0:
            return [1.0 / max(1, len(self.pipelines))] * len(self.pipelines)
        return [t / tot for t in self.throughputs_rps]


def populate_cluster(spec: ModelSpec, inventory: Dict[str, int],
                     instances: Dict[str, InstanceProfile], s_in: int,
                     s_out: int, objective: Optional[Objective] = None,
                     beam_k: int = 3, max_pipelines: int = 64,
                     min_score_frac: float = 0.0,
                     max_tp: Optional[int] = None) -> ClusterPlan:
    import time
    t0 = time.perf_counter()
    inv = dict(inventory)
    pipelines: List[Placement] = []
    rps: List[float] = []
    first_score: Optional[float] = None
    # one table engine shared by every extraction iteration: the prefix-sum
    # tables depend only on (spec, s_in, s_out), not on the shrinking
    # inventory, so re-plans after spot interruptions pay no rebuild cost.
    engine = FastEstimator(spec, s_in, s_out)
    while len(pipelines) < max_pipelines:
        avail = {n: c for n, c in inv.items() if c > 0}
        if not avail:
            break
        opt = PlacementOptimizer(spec, avail, instances, s_in, s_out,
                                 objective=objective, beam_k=beam_k,
                                 max_tp=max_tp, engine=engine)
        res = opt.search()
        if res.placement is None or res.throughput_rps <= 0:
            break
        if first_score is None:
            first_score = res.score
        elif res.score < min_score_frac * first_score:
            break
        pipelines.append(res.placement)
        rps.append(res.throughput_rps)
        # consume whole instances (fault isolation: no instance sharing
        # across pipelines)
        dev_used: Dict[str, int] = {}
        for s in res.placement.stages:
            dev_used[s.instance.name] = dev_used.get(s.instance.name, 0) + s.tp
        for name, devs in dev_used.items():
            per = instances[name].num_devices
            inv[name] = inv.get(name, 0) - math.ceil(devs / per)
    return ClusterPlan(pipelines, rps, inv, time.perf_counter() - t0)
