"""Optimization objective — paper §4.2.3, Eq. 7.

    argmax_p  Throughput(p)/Cost(p) * (1 - gamma * max(0, latency/SLO - 1))

gamma=0 (paper default) optimizes pure throughput-per-cost; gamma=inf makes
the SLO a hard constraint.

``tokens_per_req`` converts the numerator from requests/s to output
tokens/s, making the score the reciprocal of $/token (up to the 1/3600
$/hr scale): at a fixed workload point the argmax is unchanged, but the
scores become comparable *across* workload points — which is what the
histogram-weighted $/token objective (``core.buckets``) composes over the
(input-len, output-len) bucket grid.  ``cost_per_token`` reports the
actual dollar figure.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.estimator import PerfEstimate, Placement


@dataclasses.dataclass(frozen=True)
class Objective:
    gamma: float = 0.0
    slo_s: float = math.inf
    spot_pricing: bool = True
    # throughput-only mode (used by some baselines / ablations)
    per_cost: bool = True
    # > 0: score in output tokens/s (per $ when per_cost) instead of req/s
    tokens_per_req: float = 0.0

    def score(self, placement: Placement, perf: PerfEstimate) -> float:
        if perf.throughput_rps <= 0:
            return 0.0
        cost = placement.price_hr(spot=self.spot_pricing)
        base = perf.throughput_rps / cost if self.per_cost else perf.throughput_rps
        if self.tokens_per_req > 0:
            base *= self.tokens_per_req
        if self.gamma == 0.0 or math.isinf(self.slo_s):
            return base
        violation = max(0.0, perf.e2e_latency_s / self.slo_s - 1.0)
        if math.isinf(self.gamma):
            return 0.0 if violation > 0 else base
        return base * max(0.0, 1.0 - self.gamma * violation)


def cost_per_token(placement: Placement, perf: PerfEstimate,
                   tokens_per_req: float, spot: bool = True) -> float:
    """$ per output token of one placement at one workload point:
    (price/hr) / (3600 * rps * tokens/req).  inf when infeasible."""
    if perf.throughput_rps <= 0 or tokens_per_req <= 0:
        return math.inf
    return (placement.price_hr(spot=spot) / 3600.0
            / (perf.throughput_rps * tokens_per_req))
