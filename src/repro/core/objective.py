"""Optimization objective — paper §4.2.3, Eq. 7.

    argmax_p  Throughput(p)/Cost(p) * (1 - gamma * max(0, latency/SLO - 1))

gamma=0 (paper default) optimizes pure throughput-per-cost; gamma=inf makes
the SLO a hard constraint.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.estimator import PerfEstimate, Placement


@dataclasses.dataclass(frozen=True)
class Objective:
    gamma: float = 0.0
    slo_s: float = math.inf
    spot_pricing: bool = True
    # throughput-only mode (used by some baselines / ablations)
    per_cost: bool = True

    def score(self, placement: Placement, perf: PerfEstimate) -> float:
        if perf.throughput_rps <= 0:
            return 0.0
        cost = placement.price_hr(spot=self.spot_pricing)
        base = perf.throughput_rps / cost if self.per_cost else perf.throughput_rps
        if self.gamma == 0.0 or math.isinf(self.slo_s):
            return base
        violation = max(0.0, perf.e2e_latency_s / self.slo_s - 1.0)
        if math.isinf(self.gamma):
            return 0.0 if violation > 0 else base
        return base * max(0.0, 1.0 - self.gamma * violation)
