"""Vectorized incremental placement-evaluation engine.

The DP+beam optimizer (paper §4.2, Alg. 1) scores ~1e5-1e6 partial
pipelines per search, and the reference scorer (``repro.core.estimator``)
walks every layer of every stage on every call — O(stages x layers) Python
work per beam extension.  This module collapses that to table lookups:

  * :class:`StageTable` — per (instance, tp) **prefix-sum cost tables**:
    numpy cumulative sums over the layer axis of per-layer roofline
    prefill/decode latency (for every Eq. 6 batch size 1..cap at once,
    via ``roofline.layer_latency_array``), weight bytes and per-sequence
    KV/state bytes.  Any contiguous layer segment's latency, weight
    footprint and Eq. 6 batch bound is then an O(1) difference of two
    table entries.  First/last-stage extras (embedding + encoder prefix,
    LM head weights, logits op) and the per-layer TP-collective / PP
    hand-off terms (Eqs. 2-3) are separate per-batch vectors.

  * :class:`FastEstimator` — drop-in replacement for
    ``estimator.estimate``: evaluates a full :class:`Placement` in
    O(stages) table lookups.  Used by the DP optimizer, the exhaustive
    reference search and every §7.1.2 baseline planner so all of them
    speed up together.

The reference implementation in ``repro.core.estimator`` is unchanged and
remains the source of truth; ``tests/test_fast_engine.py`` pins this
engine to it.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm, roofline
from repro.core.estimator import (ACT_HEADROOM, DEFAULT_BATCH_CAP,
                                  PerfEstimate, Placement,
                                  activation_bytes_per_seq,
                                  estimate as reference_estimate)
from repro.core.modelspec import ModelSpec
from repro.hw.profiles import InstanceProfile


class StageTable:
    """Prefix-sum cost tables for stages built from ``tp`` devices of one
    instance type, for a fixed (spec, s_in, s_out) workload point.

    Hot lookups are stored as plain Python lists — scalar indexing into
    lists is ~3x faster than into numpy arrays, and the beam search does
    millions of scalar reads.
    """

    __slots__ = (
        "instance", "tp", "batch_cap", "pre_cum", "dec_cum", "w_cum",
        "kv_cum", "tp_pre", "tp_dec", "pp_pre", "pp_dec", "first_pre",
        "last_pre", "last_dec", "first_w", "last_w", "act", "mem_cap",
        "price_spot", "price_od",
    )

    def __init__(self, spec: ModelSpec, instance: InstanceProfile, tp: int,
                 s_in: int, s_out: int,
                 batch_cap: int = DEFAULT_BATCH_CAP):
        self.instance = instance
        self.tp = tp
        self.batch_cap = batch_cap
        dev = instance.device
        e = spec.dtype_bytes
        n = spec.n_layers
        B = np.arange(1, batch_cap + 1, dtype=np.float64)

        # --- per-layer roofline latency, all batch sizes at once ---------
        # uniform-layer models share one LayerSpec: evaluate each distinct
        # layer once and fan the row out over the layer axis.
        uniq: Dict[object, Tuple[np.ndarray, np.ndarray]] = {}
        pre_rows = np.empty((n, batch_cap))
        dec_rows = np.empty((n, batch_cap))
        for i, l in enumerate(spec.layers):
            if l not in uniq:
                uniq[l] = (
                    roofline.layer_latency_array(l, dev, "prefill", B, s_in,
                                                 s_out, tp, e),
                    roofline.layer_latency_array(l, dev, "decode", B, s_in,
                                                 s_out, tp, e))
            pre_rows[i], dec_rows[i] = uniq[l]
        zero = np.zeros((1, batch_cap))
        self.pre_cum = np.concatenate(
            [zero, np.cumsum(pre_rows, axis=0)]).tolist()
        self.dec_cum = np.concatenate(
            [zero, np.cumsum(dec_rows, axis=0)]).tolist()

        # --- weight / KV prefix sums (batch-independent) -----------------
        w = [l.weight_bytes(e) for l in spec.layers]
        kv = []
        for l in spec.layers:
            tokens = s_in + s_out
            if l.window is not None:
                tokens = min(tokens, l.window)
            kv.append(l.kv_bytes_per_token(e) * tokens
                      + l.state_bytes_per_seq(e))
        self.w_cum = np.concatenate([[0.0], np.cumsum(w)]).tolist()
        self.kv_cum = np.concatenate([[0.0], np.cumsum(kv)]).tolist()

        # --- per-layer TP collectives and per-stage PP hand-off ----------
        link = comm.Link(dev.intra_alpha_s, dev.intra_beta_bps)
        ilink = comm.Link(instance.inter_alpha_s, instance.inter_beta_bps)
        H = spec.hidden
        self.tp_pre = [comm.tp_comm_latency(b, s_in, H, tp, 1, link, e)
                       for b in range(1, batch_cap + 1)]
        self.tp_dec = [comm.tp_comm_latency(b, 1, H, tp, 1, link, e) * s_out
                       for b in range(1, batch_cap + 1)]
        self.pp_pre = [comm.pp_comm_latency(b, s_in, H, ilink, e)
                       for b in range(1, batch_cap + 1)]
        self.pp_dec = [comm.pp_comm_latency(b, 1, H, ilink, e) * s_out
                       for b in range(1, batch_cap + 1)]

        # --- first/last stage extras -------------------------------------
        first_pre = np.zeros(batch_cap)
        for l in spec.encoder_layers:
            first_pre += roofline.layer_latency_array(l, dev, "prefill", B,
                                                      s_in, 0, tp, e)
        self.first_pre = first_pre.tolist()
        self.last_pre = roofline.logits_op_cost(
            spec, "prefill", B, s_in, s_out, tp).latency(dev).tolist()
        self.last_dec = roofline.logits_op_cost(
            spec, "decode", B, s_in, s_out, tp).latency(dev).tolist()
        self.first_w = (spec.vocab * spec.hidden * e
                        + sum(l.weight_bytes(e)
                              for l in spec.encoder_layers))
        self.last_w = (0.0 if spec.tie_embeddings
                       else spec.vocab * spec.hidden * e)

        # --- Eq. 6 ingredients + pricing ----------------------------------
        self.act = activation_bytes_per_seq(spec, s_in, tp)
        self.mem_cap = tp * dev.mem_gb * 1e9 * ACT_HEADROOM
        frac = tp / instance.num_devices
        self.price_spot = instance.price_spot_hr * frac
        self.price_od = instance.price_ondemand_hr * frac

    # -- O(1) segment queries (bidx = batch - 1) ---------------------------
    def seg_pre(self, lo: int, hi: int, bidx: int) -> float:
        """Prefill latency of layers [lo, hi) incl. TP collectives."""
        return (self.pre_cum[hi][bidx] - self.pre_cum[lo][bidx]
                + (hi - lo) * self.tp_pre[bidx])

    def seg_dec(self, lo: int, hi: int, bidx: int) -> float:
        return (self.dec_cum[hi][bidx] - self.dec_cum[lo][bidx]
                + (hi - lo) * self.tp_dec[bidx])

    def bound(self, lo: int, hi: int, first: bool, last: bool) -> int:
        """Eq. 6 per-stage batch bound for layers [lo, hi)."""
        w = self.w_cum[hi] - self.w_cum[lo]
        if first:
            w += self.first_w
        if last:
            w += self.last_w
        avail = self.mem_cap - w
        if avail <= 0:
            return 0
        denom = self.kv_cum[hi] - self.kv_cum[lo] + self.act
        if denom <= 0:
            return self.batch_cap
        b = int(avail // denom)
        return b if b < self.batch_cap else self.batch_cap

    def per_layer_latency(self, bidx: int) -> List[float]:
        """Per-layer prefill+decode roofline latency at one batch size
        (no comm terms) — used by the AlpaServe latency-balancing DP."""
        pre, dec = self.pre_cum, self.dec_cum
        return [pre[i + 1][bidx] - pre[i][bidx]
                + dec[i + 1][bidx] - dec[i][bidx]
                for i in range(len(pre) - 1)]


class FastEstimator:
    """Table-backed equivalent of ``estimator.estimate`` for a fixed
    (spec, s_in, s_out).  Tables are built lazily per (instance, tp) and
    shared across every placement evaluated through this instance — e.g.
    all ``populate_cluster`` iterations and all baseline planners."""

    def __init__(self, spec: ModelSpec, s_in: int, s_out: int,
                 batch_cap: int = DEFAULT_BATCH_CAP):
        self.spec = spec
        self.s_in, self.s_out = s_in, s_out
        self.batch_cap = batch_cap
        self._tables: Dict[Tuple[InstanceProfile, int], StageTable] = {}

    def table(self, instance: InstanceProfile, tp: int) -> StageTable:
        key = (instance, tp)
        t = self._tables.get(key)
        if t is None:
            t = StageTable(self.spec, instance, tp, self.s_in, self.s_out,
                           self.batch_cap)
            self._tables[key] = t
        return t

    def estimate(self, placement: Placement,
                 batch: Optional[int] = None) -> PerfEstimate:
        """Mirror of ``estimator.estimate`` via table lookups."""
        stages = placement.stages
        ranges = placement.layer_ranges()
        tables = [self.table(s.instance, s.tp) for s in stages]
        if batch is None:
            batch = self.batch_cap
            for s, t, (lo, hi) in zip(stages, tables, ranges):
                batch = min(batch, t.bound(lo, hi, s.first, s.last))
        elif batch > self.batch_cap:
            # off the table grid; fall back to the reference path
            return reference_estimate(placement.spec, placement, self.s_in,
                                      self.s_out, batch=batch)
        if batch <= 0:
            return PerfEstimate(0, [], [], math.inf, math.inf, math.inf, 0.0)
        bidx = batch - 1
        d_pp = len(stages)
        pre, dec = [], []
        for s, t, (lo, hi) in zip(stages, tables, ranges):
            lp = t.seg_pre(lo, hi, bidx)
            ld = t.seg_dec(lo, hi, bidx)
            if s.first:
                lp += t.first_pre[bidx]
            if s.last:
                lp += t.last_pre[bidx]
                ld += t.last_dec[bidx]
            if not s.last or d_pp > 1:
                lp += t.pp_pre[bidx]
                ld += t.pp_dec[bidx]
            pre.append(lp)
            dec.append(ld)
        l_b = max(pre) + max(dec)
        rps = batch / l_b if l_b > 0 else 0.0
        ttft = sum(pre)
        tpot = sum(d / self.s_out for d in dec)
        e2e = sum(pre) + sum(dec)
        return PerfEstimate(batch, pre, dec, ttft, tpot, e2e, rps)
