"""Communication cost model — paper §4.1.2 (alpha-beta / Hockney model).

Ring-based collectives: one AllReduce = ReduceScatter + AllGather, each
moving N/P bytes for P-1 rounds:   t = (alpha + (N/P)/beta) * (P-1)  [x2]

TP incurs 2 AllReduces per transformer layer => Eq. 3's factor 4.
PP transfers one activation tensor per stage boundary (Eq. 2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Link:
    alpha_s: float
    beta_bps: float


def ring_allreduce(nbytes: float, p: int, link: Link) -> float:
    """One ring AllReduce of ``nbytes`` over ``p`` participants."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    per_round = link.alpha_s + (nbytes / p) / link.beta_bps
    return 2.0 * per_round * (p - 1)          # RS + AG


def ring_allgather(nbytes: float, p: int, link: Link) -> float:
    if p <= 1 or nbytes <= 0:
        return 0.0
    return (link.alpha_s + (nbytes / p) / link.beta_bps) * (p - 1)


def p2p(nbytes: float, link: Link) -> float:
    return link.alpha_s + nbytes / link.beta_bps


def tp_comm_latency(batch: int, seq: int, hidden: int, d_tp: int,
                    n_layers: int, link: Link, e: int = 2,
                    allreduces_per_layer: int = 2) -> float:
    """Paper Eq. 3: AllReduce of the (B,S,H) activation, twice per layer.

    Written via :func:`ring_allreduce` so the 4(alpha + BSHE/(D*beta))(D-1)l
    closed form of the paper falls out exactly for allreduces_per_layer=2.
    """
    if d_tp <= 1:
        return 0.0
    nbytes = batch * seq * hidden * e
    return allreduces_per_layer * ring_allreduce(nbytes, d_tp, link) * n_layers


def pp_comm_latency(batch: int, seq: int, hidden: int, link: Link,
                    e: int = 2) -> float:
    """Paper Eq. 2: one activation handoff at a stage boundary."""
    return p2p(batch * seq * hidden * e, link)
