"""Serving performance estimator — paper §4.1 (Eqs. 1, 4, 5).

Combines the op-level roofline costs (Table 2, ``repro.core.roofline``) with
the alpha-beta communication model (``repro.core.comm``) to predict per-stage
prefill/decode latency, end-to-end latency, and pipeline throughput for any
(placement x batch x sequence) point — no per-configuration profiling.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core import comm, roofline
from repro.core.modelspec import ModelSpec
from repro.hw.profiles import DeviceProfile, InstanceProfile


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: a layer range on (part of) one instance."""

    instance: InstanceProfile
    tp: int                       # devices used on this instance for TP
    n_layers: int                 # decoder layers assigned
    first: bool = False           # holds the input embedding
    last: bool = False            # holds the LM head (logits)
    n_encoder_layers: int = 0     # whisper-style encoder prefix

    @property
    def device(self) -> DeviceProfile:
        return self.instance.device

    @property
    def mem_bytes(self) -> float:
        return self.tp * self.device.mem_gb * 1e9

    def price_hr(self, spot: bool) -> float:
        frac = self.tp / self.instance.num_devices
        p = (self.instance.price_spot_hr if spot
             else self.instance.price_ondemand_hr)
        return p * frac

    def intra_link(self) -> comm.Link:
        return comm.Link(self.device.intra_alpha_s, self.device.intra_beta_bps)

    def inter_link(self) -> comm.Link:
        return comm.Link(self.instance.inter_alpha_s,
                         self.instance.inter_beta_bps)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A full pipeline placement: ordered stages covering all layers."""

    spec: ModelSpec
    stages: Tuple[Stage, ...]

    def __post_init__(self):
        assert sum(s.n_layers for s in self.stages) == self.spec.n_layers, \
            (sum(s.n_layers for s in self.stages), self.spec.n_layers)

    @property
    def d_pp(self) -> int:
        return len(self.stages)

    def layer_ranges(self) -> List[Tuple[int, int]]:
        out, lo = [], 0
        for s in self.stages:
            out.append((lo, lo + s.n_layers))
            lo += s.n_layers
        return out

    def price_hr(self, spot: bool = False) -> float:
        return sum(s.price_hr(spot) for s in self.stages)

    def describe(self) -> str:
        parts = [f"{s.instance.name}:tp{s.tp}:{s.n_layers}L"
                 for s in self.stages]
        return " | ".join(parts)


@dataclasses.dataclass
class PerfEstimate:
    batch: int
    prefill_stage_s: List[float]
    decode_stage_s: List[float]          # totals over S_out steps
    ttft_s: float
    tpot_s: float
    e2e_latency_s: float
    throughput_rps: float


# ---------------------------------------------------------------------------

# Eq. 6 defaults — the fast engine (repro.core.eval_engine) imports these so
# the two implementations can never drift apart.
ACT_HEADROOM = 0.9
DEFAULT_BATCH_CAP = 512


def activation_bytes_per_seq(spec: ModelSpec, s_in: int, tp: int) -> float:
    """Activation working set one request pins on a stage: a few live
    (S, H) tensors for prefill; the 4x covers residual + ffn intermediates
    under remat-free inference."""
    return 4.0 * s_in * spec.hidden * spec.dtype_bytes / max(1, tp)


def stage_weight_bytes(spec: ModelSpec, stage: Stage, lo: int, hi: int) -> float:
    e = spec.dtype_bytes
    w = sum(spec.layers[i].weight_bytes(e) for i in range(lo, hi))
    if stage.first:
        w += spec.vocab * spec.hidden * e
        w += sum(l.weight_bytes(e) for l in spec.encoder_layers)
    if stage.last and not spec.tie_embeddings:
        w += spec.vocab * spec.hidden * e
    return w


def stage_kv_bytes_per_seq(spec: ModelSpec, lo: int, hi: int, s_in: int,
                           s_out: int) -> float:
    """KV + SSM-state bytes one request pins on this stage (Eq 6 denom).

    Full attention: (S_in+S_out) tokens per layer; SWA: capped at window;
    Mamba2: constant state. This is the SSM/SWA-aware refinement of Eq. 6
    described in DESIGN.md §5.
    """
    e = spec.dtype_bytes
    total = 0.0
    for i in range(lo, hi):
        l = spec.layers[i]
        tokens = s_in + s_out
        if l.window is not None:
            tokens = min(tokens, l.window)
        total += l.kv_bytes_per_token(e) * tokens + l.state_bytes_per_seq(e)
    return total


def max_batch_size(spec: ModelSpec, placement: Placement, s_in: int,
                   s_out: int, act_headroom: float = ACT_HEADROOM,
                   cap: int = DEFAULT_BATCH_CAP) -> int:
    """Paper Eq. 6: largest B satisfying every stage's memory constraint.

    Refinement (documented): the activation term scales with B, so we solve
        B = (M*headroom - W) / (kv_per_seq + act_per_seq)
    instead of subtracting a fixed M_activation.
    """
    best = cap
    for stage, (lo, hi) in zip(placement.stages, placement.layer_ranges()):
        w = stage_weight_bytes(spec, stage, lo, hi)
        kv = stage_kv_bytes_per_seq(spec, lo, hi, s_in, s_out)
        act = activation_bytes_per_seq(spec, s_in, stage.tp)
        avail = stage.mem_bytes * act_headroom - w
        if avail <= 0:
            return 0
        denom = kv + act
        if denom <= 0:
            continue
        best = min(best, int(avail // denom))
    return max(0, best)


def stage_latencies(spec: ModelSpec, placement: Placement, batch: int,
                    s_in: int, s_out: int
                    ) -> Tuple[List[float], List[float]]:
    """Per-stage prefill and decode (total over S_out) latency, including TP
    collectives (Eq. 3) and the PP hand-off (Eq. 2) out of each stage."""
    e = spec.dtype_bytes
    prefill, decode = [], []
    for stage, (lo, hi) in zip(placement.stages, placement.layer_ranges()):
        dev = stage.device
        lp = ld = 0.0
        for i in range(lo, hi):
            l = spec.layers[i]
            lp += roofline.layer_latency(l, dev, "prefill", batch, s_in,
                                         s_out, stage.tp, e)
            ld += roofline.layer_latency(l, dev, "decode", batch, s_in,
                                         s_out, stage.tp, e)
        if stage.first:
            for l in spec.encoder_layers:
                lp += roofline.layer_latency(l, dev, "prefill", batch, s_in,
                                             0, stage.tp, e)
        if stage.last:
            lp += roofline.logits_op_cost(spec, "prefill", batch, s_in,
                                          s_out, stage.tp).latency(dev)
            ld += roofline.logits_op_cost(spec, "decode", batch, s_in,
                                          s_out, stage.tp).latency(dev)
        # TP collectives (2 AllReduce / layer, Eq. 3)
        link = stage.intra_link()
        n_l = hi - lo
        lp += comm.tp_comm_latency(batch, s_in, spec.hidden, stage.tp, n_l,
                                   link, e)
        ld += comm.tp_comm_latency(batch, 1, spec.hidden, stage.tp, n_l,
                                   link, e) * s_out
        # PP hand-off to the next stage (Eq. 2)
        if not stage.last or placement.d_pp > 1:
            ilink = stage.inter_link()
            lp += comm.pp_comm_latency(batch, s_in, spec.hidden, ilink, e)
            ld += comm.pp_comm_latency(batch, 1, spec.hidden, ilink, e) * s_out
        prefill.append(lp)
        decode.append(ld)
    return prefill, decode


def estimate(spec: ModelSpec, placement: Placement, s_in: int, s_out: int,
             batch: Optional[int] = None) -> PerfEstimate:
    """Full paper pipeline: Eq. 6 batch -> Eq. 1 latencies -> Eq. 5 -> Eq. 4."""
    if batch is None:
        batch = max_batch_size(spec, placement, s_in, s_out)
    if batch <= 0:
        return PerfEstimate(0, [], [], math.inf, math.inf, math.inf, 0.0)
    pre, dec = stage_latencies(spec, placement, batch, s_in, s_out)
    # Eq. 5: bottleneck-stage latency per phase (pipelined steady state).
    l_b = max(pre) + max(dec)
    rps = batch / l_b if l_b > 0 else 0.0          # Eq. 4
    ttft = sum(pre)                                 # first token: full path
    tpot = sum(d / s_out for d in dec)              # per-token, full path
    e2e = sum(pre) + sum(dec)
    return PerfEstimate(batch, pre, dec, ttft, tpot, e2e, rps)
