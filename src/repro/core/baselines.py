"""Baseline placement algorithms the paper compares against (§7.1.2).

All baselines are *evaluated through the same estimator* as ShuntServe so the
comparison isolates the placement algorithm (exactly how the paper's offline
evaluation treats them — each system's algorithm decides the placement, the
same engine serves it).  Scoring goes through the prefix-sum table engine
(``repro.core.eval_engine``), which is pinned to the reference estimator by
tests — so the Fig 9/10 planners all speed up together.

  * ``vllm_even``       — vLLM: homogeneous groups, even layer partition,
                          intra-node TP (one pipeline per instance group).
  * ``alpaserve_dp``    — AlpaServe-style: homogeneous groups; two-phase
                          optimization (cluster grouping + DP that equalizes
                          stage latencies); prefers replication for SLO.
  * ``hexgen_genetic``  — HexGen-style: genetic algorithm over heterogeneous
                          assignments with memory-proportional layer
                          allocation and local-perturbation mutations.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cluster_opt import ClusterPlan
from repro.core.estimator import Placement, Stage
from repro.core.eval_engine import FastEstimator
from repro.core.modelspec import ModelSpec
from repro.core.objective import Objective
from repro.hw.profiles import InstanceProfile


def _even_split(n_layers: int, k: int) -> List[int]:
    base, rem = divmod(n_layers, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


def _mark_ends(stages: List[Stage]) -> Tuple[Stage, ...]:
    return tuple(
        dataclasses.replace(s, first=(i == 0), last=(i == len(stages) - 1))
        for i, s in enumerate(stages))


# ---------------------------------------------------------------------------
# vLLM: per homogeneous instance-type group, TP = intra-node, PP = enough
# nodes to fit the model, even layer split. One or more identical pipelines
# per group.
# ---------------------------------------------------------------------------
def vllm_even(spec: ModelSpec, inventory: Dict[str, int],
              instances: Dict[str, InstanceProfile], s_in: int,
              s_out: int) -> ClusterPlan:
    import time
    t0 = time.perf_counter()
    engine = FastEstimator(spec, s_in, s_out)
    pipelines, rps = [], []
    for name, count in inventory.items():
        if count <= 0:
            continue
        inst = instances[name]
        # smallest PP depth whose pipeline fits
        for d_pp in range(1, count + 1):
            split = _even_split(spec.n_layers, d_pp)
            if any(s <= 0 for s in split):
                break
            stages = _mark_ends([
                Stage(inst, inst.num_devices, nl) for nl in split])
            placement = Placement(spec, stages)
            perf = engine.estimate(placement)
            if perf.batch > 0:
                n_pipes = count // d_pp
                pipelines.extend([placement] * n_pipes)
                rps.extend([perf.throughput_rps] * n_pipes)
                break
    return ClusterPlan(pipelines, rps, {}, time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# AlpaServe-style: homogeneous groups; for each group enumerate (replicas,
# d_pp) splits; DP equalizes stage *latency* (not layer count); pick the
# grouping that maximizes aggregate goodput with a replication preference.
# ---------------------------------------------------------------------------
def _latency_balanced_split(spec: ModelSpec, inst: InstanceProfile,
                            d_pp: int, engine: FastEstimator) -> List[int]:
    """DP that minimizes the max per-stage latency over contiguous splits.

    Per-layer prefill+decode latency at batch 1 comes from the prefix-sum
    tables — one row read instead of 2n roofline evaluations."""
    n = spec.n_layers
    lat = engine.table(inst, inst.num_devices).per_layer_latency(0)
    prefix = [0.0]
    for v in lat:
        prefix.append(prefix[-1] + v)
    INF = math.inf
    # dp[s][i] = min over splits of first i layers into s stages of max stage
    dp = [[INF] * (n + 1) for _ in range(d_pp + 1)]
    cut = [[0] * (n + 1) for _ in range(d_pp + 1)]
    dp[0][0] = 0.0
    for s in range(1, d_pp + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                v = max(dp[s - 1][j], prefix[i] - prefix[j])
                if v < dp[s][i]:
                    dp[s][i], cut[s][i] = v, j
    # recover split
    splits, i = [], n
    for s in range(d_pp, 0, -1):
        j = cut[s][i]
        splits.append(i - j)
        i = j
    return list(reversed(splits))


def alpaserve_dp(spec: ModelSpec, inventory: Dict[str, int],
                 instances: Dict[str, InstanceProfile], s_in: int,
                 s_out: int, prefer_replication: bool = True) -> ClusterPlan:
    import time
    t0 = time.perf_counter()
    engine = FastEstimator(spec, s_in, s_out)
    pipelines, rps = [], []
    for name, count in inventory.items():
        if count <= 0:
            continue
        inst = instances[name]
        best: Optional[Tuple[float, List[Placement], List[float]]] = None
        for d_pp in range(1, count + 1):
            n_rep = count // d_pp
            if n_rep <= 0:
                continue
            split = _latency_balanced_split(spec, inst, d_pp, engine)
            if any(s <= 0 for s in split):
                continue
            stages = _mark_ends([
                Stage(inst, inst.num_devices, nl) for nl in split])
            placement = Placement(spec, stages)
            perf = engine.estimate(placement)
            if perf.batch <= 0:
                continue
            total = perf.throughput_rps * n_rep
            # replication preference: break near-ties toward more replicas
            # (AlpaServe's statistical-multiplexing bias).
            bias = 1.0 + (0.05 * n_rep if prefer_replication else 0.0)
            key = total * bias
            if best is None or key > best[0]:
                best = (key, [placement] * n_rep,
                        [perf.throughput_rps] * n_rep)
        if best:
            pipelines.extend(best[1])
            rps.extend(best[2])
    return ClusterPlan(pipelines, rps, {}, time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# HexGen-style genetic search.
# ---------------------------------------------------------------------------
def _memory_proportional_split(spec: ModelSpec, stages: List[Stage]
                               ) -> List[int]:
    """HexGen distributes layers proportional to stage memory capacity."""
    mems = [s.mem_bytes for s in stages]
    tot = sum(mems)
    n = spec.n_layers
    raw = [m / tot * n for m in mems]
    split = [max(1, int(r)) for r in raw]
    # fix rounding to sum exactly n
    while sum(split) > n:
        split[split.index(max(split))] -= 1
    while sum(split) < n:
        split[split.index(min(split))] += 1
    return split


def hexgen_genetic(spec: ModelSpec, inventory: Dict[str, int],
                   instances: Dict[str, InstanceProfile], s_in: int,
                   s_out: int, pop_size: int = 24, generations: int = 30,
                   seed: int = 0, objective: Optional[Objective] = None
                   ) -> ClusterPlan:
    """Genetic algorithm: a genome is a partition of the device inventory
    into pipelines of (instance, tp) stages; layers are allocated
    memory-proportionally, then refined by local perturbation (HexGen §5)."""
    import time
    t0 = time.perf_counter()
    rng = random.Random(seed)
    objective = objective or Objective()
    engine = FastEstimator(spec, s_in, s_out)
    dev_inv = {n: c * instances[n].num_devices for n, c in inventory.items()}

    def random_genome() -> List[List[Tuple[str, int]]]:
        # HexGen initializes groups from communication topology => stages
        # drawn per-instance; pipelines greedily filled until memory fits.
        inv = dict(dev_inv)
        pipes: List[List[Tuple[str, int]]] = []
        names = [n for n in inv if inv[n] > 0]
        while names:
            pipe: List[Tuple[str, int]] = []
            target_mem = spec.weight_bytes_total() * 1.3
            got = 0.0
            guard = 0
            while got < target_mem and guard < 64:
                guard += 1
                names = [n for n in inv if inv[n] > 0]
                if not names:
                    break
                n = rng.choice(names)
                inst = instances[n]
                tp = rng.choice([d for d in (1, 2, 4, 8)
                                 if d <= min(inst.num_devices, inv[n])])
                inv[n] -= tp
                pipe.append((n, tp))
                got += tp * inst.device.mem_gb * 1e9
            if pipe and got >= spec.weight_bytes_total():
                pipes.append(pipe)
            elif not pipe:
                break
            names = [n for n in inv if inv[n] > 0]
        return pipes

    def genome_to_plan(genome) -> ClusterPlan:
        pipelines, rps = [], []
        for pipe in genome:
            stages = [Stage(instances[n], tp, 1) for n, tp in pipe]
            split = _memory_proportional_split(spec, stages)
            if len(split) != len(stages) or any(x <= 0 for x in split):
                continue
            stages = _mark_ends([
                dataclasses.replace(s, n_layers=nl)
                for s, nl in zip(stages, split)])
            try:
                placement = Placement(spec, stages)
            except AssertionError:
                continue
            perf = engine.estimate(placement)
            if perf.batch <= 0:
                continue
            pipelines.append(placement)
            rps.append(perf.throughput_rps)
        return ClusterPlan(pipelines, rps, {}, 0.0)

    def fitness(genome) -> float:
        plan = genome_to_plan(genome)
        if not plan.pipelines:
            return 0.0
        cost = plan.price_hr(spot=True)
        return plan.total_rps / cost if cost > 0 else 0.0

    def mutate(genome):
        g = [list(p) for p in genome]
        if not g:
            return g
        # local perturbation: move a stage between pipelines or re-roll tp
        op = rng.random()
        pi = rng.randrange(len(g))
        if op < 0.5 and len(g[pi]) > 1:
            si = rng.randrange(len(g[pi]))
            stage = g[pi].pop(si)
            g[rng.randrange(len(g))].append(stage)
        else:
            si = rng.randrange(len(g[pi]))
            n, tp = g[pi][si]
            choices = [d for d in (1, 2, 4, 8)
                       if d <= instances[n].num_devices]
            g[pi][si] = (n, rng.choice(choices))
        return [p for p in g if p]

    pop = [random_genome() for _ in range(pop_size)]
    scored = sorted(((fitness(g), i, g) for i, g in enumerate(pop)),
                    key=lambda x: -x[0])
    for gen in range(generations):
        elite = [g for _, _, g in scored[:max(2, pop_size // 4)]]
        children = [mutate(rng.choice(elite))
                    for _ in range(pop_size - len(elite))]
        pop = elite + children
        scored = sorted(((fitness(g), i, g) for i, g in enumerate(pop)),
                        key=lambda x: -x[0])
    best = scored[0][2]
    plan = genome_to_plan(best)
    plan.wall_time_s = time.perf_counter() - t0
    return plan
