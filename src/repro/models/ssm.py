"""Mamba2 (SSD — state-space duality) block in pure JAX.

Prefill/train use the chunked SSD algorithm (arXiv:2405.21060): the sequence
is cut into chunks; within a chunk the dual quadratic form runs on the MXU
(C B^T masked by cumulative decay), between chunks a tiny recurrence carries
the (heads, head_dim, state) SSM state. Decode is the O(1) recurrent step.

This module is also the oracle for ``repro.kernels.ssd_scan``.

Layout (n_groups=1, as mamba2-1.3b / zamba2):
  in_proj : H -> [z (d_inner), x (d_inner), B (N), C (N), dt (nheads)]
  conv1d  : causal depthwise width-4 over [x, B, C]
  SSD     : h_t = h_{t-1} * exp(dt_t A) + dt_t * B_t (x) x_t ; y_t = C_t . h_t
  gate    : y = RMSNorm(y) * silu(z) ; out_proj : d_inner -> H
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, rms_norm


class SSMState(NamedTuple):
    conv: jax.Array    # (B, conv_width-1, d_inner + 2N)
    ssd: jax.Array     # (B, nheads, head_dim, N) float32


def mamba2_schema(d_model: int, d_inner: int, n_state: int, n_heads: int,
                  conv_width: int) -> Dict:
    conv_ch = d_inner + 2 * n_state
    proj_out = 2 * d_inner + 2 * n_state + n_heads
    return {
        "w_in": ParamDef((d_model, proj_out), ("embed", "ssm_inner")),
        "conv_w": ParamDef((conv_width, conv_ch), (None, "ssm_inner"),
                           "normal", 0.1),
        "conv_b": ParamDef((conv_ch,), ("ssm_inner",), "zeros"),
        "dt_bias": ParamDef((n_heads,), ("ssm_heads",), "mamba_dt"),
        "a_log": ParamDef((n_heads,), ("ssm_heads",), "mamba_alog"),
        "d_skip": ParamDef((n_heads,), ("ssm_heads",), "ones"),
        "gate_norm": ParamDef((d_inner,), ("ssm_inner",), "ones"),
        "w_out": ParamDef((d_inner, d_model), ("ssm_inner", "embed")),
    }


def _split_proj(proj: jax.Array, d_inner: int, n_state: int, n_heads: int):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n_state]
    dt = proj[..., 2 * d_inner + 2 * n_state:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. xbc: (B,S,C), w: (K,C). init_state (B,K-1,C)
    supplies left context (zeros for a fresh prompt)."""
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]),
                               xbc.dtype)
    xp = jnp.concatenate([init_state, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (B, S, nh, hd)   dt: (B, S, nh)  (already softplus'ed, >0)
    a:  (nh,)  negative   b, c: (B, S, N)  (n_groups=1, shared over heads)
    h0: (B, nh, hd, N) initial state (float32).
    Returns y (B,S,nh,hd), h_final.
    """
    B, S, nh, hd = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        # zero-pad the tail: dt=0 => decay exp(0)=1 and no state update, so
        # padded steps are exact no-ops for the carried state.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // chunk
    xc = x.reshape(B, nc, chunk, nh, hd)
    dtc = dt.reshape(B, nc, chunk, nh)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)
    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), jnp.float32)

    def per_chunk(h, inp):
        xk, dtk, bk, ck = inp          # (B,chunk,nh,hd) (B,chunk,nh) ...
        # log-decay within chunk: l_t = sum_{u<=t} dt_u * a   (B,chunk,nh)
        da = dtk * a                    # negative
        l = jnp.cumsum(da, axis=1)
        # intra-chunk dual form: m[i,j] = exp(l_i - l_j) for j<=i
        li = l[:, :, None, :]           # (B,chunk_i,1,nh)
        lj = l[:, None, :, :]           # (B,1,chunk_j,nh)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        decay = jnp.where(mask, jnp.exp(li - lj), 0.0)      # (B,i,j,nh)
        cb = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                        bk.astype(jnp.float32))             # (B,i,j)
        m = cb[..., None] * decay                           # (B,i,j,nh)
        xdt = xk.astype(jnp.float32) * dtk[..., None]       # (B,j,nh,hd)
        y_intra = jnp.einsum("bijh,bjhd->bihd", m, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhdn,bih->bihd", ck.astype(jnp.float32),
                             h, jnp.exp(li[:, :, 0, :]))
        # state update: h' = h * exp(l_last) + sum_j exp(l_last - l_j) dt_j
        #               B_j (x) x_j
        l_last = l[:, -1:, :]                               # (B,1,nh)
        w = jnp.exp(l_last - l)                             # (B,chunk,nh)
        hb = jnp.einsum("bjn,bjhd,bjh->bhdn", bk.astype(jnp.float32),
                        xdt, w)
        h_new = h * jnp.exp(l_last[:, 0, :])[:, :, None, None] + hb
        return h_new, (y_intra + y_inter).astype(x.dtype)

    inputs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
              jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    h_final, ys = jax.lax.scan(per_chunk, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S_pad, nh, hd)
    if pad:
        y = y[:, :S]
    return y, h_final


def ssd_step(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One recurrent step. x: (B,nh,hd), dt: (B,nh), b/c: (B,N),
    h: (B,nh,hd,N) fp32."""
    da = jnp.exp(dt * a)                                    # (B,nh)
    upd = jnp.einsum("bhd,bn->bhdn", x.astype(jnp.float32) * dt[..., None],
                     b.astype(jnp.float32))
    h_new = h * da[..., None, None] + upd
    y = jnp.einsum("bhdn,bn->bhd", h_new, c.astype(jnp.float32))
    return y.astype(x.dtype), h_new


def mamba2_prefill(p: Dict, x: jax.Array, d_inner: int, n_state: int,
                   n_heads: int, head_dim: int, chunk: int = 128,
                   use_kernel: bool = False
                   ) -> Tuple[jax.Array, SSMState]:
    """Full-prompt Mamba2 block. x: (B,S,H) -> (y (B,S,H), final state)."""
    B, S, H = x.shape
    proj = x @ p["w_in"]
    z, xbc, dt = _split_proj(proj, d_inner, n_state, n_heads)
    conv_tail = xbc[:, -(p["conv_w"].shape[0] - 1):, :]      # pre-activation
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner].reshape(B, S, n_heads, head_dim)
    bmat = xbc[..., d_inner:d_inner + n_state]
    cmat = xbc[..., d_inner + n_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    if use_kernel:
        from repro.kernels import ops as kops
        y, h = kops.ssd_scan(xs, dt, a, bmat, cmat, chunk=chunk)
    else:
        y, h = ssd_chunked(xs, dt, a, bmat, cmat, chunk=chunk)
    y = y + xs * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y, p["gate_norm"]) * jax.nn.silu(z)
    out = y @ p["w_out"]
    # conv state for subsequent decode: last K-1 *pre-conv* channel values
    pad = p["conv_w"].shape[0] - 1 - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
    return out, SSMState(conv_tail, h)


def mamba2_step(p: Dict, x: jax.Array, state: SSMState, d_inner: int,
                n_state: int, n_heads: int, head_dim: int
                ) -> Tuple[jax.Array, SSMState]:
    """One-token Mamba2 step. x: (B,1,H)."""
    B = x.shape[0]
    proj = x @ p["w_in"]                                    # (B,1,P)
    z, xbc, dt = _split_proj(proj, d_inner, n_state, n_heads)
    # conv over [state ; current]
    window = jnp.concatenate([state.conv, xbc], axis=1)     # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]            # (B,1,C)
    new_conv = window[:, 1:, :]
    xs = conv_out[..., :d_inner].reshape(B, n_heads, head_dim)
    bmat = conv_out[:, 0, d_inner:d_inner + n_state]
    cmat = conv_out[:, 0, d_inner + n_state:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h_new = ssd_step(xs, dt1, a, bmat, cmat, state.ssd)
    y = y + xs * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y, p["gate_norm"]) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return out, SSMState(new_conv, h_new)
