"""Whisper-style encoder-decoder LM (audio frontend stubbed).

Per the brief, the conv/log-mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model). The transformer backbone —
bidirectional encoder, causal decoder with cross-attention, LayerNorm, GELU,
biases, absolute sinusoidal positions, tied embeddings — is real.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (ParamDef, dtype_of, init_params, make_norm,
                                 norm_schema, schema_shapes, schema_specs,
                                 sinusoidal_positions, stack_schema)
from repro.sharding.rules import Sharder


class EncDecLM:
    def __init__(self, cfg: ArchConfig, sharder: Optional[Sharder] = None,
                 use_pallas: bool = False, attn_chunk: int = 512,
                 remat: bool = True):
        assert cfg.is_encdec
        self.cfg = cfg
        self.sharder = sharder or Sharder(mesh=None)
        self.use_pallas = use_pallas
        self.attn_chunk = attn_chunk
        self.remat = remat
        self.dtype = dtype_of(cfg.dtype)
        self.norm = make_norm(cfg.norm)
        self._schema = self._build_schema()

    # -- schema ------------------------------------------------------------
    def _attn_schema(self) -> Dict:
        c = self.cfg
        return {
            "wq": ParamDef((c.d_model, c.n_heads * c.hd), ("embed", "heads")),
            "wk": ParamDef((c.d_model, c.n_kv_heads * c.hd),
                           ("embed", "kv_heads")),
            "wv": ParamDef((c.d_model, c.n_kv_heads * c.hd),
                           ("embed", "kv_heads")),
            "wo": ParamDef((c.n_heads * c.hd, c.d_model), ("heads", "embed")),
            "bq": ParamDef((c.n_heads * c.hd,), ("heads",), "zeros"),
            "bk": ParamDef((c.n_kv_heads * c.hd,), ("kv_heads",), "zeros"),
            "bv": ParamDef((c.n_kv_heads * c.hd,), ("kv_heads",), "zeros"),
            "bo": ParamDef((c.d_model,), ("embed",), "zeros"),
        }

    def _enc_layer_schema(self) -> Dict:
        c = self.cfg
        return {
            "ln1": norm_schema(c.norm, c.d_model),
            "attn": self._attn_schema(),
            "ln2": norm_schema(c.norm, c.d_model),
            "mlp": ffn_mod.ffn_schema(c.d_model, c.d_ff, c.gated_ffn,
                                      c.mlp_bias),
        }

    def _dec_layer_schema(self) -> Dict:
        c = self.cfg
        return {
            "ln1": norm_schema(c.norm, c.d_model),
            "self_attn": self._attn_schema(),
            "ln2": norm_schema(c.norm, c.d_model),
            "cross_attn": self._attn_schema(),
            "ln3": norm_schema(c.norm, c.d_model),
            "mlp": ffn_mod.ffn_schema(c.d_model, c.d_ff, c.gated_ffn,
                                      c.mlp_bias),
        }

    def _build_schema(self) -> Dict:
        c = self.cfg
        return {
            "embed": {"tok": ParamDef((c.padded_vocab, c.d_model),
                                      ("vocab", "embed"))},
            "encoder": stack_schema(self._enc_layer_schema(),
                                    c.n_encoder_layers),
            "enc_final_ln": norm_schema(c.norm, c.d_model),
            "decoder": stack_schema(self._dec_layer_schema(), c.n_layers),
            "final_norm": norm_schema(c.norm, c.d_model),
        }

    def init(self, key):
        return init_params(self._schema, key, self.dtype)

    def param_specs(self):
        return schema_specs(self._schema)

    def param_shapes(self):
        return schema_shapes(self._schema, self.dtype)

    def param_count(self) -> int:
        from repro.models.common import param_count
        return param_count(self._schema)

    # -- attention helpers ---------------------------------------------------
    def _proj_qkv(self, p, xq, xkv):
        c = self.cfg
        q = (xq @ p["wq"] + p["bq"]).reshape(
            xq.shape[0], xq.shape[1], c.n_heads, c.hd)
        k = (xkv @ p["wk"] + p["bk"]).reshape(
            xkv.shape[0], xkv.shape[1], c.n_kv_heads, c.hd)
        v = (xkv @ p["wv"] + p["bv"]).reshape(
            xkv.shape[0], xkv.shape[1], c.n_kv_heads, c.hd)
        return q, k, v

    def _attn_out(self, p, o, b, s):
        c = self.cfg
        return o.reshape(b, s, c.n_heads * c.hd) @ p["wo"] + p["bo"]

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: (B, S_enc, d_model) stubbed frontend output."""
        c = self.cfg
        x = frames.astype(self.dtype)
        x = x + sinusoidal_positions(x.shape[1], c.d_model).astype(self.dtype)
        x = self.sharder.constrain(x, "batch", "seq", None)

        def body(h, p_l):
            a = self.norm(h, p_l["ln1"])
            q, k, v = self._proj_qkv(p_l["attn"], a, a)
            o = attn.prefill_attention(q, k, v, causal=False,
                                       chunk_q=self.attn_chunk)
            h = h + self._attn_out(p_l["attn"], o, h.shape[0], h.shape[1])
            m = self.norm(h, p_l["ln2"])
            h = h + ffn_mod.ffn_apply(p_l["mlp"], m, c.act, c.gated_ffn,
                                      sharder=self.sharder)
            return h, None
        body = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return self.norm(x, params["enc_final_ln"])

    # -- decoder (full sequence) ----------------------------------------------
    def _decoder_full(self, params, tokens, enc_out, collect_kv: bool):
        c = self.cfg
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        x = x + sinusoidal_positions(x.shape[1], c.d_model).astype(self.dtype)

        def body(h, p_l):
            a = self.norm(h, p_l["ln1"])
            q, k, v = self._proj_qkv(p_l["self_attn"], a, a)
            o = attn.prefill_attention(q, k, v, causal=True,
                                       chunk_q=self.attn_chunk)
            h = h + self._attn_out(p_l["self_attn"], o, h.shape[0],
                                   h.shape[1])
            a = self.norm(h, p_l["ln2"])
            qc, kc, vc = self._proj_qkv(p_l["cross_attn"], a, enc_out)
            oc = attn.prefill_attention(qc, kc, vc, causal=False,
                                        chunk_q=self.attn_chunk)
            h = h + self._attn_out(p_l["cross_attn"], oc, h.shape[0],
                                   h.shape[1])
            m = self.norm(h, p_l["ln3"])
            h = h + ffn_mod.ffn_apply(p_l["mlp"], m, c.act, c.gated_ffn,
                                      sharder=self.sharder)
            if collect_kv:
                return h, (k, v, kc, vc)
            return h, None
        body = jax.checkpoint(body) if self.remat else body
        x, ys = jax.lax.scan(body, x, params["decoder"])
        return self.norm(x, params["final_norm"]), ys

    def logits(self, params, x):
        out = x @ params["embed"]["tok"].T
        return self.sharder.constrain(out, "batch", "seq", "vocab")

    # -- public API -------------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        c = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        x, _ = self._decoder_full(params, batch["tokens"], enc_out,
                                  collect_kv=False)
        logits = self.logits(params, x).astype(jnp.float32)
        if c.padded_vocab != c.vocab:
            pad = jnp.arange(c.padded_vocab) < c.vocab
            logits = jnp.where(pad[None, None, :], logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        mask = batch.get("mask")
        if mask is not None:
            return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(nll)

    def init_cache(self, batch: int, max_len: int, s_enc: int,
                   ring: bool = True, vector_pos: bool = False) -> Dict:
        c = self.cfg
        return {
            "pos": (jnp.zeros((batch,), jnp.int32) if vector_pos
                    else jnp.zeros((), jnp.int32)),
            "k": jnp.zeros((c.n_layers, batch, max_len, c.n_kv_heads, c.hd),
                           self.dtype),
            "v": jnp.zeros((c.n_layers, batch, max_len, c.n_kv_heads, c.hd),
                           self.dtype),
            "ck": jnp.zeros((c.n_layers, batch, s_enc, c.n_kv_heads, c.hd),
                            self.dtype),
            "cv": jnp.zeros((c.n_layers, batch, s_enc, c.n_kv_heads, c.hd),
                            self.dtype),
        }

    def cache_specs(self) -> Dict:
        kv = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        return {"pos": (), "k": kv, "v": kv, "ck": kv, "cv": kv}

    def prefill(self, params, inputs, max_len: Optional[int] = None,
                last_pos: Optional[jax.Array] = None):
        """inputs: {"embeds": (B,S_enc,H) frames, "tokens": (B,S_dec)}.
        last_pos (B,) reads logits at per-row decoder positions (batched
        right-padded prefill)."""
        tokens = inputs["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        enc_out = self.encode(params, inputs["embeds"])
        x, ys = self._decoder_full(params, tokens, enc_out, collect_kv=True)
        k, v, kc, vc = ys
        cache = self.init_cache(b, max_len, enc_out.shape[1])
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(self.dtype), 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(self.dtype), 0, axis=2)
        cache["ck"], cache["cv"] = kc, vc
        cache["pos"] = jnp.array(s, jnp.int32)
        if last_pos is None:
            last = x[:, -1:, :]
        else:
            last = x[jnp.arange(b), last_pos][:, None, :]
        logits = self.logits(params, last)[:, 0, :]
        return logits, cache

    def cross_kv(self, params, enc_out) -> Tuple[jax.Array, jax.Array]:
        """Per-layer cross-attention K/V of ``enc_out`` — the decode
        cache's ck/cv computed WITHOUT running any decoder tokens, exactly
        as ``_decoder_full`` would project them. Chunked prefill warms the
        cross cache once at group creation; the chunks then touch only
        self-attention."""
        c = self.cfg

        def body(_, p_l):
            p = p_l["cross_attn"]
            kc = (enc_out @ p["wk"] + p["bk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], c.n_kv_heads, c.hd)
            vc = (enc_out @ p["wv"] + p["bv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], c.n_kv_heads, c.hd)
            return 0, (kc, vc)
        _, (ck, cv) = jax.lax.scan(body, 0, params["decoder"])
        return ck, cv

    def prefill_chunk(self, params, cache, tokens, base,
                      last_pos: Optional[jax.Array] = None):
        """Chunked decoder prefill: ``tokens`` (B, C) sit at absolute
        decoder positions [base, base+C). The cross-attention cache
        (ck/cv) must already be resident — ``cross_kv`` at group creation
        — so each chunk runs only the self-attention/cross-read decoder
        body, mathematically identical to one full ``prefill`` over the
        concatenated chunks. Signature matches the LM chunk dispatch."""
        c = self.cfg
        b, cl = tokens.shape
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        max_pos = cache["k"].shape[2]
        pe = sinusoidal_positions(max_pos, c.d_model).astype(self.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(pe, base, cl, axis=0)[None]
        q_pos = base + jnp.broadcast_to(jnp.arange(cl)[None], (b, cl))

        def body(h, xs):
            p_l, ck, cv, cck, ccv = xs
            a = self.norm(h, p_l["ln1"])
            q, k, v = self._proj_qkv(p_l["self_attn"], a, a)
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), base, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), base, axis=1)
            if self.use_pallas:
                from repro.kernels import ops as kops
                o = kops.chunk_attention(q, ck, cv, base)
            else:
                o = attn.chunk_attention(q, ck, cv, q_pos)
            h = h + self._attn_out(p_l["self_attn"], o, b, cl)
            a = self.norm(h, p_l["ln2"])
            qc = (a @ p_l["cross_attn"]["wq"]
                  + p_l["cross_attn"]["bq"]).reshape(b, cl, c.n_heads, c.hd)
            oc = attn.sdpa(qc, cck, ccv, mask=None)
            h = h + self._attn_out(p_l["cross_attn"], oc, b, cl)
            m = self.norm(h, p_l["ln3"])
            h = h + ffn_mod.ffn_apply(p_l["mlp"], m, c.act, c.gated_ffn,
                                      sharder=self.sharder)
            return h, (ck, cv)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        x = self.norm(x, params["final_norm"])
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_new, v_new
        new_cache["pos"] = jnp.broadcast_to(
            base + cl, cache["pos"].shape).astype(jnp.int32)
        if last_pos is None:
            last = x[:, -1:, :]
        else:
            last = x[jnp.arange(b), last_pos][:, None, :]
        logits = self.logits(params, last)[:, 0, :]
        return logits, new_cache

    def decode_step(self, params, cache, tokens):
        """tokens: (B,1) int32."""
        c = self.cfg
        pos = cache["pos"]
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        max_pos = cache["k"].shape[2]
        pe = sinusoidal_positions(max_pos, c.d_model).astype(self.dtype)
        if pos.ndim == 0:
            x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None]
        else:   # per-sequence positions (continuous batching)
            x = x + jnp.take(pe, jnp.minimum(pos, max_pos - 1),
                             axis=0)[:, None]

        def body(h, xs):
            p_l, ck, cv, cck, ccv = xs
            a = self.norm(h, p_l["ln1"])
            q, k, v = self._proj_qkv(p_l["self_attn"], a, a)
            ck2, cv2, _ = attn.cache_write_token(ck, cv, k, v, pos, None)
            o = attn.decode_attention(q, ck2, cv2, pos, None)
            h = h + self._attn_out(p_l["self_attn"], o, h.shape[0], 1)
            a = self.norm(h, p_l["ln2"])
            qc = (a @ p_l["cross_attn"]["wq"]
                  + p_l["cross_attn"]["bq"]).reshape(
                      h.shape[0], 1, c.n_heads, c.hd)
            oc = attn.sdpa(qc, cck, ccv, mask=None)
            h = h + self._attn_out(p_l["cross_attn"], oc, h.shape[0], 1)
            m = self.norm(h, p_l["ln3"])
            h = h + ffn_mod.ffn_apply(p_l["mlp"], m, c.act, c.gated_ffn,
                                      sharder=self.sharder)
            return h, (ck2, cv2)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        x = self.norm(x, params["final_norm"])
        logits = self.logits(params, x)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = k_new, v_new
        new_cache["pos"] = pos + 1
        return logits, new_cache

    def sample_greedy(self, logits):
        return jnp.argmax(logits[..., :self.cfg.vocab], axis=-1)
