"""Attention: GQA prefill (full / chunked / sliding-window), decode against a
KV cache (linear or ring-buffer), and cross-attention.

The pure-jnp path here is the oracle and the dry-run lowering path; the
Pallas kernels in ``repro.kernels`` are the TPU runtime path, selected via
``use_pallas`` (validated against this code in tests with interpret=True).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,nh,d), k: (B,Sk,nkv,d) -> scores (B,nkv,g,Sq,Sk)."""
    b, sq, nh, d = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, sq, nkv, g, d)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,nkv,g,Sq,Sk), v: (B,Sk,nkv,d) -> (B,Sq,nh,d)."""
    b, nkv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, nkv * g, v.shape[-1])


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         mask: Optional[jax.Array] = None, scale: Optional[float] = None
         ) -> jax.Array:
    """Grouped-query SDPA. mask broadcastable to (B,1,1,Sq,Sk), True=keep."""
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    scores = _gqa_scores(q, k) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return _gqa_out(probs, v)


def causal_mask(sq: int, sk: int, q_offset=0,
                window: Optional[int] = None) -> jax.Array:
    """(1,1,1,Sq,Sk) boolean mask; query i (absolute q_offset+i) sees keys
    j <= q_pos and, with SWA, j > q_pos - window."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None, None]


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      chunk_q: int = 0) -> jax.Array:
    """Self-attention over a full prompt.

    chunk_q > 0 processes queries in blocks via lax.map so the (Sq, Sk) score
    matrix never materializes whole — required for the 32k prefill shapes
    (memory O(chunk * Sk) instead of O(Sk^2)).
    """
    b, sq, nh, d = q.shape
    if chunk_q <= 0 or sq <= chunk_q:
        mask = causal_mask(sq, k.shape[1], 0, window) if causal else None
        return sdpa(q, k, v, mask)
    assert sq % chunk_q == 0, (sq, chunk_q)
    n_chunks = sq // chunk_q

    def one_chunk(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * chunk_q, chunk_q, axis=1)
        mask = causal_mask(chunk_q, k.shape[1], i * chunk_q, window)
        return sdpa(qc, k, v, mask)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, nh, d)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    Linear cache: k/v (L,B,S_max,nkv,d), slot i holds position i.
    Ring cache (SWA): S_max = window; slot = pos % window; ``slot_pos``
    (L-independent, (S_max,)) tracks which absolute position a slot holds
    (-1 = empty). ``pos`` is the absolute next-token position (scalar int32).
    """
    k: jax.Array
    v: jax.Array
    pos: jax.Array                 # scalar int32
    slot_pos: Optional[jax.Array]  # (S_max,) int32 or None for linear


def init_kv_cache(n_layers: int, batch: int, s_max: int, n_kv: int, d: int,
                  dtype, window: Optional[int] = None) -> KVCache:
    s_alloc = min(s_max, window) if window else s_max
    shape = (n_layers, batch, s_alloc, n_kv, d)
    slot = (jnp.full((s_alloc,), -1, jnp.int32) if window else None)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32), slot)


def cache_write_prefill(cache_k: jax.Array, cache_v: jax.Array,
                        k: jax.Array, v: jax.Array,
                        window: Optional[int]) -> Tuple[jax.Array, jax.Array]:
    """Write a full prompt's K/V (B,S,nkv,d) into layer-slice caches
    (B,S_alloc,nkv,d), assuming pos=0 start."""
    s = k.shape[1]
    s_alloc = cache_k.shape[1]
    if window and s > s_alloc:
        k = k[:, -s_alloc:]
        v = v[:, -s_alloc:]
        # ring layout: slot = pos % window for pos in [s-window, s)
        start = s - s_alloc
        slots = (start + jnp.arange(s_alloc)) % s_alloc
        order = jnp.argsort(slots)
        k = jnp.take(k, order, axis=1)
        v = jnp.take(v, order, axis=1)
        return (cache_k.at[:, :].set(k), cache_v.at[:, :].set(v))
    return (jax.lax.dynamic_update_slice_in_dim(cache_k, k, 0, axis=1),
            jax.lax.dynamic_update_slice_in_dim(cache_v, v, 0, axis=1))


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, slot_pos: Optional[jax.Array],
                     window: Optional[int] = None) -> jax.Array:
    """One-token attention. q: (B,1,nh,d); cache_k/v: (B,S_alloc,nkv,d);
    ``pos`` is the position of the *current* token (already written).

    pos may be a scalar (uniform batch — serve_step) or a (B,) vector
    (continuous batching — each sequence at its own position). ``window``
    applies SWA masking on *linear* caches (ring caches encode the window in
    slot_pos already)."""
    s_alloc = cache_k.shape[1]
    kpos = jnp.arange(s_alloc)
    if slot_pos is None:
        if pos.ndim == 0:
            valid = (kpos <= pos)[None, :]                  # (1, S)
        else:
            valid = kpos[None, :] <= pos[:, None]           # (B, S)
        if window is not None:
            lo = pos - window
            lo = lo[..., None] if pos.ndim else lo
            valid = valid & (kpos[None, :] > lo)
    else:
        valid = ((slot_pos >= 0) & (slot_pos <= pos))[None, :]
    mask = valid[:, None, None, None, :]
    if cache_k.dtype != q.dtype:      # quantized (f8) KV cache: upcast on read
        cache_k = cache_k.astype(q.dtype)
        cache_v = cache_v.astype(q.dtype)
    return sdpa(q, cache_k, cache_v, mask)


def chunk_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                    q_pos: jax.Array, window: Optional[int] = None
                    ) -> jax.Array:
    """Multi-token attention against a linear cache (chunked prefill).

    q: (B,C,nh,d) — a chunk of C new tokens whose K/V are already written
    into the cache at their absolute positions; q_pos: (B,C) absolute
    position per query. Query i sees cache slots at positions <= q_pos[i]
    (and > q_pos[i] - window under SWA). Generalizes ``decode_attention``
    from C=1 to a whole chunk, which is what bounds head-of-line blocking
    during migration-recompute storms.
    """
    s_alloc = cache_k.shape[1]
    kpos = jnp.arange(s_alloc)
    valid = kpos[None, None, :] <= q_pos[:, :, None]        # (B, C, S)
    if window is not None:
        valid &= kpos[None, None, :] > (q_pos[:, :, None] - window)
    mask = valid[:, None, None, :, :]
    if cache_k.dtype != q.dtype:
        cache_k = cache_k.astype(q.dtype)
        cache_v = cache_v.astype(q.dtype)
    return sdpa(q, cache_k, cache_v, mask)


# ---------------------------------------------------------------------------
# Paged (block-table) KV cache — reference path
#
# The pool holds ``n_blocks`` fixed-size token blocks per layer:
# ``cache_k/v: (n_blocks, block, nkv, d)`` (a per-layer slice of the stacked
# ``(L, n_blocks, block, nkv, d)`` engine pool). ``block_tbl: (B, max_blocks)``
# maps slot-virtual position t to pool block ``block_tbl[b, t // block]`` at
# offset ``t % block``; unallocated entries point at the reserved trash block
# 0, whose contents position masking keeps invisible. These are the pure-jnp
# oracles for the Pallas gather kernel in ``repro.kernels.decode_attention``.
# ---------------------------------------------------------------------------
def _gather_pages(cache_k: jax.Array, cache_v: jax.Array,
                  block_tbl: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Materialize each row's virtual KV view: (B, max_blocks*block, nkv, d)."""
    b, mb = block_tbl.shape
    blk = cache_k.shape[1]
    pk = jnp.take(cache_k, block_tbl, axis=0)     # (B, MB, blk, nkv, d)
    pv = jnp.take(cache_v, block_tbl, axis=0)
    shape = (b, mb * blk) + cache_k.shape[2:]
    return pk.reshape(shape), pv.reshape(shape)


def _poison_probe(pk: jax.Array, pv: jax.Array, readable: jax.Array) -> None:
    """Device-side KV sanitizer probe: assert no *readable* (mask-valid)
    gathered position carries freed-block poison. The caller's dispatch
    must be ``checkify``-transformed (the engine arms this only alongside
    the sanitizer); positions hidden by masking are exempt — a reused
    block legitimately holds poison past its written prefix."""
    from jax.experimental import checkify
    from repro.serving.kv_blocks import KV_POISON
    mag = jnp.maximum(jnp.max(jnp.abs(pk.astype(jnp.float32)), axis=(-2, -1)),
                      jnp.max(jnp.abs(pv.astype(jnp.float32)), axis=(-2, -1)))
    worst = jnp.max(jnp.where(readable, mag, 0.0))
    checkify.check(worst < KV_POISON,
                   "poisoned KV block read through the block table "
                   "(max readable |kv| = {m})", m=worst)


def decode_attention_paged(q: jax.Array, cache_k: jax.Array,
                           cache_v: jax.Array, block_tbl: jax.Array,
                           pos: jax.Array, window: Optional[int] = None,
                           probe: bool = False) -> jax.Array:
    """Block-table ``decode_attention``. q: (B,1,nh,d); cache_k/v:
    (n_blocks, block, nkv, d); pos scalar or (B,), position of the current
    (already written) token."""
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (q.shape[0],))
    pk, pv = _gather_pages(cache_k, cache_v, block_tbl)
    kpos = jnp.arange(pk.shape[1])
    valid = kpos[None, :] <= pos[:, None]
    if window is not None:
        valid &= kpos[None, :] > (pos[:, None] - window)
    if probe:
        _poison_probe(pk, pv, valid)
    mask = valid[:, None, None, None, :]
    if pk.dtype != q.dtype:
        pk, pv = pk.astype(q.dtype), pv.astype(q.dtype)
    return sdpa(q, pk, pv, mask)


def chunk_attention_paged(q: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, block_tbl: jax.Array,
                          q_pos: jax.Array, window: Optional[int] = None,
                          probe: bool = False) -> jax.Array:
    """Block-table ``chunk_attention``: (B,C) queries at absolute positions
    ``q_pos`` against each row's gathered pages."""
    pk, pv = _gather_pages(cache_k, cache_v, block_tbl)
    kpos = jnp.arange(pk.shape[1])
    valid = kpos[None, None, :] <= q_pos[:, :, None]        # (B, C, S)
    if window is not None:
        valid &= kpos[None, None, :] > (q_pos[:, :, None] - window)
    if probe:
        _poison_probe(pk, pv, jnp.any(valid, axis=1))
    mask = valid[:, None, None, :, :]
    if pk.dtype != q.dtype:
        pk, pv = pk.astype(q.dtype), pv.astype(q.dtype)
    return sdpa(q, pk, pv, mask)


def cache_write_token_paged(cache_k: jax.Array, cache_v: jax.Array,
                            k: jax.Array, v: jax.Array, pos: jax.Array,
                            block_tbl: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """Write one token's K/V (B,1,nkv,d) at per-row virtual position ``pos``
    through the block table. Dead/frozen rows whose table entry is the trash
    block write garbage there (never read)."""
    blk = cache_k.shape[1]
    k = k.astype(cache_k.dtype)
    v = v.astype(cache_v.dtype)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (k.shape[0],))
    dest = jnp.take_along_axis(block_tbl, (pos // blk)[:, None],
                               axis=1)[:, 0]                 # (B,)
    off = pos % blk
    return cache_k.at[dest, off].set(k[:, 0]), cache_v.at[dest, off].set(
        v[:, 0])


def cache_write_chunk_paged(cache_k: jax.Array, cache_v: jax.Array,
                            k: jax.Array, v: jax.Array, base: jax.Array,
                            block_tbl: jax.Array,
                            lens: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Write a C-token chunk's K/V (B,C,nkv,d) at virtual positions
    [base, base+C) through the block table. ``base`` may be per-row (B,) —
    the prefix-sharing suffix path, where each row starts at its own
    shared-prefix boundary — and ``lens`` (B,) masks each row's columns
    past its real length into the trash block (pad rows/columns)."""
    blk = cache_k.shape[1]
    ar = jnp.arange(k.shape[1])                              # (C,)
    if jnp.ndim(base) == 0:
        t = base + ar                                        # (C,)
        dest = jnp.take(block_tbl, t // blk, axis=1)         # (B, C)
    else:
        t = base[:, None] + ar[None, :]                      # (B, C)
        # clamp: masked pad columns may index past the table width
        t = jnp.minimum(t, block_tbl.shape[1] * blk - 1)
        dest = jnp.take_along_axis(block_tbl, t // blk, axis=1)
    off = t % blk                                            # broadcasts
    if lens is not None:
        dest = jnp.where(ar[None, :] < lens[:, None], dest, 0)
    return (cache_k.at[dest, off].set(k.astype(cache_k.dtype)),
            cache_v.at[dest, off].set(v.astype(cache_v.dtype)))


def cache_write_token(cache_k: jax.Array, cache_v: jax.Array, k: jax.Array,
                      v: jax.Array, pos: jax.Array,
                      slot_pos: Optional[jax.Array]):
    """Write one token's K/V (B,1,nkv,d) at position ``pos``.

    Returns (cache_k, cache_v, slot_pos'). Ring caches write at pos % window
    (scalar pos only); per-sequence (B,) pos scatters row-wise into linear
    caches (continuous batching).
    """
    s_alloc = cache_k.shape[1]
    k = k.astype(cache_k.dtype)       # quantized caches: downcast on write
    v = v.astype(cache_v.dtype)
    if pos.ndim == 1:
        assert slot_pos is None, "per-slot pos requires a linear cache"
        rows = jnp.arange(cache_k.shape[0])
        slot = jnp.minimum(pos, s_alloc - 1)
        ck = cache_k.at[rows, slot].set(k[:, 0])
        cv = cache_v.at[rows, slot].set(v[:, 0])
        return ck, cv, None
    slot = pos % s_alloc if slot_pos is not None else jnp.minimum(
        pos, s_alloc - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    if slot_pos is not None:
        slot_pos = slot_pos.at[slot].set(pos)
    return ck, cv, slot_pos
