"""Dense feed-forward blocks (gated SwiGLU-style and plain MLP)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, activation


def ffn_schema(d_model: int, d_ff: int, gated: bool, bias: bool) -> Dict:
    s = {
        "w_up": ParamDef((d_model, d_ff), ("embed", "ffn")),
        "w_down": ParamDef((d_ff, d_model), ("ffn", "embed")),
    }
    if gated:
        s["w_gate"] = ParamDef((d_model, d_ff), ("embed", "ffn"))
    if bias:
        s["b_up"] = ParamDef((d_ff,), ("ffn",), "zeros")
        s["b_down"] = ParamDef((d_model,), ("embed",), "zeros")
    return s


def ffn_apply(p: Dict, x: jax.Array, act: str, gated: bool,
              sharder=None) -> jax.Array:
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    a = activation(act)
    if gated:
        h = a(x @ p["w_gate"]) * h
    else:
        h = a(h)
    if sharder is not None:
        h = sharder.constrain(h, "batch", "seq", "ffn")
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y
