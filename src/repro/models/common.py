"""Shared model building blocks: norms, RoPE / M-RoPE, embeddings, schema-
driven parameter initialization with logical sharding names.

Parameters are plain pytrees (nested dicts of jnp arrays). Each module
defines a *schema*: ``{path: ParamDef(shape, logical_names, init)}``; the
same schema yields (a) initialized arrays, (b) a same-structure tree of
logical-name tuples for ``sharding.rules.tree_shardings``, and (c)
ShapeDtypeStructs for allocation-free dry-runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    names: Tuple[Optional[str], ...]
    init: str = "normal"         # normal | zeros | ones | small_normal |
    #                              mamba_dt | mamba_alog
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.names), (self.shape, self.names)


Schema = Dict[str, "SchemaNode"]  # nested dict of ParamDef


def stack_schema(schema: Dict, n: int) -> Dict:
    """Prepend a scanned layer dimension to every ParamDef in a schema."""
    out = {}
    for k, v in schema.items():
        if isinstance(v, dict):
            out[k] = stack_schema(v, n)
        else:
            out[k] = ParamDef((n,) + v.shape, ("layers",) + v.names,
                              v.init, v.scale)
    return out


def _init_array(key, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "mamba_dt":
        # dt bias so softplus(dt_bias) spans [1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(dtype)
    if d.init == "mamba_alog":
        a = jnp.linspace(1.0, 16.0, num=int(np.prod(d.shape)) or 1)
        return jnp.log(a).reshape(d.shape).astype(dtype)
    scale = d.scale if d.init == "normal" else d.scale * 0.25
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def init_params(schema: Dict, key: jax.Array, dtype) -> Dict:
    flat = jax.tree_util.tree_leaves_with_path(
        schema, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, max(1, len(flat)))
    leaf_map = {jax.tree_util.keystr(p): k for (p, _), k in zip(flat, keys)}

    def build(path, node):
        if isinstance(node, ParamDef):
            return _init_array(leaf_map[path], node, dtype)
        return {k: build(path + f"['{k}']", v) for k, v in node.items()}

    return build("", schema)


def schema_specs(schema: Dict):
    """Logical-name tree (leaves are tuples of names)."""
    def walk(node):
        if isinstance(node, ParamDef):
            return node.names
        return {k: walk(v) for k, v in node.items()}
    return walk(schema)


def schema_shapes(schema: Dict, dtype) -> Dict:
    def walk(node):
        if isinstance(node, ParamDef):
            return jax.ShapeDtypeStruct(node.shape, dtype)
        return {k: walk(v) for k, v in node.items()}
    return walk(schema)


def param_count(schema: Dict) -> int:
    leaves = jax.tree_util.tree_leaves(
        schema, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(l.shape)) for l in leaves)


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return lambda x, p: rms_norm(x, p["w"])
    return lambda x, p: layer_norm(x, p["w"], p.get("b"))


def norm_schema(kind: str, dim: int) -> Dict:
    s = {"w": ParamDef((dim,), ("embed",), "ones")}
    if kind == "layernorm":
        s["b"] = ParamDef((dim,), ("embed",), "zeros")
    return s


def activation(kind: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[kind]


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions3: jax.Array, theta: float,
                 sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head_dim/2 frequency slots are split
    into (temporal, height, width) sections, each rotated by its own position
    stream.

    x: (B, S, n, d); positions3: (3, B, S) int — for text tokens the three
    streams are identical, recovering standard RoPE.
    """
    if theta <= 0:
        return x
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    # (3, B, S, half)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs
    chunks = []
    off = 0
    for i, sec in enumerate(sections):
        chunks.append(ang_all[i, ..., off:off + sec])
        off += sec
    ang = jnp.concatenate(chunks, axis=-1)                # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, dim: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (built lazily)."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float16": jnp.float16,
            "float32": jnp.float32}[name]
