"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

One functional model class, schema-driven params, three entry points:

  * ``loss(params, batch)``                  — training objective
  * ``prefill(params, inputs)``              — prompt -> (last logits, cache)
  * ``decode_step(params, cache, tokens)``   — one token with a KV cache

Layers are scanned (``lax.scan`` over stacked params) so the HLO stays small
for 64-80-layer configs; training remats each layer. Zamba2's hybrid trunk
scans groups of (period Mamba2 layers + one shared-attention application).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamDef, apply_m_rope, apply_rope,
                                 dtype_of, init_params, make_norm,
                                 norm_schema, schema_shapes, schema_specs,
                                 stack_schema)
from repro.sharding.rules import Sharder


class LM:
    def __init__(self, cfg: ArchConfig, sharder: Optional[Sharder] = None,
                 use_pallas: bool = False, attn_chunk: int = 512,
                 ssd_chunk: int = 128, remat: bool = True,
                 moe_capacity_factor: float = 1.25,
                 remat_policy: Optional[str] = None,
                 kv_probe: bool = False):
        self.cfg = cfg
        self.sharder = sharder or Sharder(mesh=None)
        self.use_pallas = use_pallas
        # device-side KV sanitizer probe: paged gathers checkify readable
        # |K|/|V| against KV_POISON. Requires the caller's dispatch to be
        # checkify-transformed (the engine arms it with the sanitizer).
        self.kv_probe = kv_probe
        self.attn_chunk = attn_chunk
        self.ssd_chunk = ssd_chunk
        self.remat = remat
        self.remat_policy = remat_policy
        self.moe_capacity_factor = moe_capacity_factor
        self.dtype = dtype_of(cfg.dtype)
        self.norm = make_norm(cfg.norm)
        self._schema = self._build_schema()

    # ------------------------------------------------------------------ #
    # schema / params
    # ------------------------------------------------------------------ #
    def _attn_schema(self, in_dim: Optional[int] = None) -> Dict:
        c = self.cfg
        d_in = in_dim or c.d_model
        s = {
            "wq": ParamDef((d_in, c.n_heads * c.hd), ("embed", "heads")),
            "wk": ParamDef((d_in, c.n_kv_heads * c.hd),
                           ("embed", "kv_heads")),
            "wv": ParamDef((d_in, c.n_kv_heads * c.hd),
                           ("embed", "kv_heads")),
            "wo": ParamDef((c.n_heads * c.hd, c.d_model),
                           ("heads", "embed")),
        }
        if c.qkv_bias:
            s["bq"] = ParamDef((c.n_heads * c.hd,), ("heads",), "zeros")
            s["bk"] = ParamDef((c.n_kv_heads * c.hd,), ("kv_heads",), "zeros")
            s["bv"] = ParamDef((c.n_kv_heads * c.hd,), ("kv_heads",), "zeros")
        if c.o_bias:
            s["bo"] = ParamDef((c.d_model,), ("embed",), "zeros")
        return s

    def _dense_layer_schema(self) -> Dict:
        c = self.cfg
        s = {
            "ln_attn": norm_schema(c.norm, c.d_model),
            "attn": self._attn_schema(),
            "ln_mlp": norm_schema(c.norm, c.d_model),
        }
        if c.n_experts > 0:
            s["moe"] = moe_mod.moe_schema(c.d_model, c.d_ff, c.n_experts,
                                          c.gated_ffn)
        else:
            s["mlp"] = ffn_mod.ffn_schema(c.d_model, c.d_ff, c.gated_ffn,
                                          c.mlp_bias)
        return s

    def _mamba_layer_schema(self) -> Dict:
        c = self.cfg
        return {
            "ln": norm_schema(c.norm, c.d_model),
            "mixer": ssm_mod.mamba2_schema(c.d_model, c.d_inner, c.ssm_state,
                                           c.ssm_heads, c.conv_width),
        }

    def _shared_block_schema(self) -> Dict:
        """Zamba2 shared transformer block: attention over concat(x, x0)."""
        c = self.cfg
        return {
            "ln_attn": norm_schema(c.norm, 2 * c.d_model),
            "attn": self._attn_schema(in_dim=2 * c.d_model),
            "ln_mlp": norm_schema(c.norm, c.d_model),
            "mlp": ffn_mod.ffn_schema(c.d_model, c.d_ff, c.gated_ffn,
                                      c.mlp_bias),
        }

    def _build_schema(self) -> Dict:
        c = self.cfg
        s: Dict[str, Any] = {
            "embed": {"tok": ParamDef((c.padded_vocab, c.d_model),
                                      ("vocab", "embed"))},
            "final_norm": norm_schema(c.norm, c.d_model),
        }
        if c.family == "ssm":
            s["layers"] = stack_schema(self._mamba_layer_schema(), c.n_layers)
        elif c.family == "hybrid":
            s["layers"] = stack_schema(self._mamba_layer_schema(), c.n_layers)
            s["shared"] = self._shared_block_schema()
        else:
            s["layers"] = stack_schema(self._dense_layer_schema(), c.n_layers)
        if not c.tie_embeddings:
            s["lm_head"] = ParamDef((c.d_model, c.padded_vocab),
                                    ("embed", "vocab"))
        return s

    def init(self, key: jax.Array) -> Dict:
        return init_params(self._schema, key, self.dtype)

    def param_specs(self) -> Dict:
        return schema_specs(self._schema)

    def param_shapes(self) -> Dict:
        return schema_shapes(self._schema, self.dtype)

    def param_count(self) -> int:
        from repro.models.common import param_count
        return param_count(self._schema)

    # ------------------------------------------------------------------ #
    # embedding / logits
    # ------------------------------------------------------------------ #
    def embed(self, params: Dict, inputs: Dict) -> jax.Array:
        if "embeds" in inputs:               # stubbed VLM/audio frontend
            return inputs["embeds"].astype(self.dtype)
        return jnp.take(params["embed"]["tok"], inputs["tokens"], axis=0)

    def logits(self, params: Dict, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            out = x @ params["embed"]["tok"].T
        else:
            out = x @ params["lm_head"]
        return self.sharder.constrain(out, "batch", "seq", "vocab")

    # ------------------------------------------------------------------ #
    # attention layer bodies
    # ------------------------------------------------------------------ #
    def _qkv(self, p: Dict, x: jax.Array, positions, x_kv=None):
        c = self.cfg
        sh = self.sharder
        xk = x if x_kv is None else x_kv
        q = x @ p["wq"]
        k = xk @ p["wk"]
        v = xk @ p["wv"]
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        b, s = x.shape[0], x.shape[1]
        q = q.reshape(b, s, c.n_heads, c.hd)
        k = k.reshape(b, xk.shape[1], c.n_kv_heads, c.hd)
        v = v.reshape(b, xk.shape[1], c.n_kv_heads, c.hd)
        if positions is not None:
            if c.m_rope:
                q = apply_m_rope(q, positions, c.rope_theta, c.mrope_sections)
                k = apply_m_rope(k, positions, c.rope_theta, c.mrope_sections)
            else:
                pos2 = positions[0] if positions.ndim == 3 else positions
                q = apply_rope(q, pos2, c.rope_theta)
                k = apply_rope(k, pos2, c.rope_theta)
        q = sh.constrain(q, "batch", "seq", "heads", "head_dim")
        k = sh.constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = sh.constrain(v, "batch", "seq", "kv_heads", "head_dim")
        return q, k, v

    def _attn_full(self, p: Dict, x: jax.Array, positions) -> Tuple[
            jax.Array, jax.Array, jax.Array]:
        """Full-sequence causal attention; returns (out, k, v)."""
        c = self.cfg
        q, k, v = self._qkv(p, x, positions)
        if self.use_pallas:
            from repro.kernels import ops as kops
            o = kops.flash_attention(q, k, v, causal=True,
                                     window=c.swa_window)
        else:
            o = attn.prefill_attention(q, k, v, causal=True,
                                       window=c.swa_window,
                                       chunk_q=self.attn_chunk)
        o = o.reshape(x.shape[0], x.shape[1], c.n_heads * c.hd)
        o = o @ p["wo"]
        if "bo" in p:
            o = o + p["bo"]
        return self.sharder.constrain(o, "batch", "seq", None), k, v

    def _attn_decode(self, p: Dict, x: jax.Array, pos, cache_k, cache_v,
                     slot_pos):
        """One-token attention against the cache slice of this layer."""
        c = self.cfg
        positions = self._decode_positions(pos, x.shape[0])
        q, k, v = self._qkv(p, x, positions)
        ck, cv, slot_new = attn.cache_write_token(cache_k, cache_v, k, v,
                                                  pos, slot_pos)
        # linear caches apply SWA via masking; ring caches encode it in
        # slot_pos already
        window = self.cfg.swa_window if slot_new is None else None
        if self.use_pallas:
            from repro.kernels import ops as kops
            o = kops.decode_attention(q, ck, cv, pos, slot_new, window=window)
        else:
            o = attn.decode_attention(q, ck, cv, pos, slot_new, window=window)
        o = o.reshape(x.shape[0], 1, c.n_heads * c.hd)
        o = o @ p["wo"]
        if "bo" in p:
            o = o + p["bo"]
        return o, ck, cv, slot_new

    def _attn_decode_paged(self, p: Dict, x: jax.Array, pos, cache_k,
                           cache_v, block_tbl):
        """One-token attention against this layer's block pool: write the
        token through the block table, attend over gathered pages."""
        c = self.cfg
        positions = self._decode_positions(pos, x.shape[0])
        q, k, v = self._qkv(p, x, positions)
        ck, cv = attn.cache_write_token_paged(cache_k, cache_v, k, v, pos,
                                              block_tbl)
        if self.use_pallas:
            from repro.kernels import ops as kops
            o = kops.decode_attention_paged(q, ck, cv, block_tbl, pos,
                                            window=c.swa_window,
                                            probe=self.kv_probe)
        else:
            o = attn.decode_attention_paged(q, ck, cv, block_tbl, pos,
                                            window=c.swa_window,
                                            probe=self.kv_probe)
        o = o.reshape(x.shape[0], 1, c.n_heads * c.hd)
        o = o @ p["wo"]
        if "bo" in p:
            o = o + p["bo"]
        return o, ck, cv

    def _decode_positions(self, pos, batch):
        c = self.cfg
        if pos.ndim == 0:
            p2 = jnp.broadcast_to(pos[None, None], (batch, 1))
        else:
            p2 = pos[:, None]                      # per-sequence positions
        if c.m_rope:
            return jnp.broadcast_to(p2[None], (3, batch, 1))
        return p2

    # ------------------------------------------------------------------ #
    # layer bodies (per family)
    # ------------------------------------------------------------------ #
    def _dense_layer_fwd(self, p: Dict, x: jax.Array, positions,
                         collect_kv: bool):
        c = self.cfg
        h = self.norm(x, p["ln_attn"])
        a, k, v = self._attn_full(p["attn"], h, positions)
        # names for remat policies: saving post-collective block outputs
        # keeps the forward TP all-reduces out of the rematerialized bwd
        a = jax.ad_checkpoint.checkpoint_name(a, "block_out")
        x = x + a
        h = self.norm(x, p["ln_mlp"])
        aux = jnp.zeros((), jnp.float32)
        if c.n_experts > 0:
            m, aux = moe_mod.moe_apply(
                p["moe"], h, c.moe_top_k, c.act, c.gated_ffn,
                capacity_factor=self.moe_capacity_factor,
                sharder=self.sharder)
        else:
            m = ffn_mod.ffn_apply(p["mlp"], h, c.act, c.gated_ffn,
                                  sharder=self.sharder)
        m = jax.ad_checkpoint.checkpoint_name(m, "block_out")
        x = self.sharder.constrain(x + m, "batch", "seq", None)
        if collect_kv:
            return x, (k, v, aux)
        return x, aux

    def _mlp_or_moe(self, p: Dict, h: jax.Array) -> jax.Array:
        c = self.cfg
        if c.n_experts > 0:
            m, _ = moe_mod.moe_apply(
                p["moe"], h, c.moe_top_k, c.act, c.gated_ffn,
                capacity_factor=self.moe_capacity_factor,
                sharder=self.sharder)
            return m
        return ffn_mod.ffn_apply(p["mlp"], h, c.act, c.gated_ffn,
                                 sharder=self.sharder)

    def _dense_layer_decode(self, p: Dict, x, pos, ck, cv, slot_pos):
        h = self.norm(x, p["ln_attn"])
        a, ck, cv, slot_new = self._attn_decode(p["attn"], h, pos, ck, cv,
                                                slot_pos)
        x = x + a
        h = self.norm(x, p["ln_mlp"])
        return x + self._mlp_or_moe(p, h), ck, cv, slot_new

    def _dense_layer_decode_paged(self, p: Dict, x, pos, ck, cv, block_tbl):
        h = self.norm(x, p["ln_attn"])
        a, ck, cv = self._attn_decode_paged(p["attn"], h, pos, ck, cv,
                                            block_tbl)
        x = x + a
        h = self.norm(x, p["ln_mlp"])
        return x + self._mlp_or_moe(p, h), ck, cv

    def _dense_layer_chunk(self, p: Dict, x, q_pos, ck, cv, base,
                           block_tbl=None, lens=None):
        """Chunked-prefill layer body: C new tokens against a linear cache.

        Writes the chunk's K/V at [base, base+C) and attends every query
        against the whole cache under per-query position masking — the
        C-token generalization of ``_dense_layer_decode``. With
        ``block_tbl`` the cache slice is a block pool and writes/reads go
        through the table; per-row ``base``/``lens`` (the prefix-sharing
        suffix path) route each row to its own boundary and mask pad
        columns into the trash block.
        """
        c = self.cfg
        h = self.norm(x, p["ln_attn"])
        positions = q_pos
        if c.m_rope:
            positions = jnp.broadcast_to(q_pos[None], (3,) + q_pos.shape)
        q, k, v = self._qkv(p["attn"], h, positions)
        # under use_pallas the flash chunk kernel walks the block table by
        # scalar prefetch (q_pos is base + arange by construction, so the
        # kernel takes the bases instead of the dense position grid)
        if block_tbl is not None:
            ck, cv = attn.cache_write_chunk_paged(ck, cv, k, v, base,
                                                  block_tbl, lens=lens)
            if self.use_pallas:
                from repro.kernels import ops as kops
                o = kops.chunk_attention_paged(q, ck, cv, block_tbl, base,
                                               window=c.swa_window,
                                               probe=self.kv_probe)
            else:
                o = attn.chunk_attention_paged(q, ck, cv, block_tbl, q_pos,
                                               window=c.swa_window,
                                               probe=self.kv_probe)
        else:
            assert lens is None, "column masking requires the paged path"
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), base, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), base, axis=1)
            if self.use_pallas:
                from repro.kernels import ops as kops
                o = kops.chunk_attention(q, ck, cv, base,
                                         window=c.swa_window)
            else:
                o = attn.chunk_attention(q, ck, cv, q_pos,
                                         window=c.swa_window)
        o = o.reshape(x.shape[0], x.shape[1], c.n_heads * c.hd) @ p["attn"]["wo"]
        if "bo" in p["attn"]:
            o = o + p["attn"]["bo"]
        x = x + self.sharder.constrain(o, "batch", "seq", None)
        h = self.norm(x, p["ln_mlp"])
        x = x + self._mlp_or_moe(p, h)
        return self.sharder.constrain(x, "batch", "seq", None), ck, cv

    def _mamba_layer_fwd(self, p: Dict, x: jax.Array):
        c = self.cfg
        h = self.norm(x, p["ln"])
        y, st = ssm_mod.mamba2_prefill(
            p["mixer"], h, c.d_inner, c.ssm_state, c.ssm_heads,
            c.ssm_head_dim, chunk=self.ssd_chunk, use_kernel=self.use_pallas)
        return x + y, st

    def _mamba_layer_step(self, p: Dict, x, conv, ssd):
        c = self.cfg
        h = self.norm(x, p["ln"])
        y, st = ssm_mod.mamba2_step(
            p["mixer"], h, ssm_mod.SSMState(conv, ssd), c.d_inner,
            c.ssm_state, c.ssm_heads, c.ssm_head_dim)
        return x + y, st.conv, st.ssd

    def _shared_block_fwd(self, p: Dict, x, x0, positions, collect_kv: bool):
        """Zamba2 shared block on concat(x, x0)."""
        cat = jnp.concatenate([x, x0], axis=-1)
        h = self.norm(cat, p["ln_attn"])
        a, k, v = self._attn_full(p["attn"], h, positions)
        x = x + a
        h = self.norm(x, p["ln_mlp"])
        m = ffn_mod.ffn_apply(p["mlp"], h, self.cfg.act, self.cfg.gated_ffn,
                              sharder=self.sharder)
        x = x + m
        if collect_kv:
            return x, (k, v)
        return x

    def _shared_block_decode(self, p: Dict, x, x0, pos, ck, cv):
        cat = jnp.concatenate([x, x0], axis=-1)
        h = self.norm(cat, p["ln_attn"])
        a, ck, cv, _ = self._attn_decode(p["attn"], h, pos, ck, cv, None)
        x = x + a
        h = self.norm(x, p["ln_mlp"])
        m = ffn_mod.ffn_apply(p["mlp"], h, self.cfg.act, self.cfg.gated_ffn,
                              sharder=self.sharder)
        return x + m, ck, cv

    # ------------------------------------------------------------------ #
    # trunk runners
    # ------------------------------------------------------------------ #
    def _remat(self, body):
        if not self.remat:
            return body
        if self.remat_policy == "save_block_out":
            pol = jax.checkpoint_policies.save_only_these_names("block_out")
            return jax.checkpoint(body, policy=pol)
        return jax.checkpoint(body)

    def _run_trunk_full(self, params: Dict, x: jax.Array, positions,
                        collect_kv: bool):
        """Full-sequence pass over all layers (train / prefill).

        Returns (x, per-layer aux dict). For dense: aux has k/v stacks when
        collect_kv; for ssm/hybrid: conv/ssd state stacks (+ shared kv).
        """
        c = self.cfg
        fam = c.family
        if fam in ("ssm",):
            def body(h, p_l):
                h, st = self._mamba_layer_fwd(p_l, h)
                return h, st
            body = self._remat(body)
            x, states = jax.lax.scan(body, x, params["layers"])
            return x, {"conv": states.conv, "ssd": states.ssd}
        if fam == "hybrid":
            return self._run_hybrid_full(params, x, positions, collect_kv)

        def body(h, p_l):
            out = self._dense_layer_fwd(p_l, h, positions, collect_kv)
            return out
        body = self._remat(body)
        x, ys = jax.lax.scan(body, x, params["layers"])
        if collect_kv:
            k, v, aux = ys
            return x, {"k": k, "v": v, "aux": jnp.sum(aux)}
        return x, {"aux": jnp.sum(ys)}

    def _run_hybrid_full(self, params: Dict, x: jax.Array, positions,
                         collect_kv: bool):
        c = self.cfg
        period = c.hybrid_period
        n_groups = c.n_layers // period
        assert n_groups * period == c.n_layers, (c.n_layers, period)
        trunk = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])
        x0 = x

        def group_body(h, p_group):
            def inner(hh, p_l):
                hh, st = self._mamba_layer_fwd(p_l, hh)
                return hh, st
            h, states = jax.lax.scan(inner, h, p_group)
            out = self._shared_block_fwd(params["shared"], h, x0, positions,
                                         collect_kv)
            if collect_kv:
                h, (k, v) = out
                return h, (states, k, v)
            return out, (states,)
        group_body = self._remat(group_body)
        x, ys = jax.lax.scan(group_body, x, trunk)
        states = ys[0]
        conv = states.conv.reshape((c.n_layers,) + states.conv.shape[2:])
        ssd = states.ssd.reshape((c.n_layers,) + states.ssd.shape[2:])
        aux = {"conv": conv, "ssd": ssd}
        if collect_kv:
            aux["ak"], aux["av"] = ys[1], ys[2]
        return x, aux

    # ------------------------------------------------------------------ #
    # public: loss / prefill / decode
    # ------------------------------------------------------------------ #
    def loss(self, params: Dict, batch: Dict) -> jax.Array:
        """Causal-LM cross entropy (mean over mask), + MoE aux loss."""
        c = self.cfg
        x = self.embed(params, batch)
        x = self.sharder.constrain(x, "batch", "seq", None)
        positions = batch.get("positions")
        if positions is None:
            b, s = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            if c.m_rope:
                positions = jnp.broadcast_to(positions[None], (3, b, s))
        x, aux = self._run_trunk_full(params, x, positions, collect_kv=False)
        x = self.norm(x, params["final_norm"])
        logits = self.logits(params, x).astype(jnp.float32)
        # mask padded vocab columns
        if c.padded_vocab != c.vocab:
            pad_mask = jnp.arange(c.padded_vocab) < c.vocab
            logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        targets = batch["targets"]
        mask = batch.get("mask")
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        if mask is not None:
            nll = nll * mask
            denom = jnp.maximum(jnp.sum(mask), 1.0)
        else:
            denom = nll.size
        loss = jnp.sum(nll) / denom
        aux_w = 0.01 if c.n_experts > 0 else 0.0
        moe_aux = aux.get("aux", jnp.zeros((), jnp.float32))
        return loss + aux_w * moe_aux / max(1, c.n_layers)

    def init_cache(self, batch: int, max_len: int, ring: bool = True,
                   vector_pos: bool = False, kv_layout: str = "contig",
                   n_blocks: int = 0, block_size: int = 16) -> Dict:
        """Zero cache (also mirrors the dry-run ShapeDtypeStruct layout).

        ring=False allocates SWA archs a full-length linear cache (window
        masking instead of ring slots) — required for continuous batching
        with per-sequence positions (vector_pos).

        kv_layout="paged" allocates the KV as a pool of ``n_blocks``
        ``block_size``-token blocks (L, n_blocks, block, nkv, d) shared by
        all rows, plus a per-row ``block_tbl`` (batch, ceil(max_len/block))
        mapping virtual positions to pool blocks (entry 0 = reserved trash
        block). Attention families only; SWA applies via window masking on
        virtual positions (no ring)."""
        c = self.cfg
        pos0 = (jnp.zeros((batch,), jnp.int32) if vector_pos
                else jnp.zeros((), jnp.int32))
        cache: Dict[str, Any] = {"pos": pos0}
        if kv_layout == "paged":
            if c.family in ("ssm", "hybrid"):
                raise ValueError("paged KV requires attention caches")
            max_blocks = -(-max_len // block_size)
            if n_blocks <= 0:
                n_blocks = batch * max_blocks + 1       # capacity == contig
            cache["k"] = jnp.zeros(
                (c.n_layers, n_blocks, block_size, c.n_kv_heads, c.hd),
                self.dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
            cache["block_tbl"] = jnp.zeros((batch, max_blocks), jnp.int32)
            return cache
        if c.family in ("ssm", "hybrid"):
            conv_ch = c.d_inner + 2 * c.ssm_state
            cache["conv"] = jnp.zeros(
                (c.n_layers, batch, c.conv_width - 1, conv_ch), self.dtype)
            cache["ssd"] = jnp.zeros(
                (c.n_layers, batch, c.ssm_heads, c.ssm_head_dim,
                 c.ssm_state), jnp.float32)
            if c.family == "hybrid":
                n_apps = len(c.shared_attn_positions())
                cache["ak"] = jnp.zeros(
                    (n_apps, batch, max_len, c.n_kv_heads, c.hd), self.dtype)
                cache["av"] = jnp.zeros_like(cache["ak"])
        else:
            s_alloc = (min(max_len, c.swa_window)
                       if (c.swa_window and ring) else max_len)
            cache["k"] = jnp.zeros(
                (c.n_layers, batch, s_alloc, c.n_kv_heads, c.hd), self.dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
            if c.swa_window and ring:
                cache["slot_pos"] = jnp.full((s_alloc,), -1, jnp.int32)
        return cache

    def cache_specs(self) -> Dict:
        """Logical sharding names for cache entries (same tree structure)."""
        c = self.cfg
        specs: Dict[str, Any] = {"pos": ()}
        if c.family in ("ssm", "hybrid"):
            specs["conv"] = ("layers", "batch", None, "ssm_inner")
            specs["ssd"] = ("layers", "batch", "ssm_heads", None, None)
            if c.family == "hybrid":
                specs["ak"] = ("stack", "batch", "cache_seq",
                               "kv_heads", "head_dim")
                specs["av"] = specs["ak"]
        else:
            specs["k"] = ("layers", "batch", "cache_seq", "kv_heads",
                          "head_dim")
            specs["v"] = specs["k"]
            if c.swa_window:
                specs["slot_pos"] = (None,)
        return specs

    def prefill(self, params: Dict, inputs: Dict,
                max_len: Optional[int] = None, ring: bool = True,
                last_pos: Optional[jax.Array] = None,
                cache: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
        """Prompt -> (last-position logits (B, Vpad), filled cache).

        The returned cache is allocated at ``max_len`` (>= prompt length).
        ring=False gives SWA archs a linear full-length cache (engine mode).
        last_pos (B,) reads logits at a per-row position instead of the
        final one — the right-padded batched-prefill case, where row i's
        real prompt ends at last_pos[i] (causality keeps pad columns from
        leaking into real rows). A *paged* ``cache`` (from
        ``init_cache(kv_layout="paged")`` with allocated block tables)
        receives the prompt K/V through its block tables instead of a fresh
        contiguous allocation.
        """
        c = self.cfg
        x = self.embed(params, inputs)
        b, s = x.shape[0], x.shape[1]
        max_len = max_len or s
        positions = inputs.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            if c.m_rope:
                positions = jnp.broadcast_to(positions[None], (3, b, s))
        x, aux = self._run_trunk_full(params, x, positions, collect_kv=True)
        x = self.norm(x, params["final_norm"])
        if last_pos is None:
            last = x[:, -1:, :]
        else:
            last = x[jnp.arange(b), last_pos][:, None, :]
        logits = self.logits(params, last)[:, 0, :]
        if cache is not None and "block_tbl" in cache:
            return logits, self._write_prefill_paged(cache, aux, s)
        cache = self.init_cache(b, max_len, ring=ring)
        cache["pos"] = jnp.array(s, jnp.int32)
        window = c.swa_window if ring else None
        if c.family in ("ssm", "hybrid"):
            cache["conv"] = aux["conv"].astype(self.dtype)
            cache["ssd"] = aux["ssd"]
            if c.family == "hybrid":
                cache["ak"], _ = _write_prefill_stacked(
                    cache["ak"], aux["ak"], None)
                cache["av"], _ = _write_prefill_stacked(
                    cache["av"], aux["av"], None)
        else:
            slot = cache.get("slot_pos")
            cache["k"], slot_new = _write_prefill_stacked(
                cache["k"], aux["k"], window, s)
            cache["v"], _ = _write_prefill_stacked(
                cache["v"], aux["v"], window, s)
            if slot is not None:
                cache["slot_pos"] = slot_new
        return logits, cache

    def _write_prefill_paged(self, cache: Dict, aux: Dict, s: int) -> Dict:
        """Scatter stacked prefill K/V (L,B,S,nkv,d) into the block pool
        through each row's block table."""
        tbl = cache["block_tbl"]
        blk = cache["k"].shape[2]
        t = jnp.arange(s)
        dest = jnp.take(tbl, t // blk, axis=1)               # (B, S)
        off = t % blk                                        # broadcasts
        out = dict(cache)
        out["k"] = cache["k"].at[:, dest, off].set(
            aux["k"].astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, dest, off].set(
            aux["v"].astype(cache["v"].dtype))
        out["pos"] = jnp.broadcast_to(
            jnp.array(s, jnp.int32), cache["pos"].shape)
        return out

    def prefill_chunk(self, params: Dict, cache: Dict, tokens: jax.Array,
                      base: jax.Array,
                      last_pos: Optional[jax.Array] = None,
                      block_tbl: Optional[jax.Array] = None,
                      lens: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Dict]:
        """Incremental prefill: extend a cache with a C-token chunk
        starting at absolute position ``base``.

        tokens: (B, C) int32; base: scalar int32. Chunk K/V land at cache
        positions [base, base+C); queries attend the whole prefix under
        per-position masks, so running this over consecutive chunks is
        mathematically identical to one full prefill — that is what lets
        migration recompute interleave with live decode without a
        head-of-line stall. Attention families only (SSM state would need
        carried recurrence).

        Two destinations: a private cache (linear, or paged through the
        cache's own ``block_tbl``), or — when ``block_tbl`` is passed —
        the ENGINE's pool, with each of the B rows routed through its own
        table row so chunks land directly in the owning slot's blocks (no
        transient cache, no terminal scatter). In that engine-direct mode
        ``lens`` masks each row's columns >= lens into the trash block
        (rows that finished mid-group stop writing) and the per-SLOT
        ``pos`` update is the caller's, like ``prefill_suffix``.
        Returns (logits at ``last_pos`` (default: last chunk column),
        updated cache).
        """
        c = self.cfg
        assert c.family not in ("ssm", "hybrid"), \
            "chunked prefill requires attention caches"
        assert "slot_pos" not in cache, "chunked prefill needs a linear cache"
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        b, cl = tokens.shape
        q_pos = base + jnp.broadcast_to(jnp.arange(cl)[None], (b, cl))
        direct = block_tbl is not None
        tbl = block_tbl if direct else cache.get("block_tbl")

        def body(h, xs):
            p_l, ck, cv = xs
            h, ck, cv = self._dense_layer_chunk(p_l, h, q_pos, ck, cv, base,
                                                block_tbl=tbl, lens=lens)
            return h, (ck, cv)
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ck, cv
        if not direct:
            new_cache["pos"] = jnp.broadcast_to(base + cl, cache["pos"].shape
                                                ).astype(jnp.int32)
        x = self.norm(x, params["final_norm"])
        if last_pos is None:
            last = x[:, -1:, :]
        else:
            last = x[jnp.arange(b), last_pos][:, None, :]
        logits = self.logits(params, last)[:, 0, :]
        return logits, new_cache

    def prefill_suffix(self, params: Dict, cache: Dict, tokens: jax.Array,
                       bases: jax.Array, block_tbl: jax.Array,
                       lens: jax.Array) -> Tuple[jax.Array, Dict]:
        """Prefix-sharing suffix prefill: each row's first ``bases[i]``
        tokens are already RESIDENT in the paged pool (shared-prefix blocks
        mapped through ``block_tbl``), so only the divergent suffix is
        computed — rows' queries sit at absolute positions
        [bases, bases+lens) and attend the shared prefix through the block
        table; suffix K/V writes land from each row's own boundary, with
        columns past ``lens`` routed to the trash block (pad rows repeat
        row 0, so duplicate writes agree). Because prefix activations are
        causally independent of the suffix, this reproduces a full
        prefill's K/V and logits exactly. Attention families with a paged
        cache only. Returns (logits at each row's last real suffix token,
        cache with k/v updated — the caller owns the ``pos`` update, which
        is per-SLOT, not per-row).
        """
        c = self.cfg
        assert c.family not in ("ssm", "hybrid") and not c.is_encdec, \
            "suffix prefill requires attention-family KV caches"
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        b, cl = tokens.shape
        q_pos = bases[:, None] + jnp.arange(cl)[None, :]

        def body(h, xs):
            p_l, ck, cv = xs
            h, ck, cv = self._dense_layer_chunk(p_l, h, q_pos, ck, cv,
                                                bases, block_tbl=block_tbl,
                                                lens=lens)
            return h, (ck, cv)
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ck, cv
        x = self.norm(x, params["final_norm"])
        last = x[jnp.arange(b), lens - 1][:, None, :]
        logits = self.logits(params, last)[:, 0, :]
        return logits, new_cache

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """One new token for every sequence. tokens: (B, 1) int32 (or
        embeds (B, 1, H) under a stubbed frontend)."""
        c = self.cfg
        if tokens.ndim == 3:
            x = tokens.astype(self.dtype)
        else:
            x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        pos = cache["pos"]
        new_cache = dict(cache)
        if c.family == "ssm":
            def body(h, xs):
                p_l, conv, ssd = xs
                h, conv, ssd = self._mamba_layer_step(p_l, h, conv, ssd)
                return h, (conv, ssd)
            x, (conv, ssd) = jax.lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssd"]))
            new_cache["conv"], new_cache["ssd"] = conv, ssd
        elif c.family == "hybrid":
            x, new_cache = self._decode_hybrid(params, x, cache, new_cache,
                                               pos)
        elif "block_tbl" in cache:
            tbl = cache["block_tbl"]

            def body(h, xs):
                p_l, ck, cv = xs
                h, ck, cv = self._dense_layer_decode_paged(
                    p_l, h, pos, ck, cv, tbl)
                return h, (ck, cv)
            x, (ck, cv) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = ck, cv
        else:
            slot = cache.get("slot_pos")

            def body(h, xs):
                p_l, ck, cv = xs
                h, ck, cv, slot_new = self._dense_layer_decode(
                    p_l, h, pos, ck, cv, slot)
                return h, (ck, cv, slot_new)
            x, (ck, cv, slots) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = ck, cv
            if slot is not None:
                new_cache["slot_pos"] = slots[0]
        x = self.norm(x, params["final_norm"])
        logits = self.logits(params, x)
        new_cache["pos"] = pos + 1
        return logits, new_cache

    def _decode_hybrid(self, params, x, cache, new_cache, pos):
        c = self.cfg
        period = c.hybrid_period
        n_groups = c.n_layers // period
        trunk = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])
        conv = cache["conv"].reshape((n_groups, period)
                                     + cache["conv"].shape[1:])
        ssd = cache["ssd"].reshape((n_groups, period) + cache["ssd"].shape[1:])
        x0 = x

        def group_body(h, xs):
            p_group, conv_g, ssd_g, ak, av = xs

            def inner(hh, ys):
                p_l, cv_l, sd_l = ys
                hh, cv_l, sd_l = self._mamba_layer_step(p_l, hh, cv_l, sd_l)
                return hh, (cv_l, sd_l)
            h, (conv_g, ssd_g) = jax.lax.scan(inner, h,
                                              (p_group, conv_g, ssd_g))
            h, ak, av = self._shared_block_decode(params["shared"], h, x0,
                                                  pos, ak, av)
            return h, (conv_g, ssd_g, ak, av)

        x, (conv2, ssd2, ak, av) = jax.lax.scan(
            group_body, x, (trunk, conv, ssd, cache["ak"], cache["av"]))
        new_cache["conv"] = conv2.reshape(cache["conv"].shape)
        new_cache["ssd"] = ssd2.reshape(cache["ssd"].shape)
        new_cache["ak"], new_cache["av"] = ak, av
        return x, new_cache

    def sample_greedy(self, logits: jax.Array) -> jax.Array:
        """Greedy next token over the un-padded vocab."""
        return jnp.argmax(logits[..., :self.cfg.vocab], axis=-1)


def _write_prefill_stacked(cache, kv, window, s: Optional[int] = None):
    """Write stacked per-layer prefill K/V (L,B,S,nkv,d) into cache
    (L,B,S_alloc,nkv,d); returns (cache, slot_pos or None)."""
    s_alloc = cache.shape[2]
    s_in = kv.shape[2]
    if window and s_in > s_alloc:
        start = s_in - s_alloc
        kv = kv[:, :, -s_alloc:]
        slots = (start + jnp.arange(s_alloc)) % s_alloc
        order = jnp.argsort(slots)
        kv = jnp.take(kv, order, axis=2)
        # after reorder, ring slot j holds absolute position start + order[j]
        slot_pos = (start + order).astype(jnp.int32)
        return cache.at[:, :, :].set(kv.astype(cache.dtype)), slot_pos
    out = jax.lax.dynamic_update_slice_in_dim(
        cache, kv.astype(cache.dtype), 0, axis=2)
    if window:
        slot_pos = jnp.where(jnp.arange(s_alloc) < s_in,
                             jnp.arange(s_alloc), -1)
        return out, slot_pos
    return out, None
