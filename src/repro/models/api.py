"""Model construction + input specs for every (arch x shape) cell.

``build_model(cfg)`` returns the executable model; ``input_specs`` returns
weak-type-correct ShapeDtypeStruct stand-ins for every model input of a
given step (no device allocation — the dry-run pattern), and ``make_batch``
returns small *concrete* random inputs for tests.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models.common import dtype_of
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM
from repro.sharding.rules import Sharder

Model = Union[LM, EncDecLM]


def build_model(cfg: ArchConfig, sharder: Optional[Sharder] = None,
                **kw) -> Model:
    if cfg.is_encdec:
        kw.pop("ssd_chunk", None)
        kw.pop("moe_capacity_factor", None)
        return EncDecLM(cfg, sharder=sharder, **kw)
    return LM(cfg, sharder=sharder, **kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _frontend_embeds(cfg: ArchConfig) -> bool:
    return cfg.frontend in ("vision_embeds",)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for the given step.

    train_step  -> {"tokens"/"embeds", "targets", "mask"} (+ "tokens" for
                   enc-dec; "positions" for M-RoPE)
    prefill_step-> prompt inputs
    serve_step  -> {"cache": <cache tree>, "tokens": (B,1)}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.dtype)
    i32 = jnp.int32
    if shape.step == "train_step":
        batch: Dict[str, Any] = {}
        if cfg.is_encdec:
            batch["embeds"] = _sds((b, s, cfg.d_model), dt)
            batch["tokens"] = _sds((b, s), i32)
        elif _frontend_embeds(cfg):
            batch["embeds"] = _sds((b, s, cfg.d_model), dt)
        else:
            batch["tokens"] = _sds((b, s), i32)
        if cfg.m_rope:
            batch["positions"] = _sds((3, b, s), i32)
        batch["targets"] = _sds((b, s), i32)
        batch["mask"] = _sds((b, s), jnp.float32)
        return batch
    if shape.step == "prefill_step":
        inputs: Dict[str, Any] = {}
        if cfg.is_encdec:
            inputs["embeds"] = _sds((b, s, cfg.d_model), dt)
            inputs["tokens"] = _sds((b, s), i32)
        elif _frontend_embeds(cfg):
            inputs["embeds"] = _sds((b, s, cfg.d_model), dt)
            if cfg.m_rope:
                inputs["positions"] = _sds((3, b, s), i32)
        else:
            inputs["tokens"] = _sds((b, s), i32)
        return inputs
    # serve_step: KV cache of seq_len + one new token. eval_shape keeps the
    # cache abstract — concretizing it here would allocate terabytes.
    model = build_model(cfg)
    if cfg.is_encdec:
        cache_sds = jax.eval_shape(lambda: model.init_cache(b, s, s_enc=s))
    else:
        cache_sds = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_sds = jax.tree.map(lambda a: _sds(a.shape, a.dtype), cache_sds)
    return {"cache": cache_sds, "tokens": _sds((b, 1), i32)}


def make_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0
               ) -> Dict[str, Any]:
    """Small concrete random batch matching ``input_specs`` (tests only)."""
    rng = np.random.RandomState(seed)
    specs = input_specs(cfg, shape)

    def concretize(sds):
        if jnp.issubdtype(sds.dtype, jnp.integer):
            hi = cfg.vocab if sds.shape[-1:] != (1,) else cfg.vocab
            return jnp.asarray(
                rng.randint(0, min(hi, cfg.vocab), size=sds.shape), sds.dtype)
        return jnp.asarray(rng.randn(*sds.shape), jnp.float32).astype(
            sds.dtype)

    out = jax.tree.map(concretize, specs,
                       is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if shape.step == "serve_step":
        # a serve cache "of seq_len": position points at the final slot
        out["cache"]["pos"] = jnp.array(shape.seq_len - 1, jnp.int32)
    if shape.step == "train_step" and "mask" in out:
        out["mask"] = jnp.ones_like(out["mask"])
    if "positions" in out and shape.step != "serve_step":
        b, s = shape.global_batch, shape.seq_len
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        out["positions"] = jnp.broadcast_to(pos[None], (3, b, s)).astype(
            jnp.int32)
    return out
