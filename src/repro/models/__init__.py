from repro.models.api import build_model, input_specs, make_batch
from repro.models.transformer import LM
from repro.models.encdec import EncDecLM

__all__ = ["build_model", "input_specs", "make_batch", "LM", "EncDecLM"]
