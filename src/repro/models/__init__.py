from repro.models.api import build_model, input_specs, make_batch
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM

__all__ = ["build_model", "input_specs", "make_batch", "LM", "EncDecLM"]
