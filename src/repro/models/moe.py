"""Top-k mixture-of-experts FFN with sort-free capacity dispatch.

Dispatch is scatter/gather based (not one-hot einsum) so compiled FLOPs
reflect *active* expert compute — tokens*top_k*H*F — matching the MoE rows
we added to the paper's Table 2 (see core/roofline.py). A dense-all-experts
fallback would make every MoE roofline look compute-bound and useless.

Layout: tokens are flattened to (T, H); each (token, k) pair gets a slot in
its expert's capacity buffer (E, C, H); overflow tokens are dropped (their
gate weight contributes nothing — standard Switch/Mixtral-style capacity
semantics with capacity_factor headroom).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, activation


def moe_schema(d_model: int, d_ff: int, n_experts: int, gated: bool) -> Dict:
    s = {
        "router": ParamDef((d_model, n_experts), ("embed", None)),
        "w_up": ParamDef((n_experts, d_model, d_ff),
                         ("experts", "embed", "expert_ffn")),
        "w_down": ParamDef((n_experts, d_ff, d_model),
                           ("experts", "expert_ffn", "embed")),
    }
    if gated:
        s["w_gate"] = ParamDef((n_experts, d_model, d_ff),
                               ("experts", "embed", "expert_ffn"))
    return s


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(n_tokens * top_k / n_experts * capacity_factor)
    return max(4, min(n_tokens, c))


def moe_apply(p: Dict, x: jax.Array, top_k: int, act: str, gated: bool,
              capacity_factor: float = 1.25, sharder=None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, H) -> (B, S, H), aux_loss (load-balancing, Switch-style)."""
    b, s, h = x.shape
    e = p["router"].shape[-1]
    t = b * s
    xt = x.reshape(t, h)
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    cap = _capacity(t, e, top_k, capacity_factor)
    # position of each (token,k) within its expert queue, in (T*k) flat order
    flat_expert = expert_idx.reshape(-1)                     # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)    # exclusive
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[:, None],
                              axis=1)[:, 0]                  # (T*k,)
    keep = pos < cap
    slot = flat_expert * cap + jnp.where(keep, pos, 0)       # (T*k,)

    token_idx = jnp.repeat(jnp.arange(t), top_k)             # (T*k,)
    gathered = jnp.take(xt, token_idx, axis=0)               # (T*k, H)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    buf = jnp.zeros((e * cap, h), xt.dtype).at[slot].add(gathered)
    buf = buf.reshape(e, cap, h)
    if sharder is not None:
        buf = sharder.constrain(buf, "experts", "moe_cap", "embed")

    # expert compute: (E, C, H) x (E, H, F)
    hmid = jnp.einsum("ech,ehf->ecf", buf, p["w_up"])
    a = activation(act)
    if gated:
        hmid = a(jnp.einsum("ech,ehf->ecf", buf, p["w_gate"])) * hmid
    else:
        hmid = a(hmid)
    if sharder is not None:
        hmid = sharder.constrain(hmid, "experts", "moe_cap",
                                 "expert_ffn")
    out_buf = jnp.einsum("ecf,efh->ech", hmid, p["w_down"]).reshape(
        e * cap, h)

    # combine: gather each (token,k) slot's output, weight by gate, sum k
    per_pair = jnp.take(out_buf, slot, axis=0)               # (T*k, H)
    per_pair = per_pair * (gate_vals.reshape(-1)[:, None]
                           * keep[:, None]).astype(per_pair.dtype)
    out = jnp.sum(per_pair.reshape(t, top_k, h), axis=1)

    # Switch-style load balancing aux loss
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, h), aux
