"""Cost-vs-SLO frontier sweep over the discrete-event cluster simulator.

The paper evaluates fault tolerance at fixed settings (Fig 16); the
operational question is the *frontier*: for a given workload and spot
market, which (spot mix, grace period, recovery policy) settings are
Pareto-optimal in ($/Mtok, p99 latency) space?  This driver sweeps that
grid through ``ClusterSim`` — each cell one deterministic simulation over
the same request trace and interruption events — and reports the points
plus the Pareto front, validating ROADMAP items 2–3 (SLO tiers, kernel
speedups) against cluster economics before they touch real hardware.

Axes:
- spot_frac: fraction of pipelines on spot capacity (the rest run
  on-demand: immune to reclaims, billed at the OD rate).
- grace_s: reclaim notice window (clouds differ: 30s–600s).
- policy: recovery mechanism policy ('recompute' | 'transfer' | 'hybrid',
  see cluster/recovery.py).

Usage:
    pts = sweep_frontier(spec, placements, requests, duration_s, events)
    front = pareto_front(pts)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.network import Topology
from repro.cluster.simulator import ClusterSim, FTConfig, SimResult
from repro.cluster.workload import Request
from repro.core.estimator import Placement
from repro.core.modelspec import ModelSpec


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    spot_frac: float
    grace_s: float
    policy: str
    cost_usd: float
    cost_per_mtok: float          # $ per million generated tokens
    p99_ttft_s: float
    p99_tpot_s: float
    rps: float
    downtime_s: float
    interruptions: int

    def dominates(self, other: "FrontierPoint") -> bool:
        """Pareto dominance on (cost_per_mtok, p99_ttft_s, p99_tpot_s):
        no worse on all, strictly better on one."""
        a = (self.cost_per_mtok, self.p99_ttft_s, self.p99_tpot_s)
        b = (other.cost_per_mtok, other.p99_ttft_s, other.p99_tpot_s)
        return all(x <= y for x, y in zip(a, b)) and a != b


def _point(res: SimResult, spot_frac: float, grace_s: float,
           policy: str) -> FrontierPoint:
    out_tokens = sum(r.generated for r in res.completed)
    mtok = max(out_tokens, 1) / 1e6
    return FrontierPoint(
        spot_frac=spot_frac, grace_s=grace_s, policy=policy,
        cost_usd=res.cost_usd, cost_per_mtok=res.cost_usd / mtok,
        p99_ttft_s=res.percentile("ttft", 0.99),
        p99_tpot_s=res.percentile("tpot", 0.99),
        rps=res.rps, downtime_s=res.total_downtime_s,
        interruptions=res.interruptions)


def sweep_frontier(spec: ModelSpec, pipelines: Sequence[Placement],
                   requests: Sequence[Request], duration_s: float,
                   events: Sequence[Tuple[float, str, int]] = (),
                   spot_fracs: Sequence[float] = (0.0, 0.5, 1.0),
                   graces: Sequence[float] = (30.0, 120.0),
                   policies: Sequence[str] = ("recompute", "hybrid"),
                   ft_base: Optional[FTConfig] = None,
                   network_factory: Optional[Callable[[], Topology]] = None,
                   regions: Optional[Sequence[str]] = None,
                   mean_s_in: int = 763, mean_s_out: int = 232,
                   efficiency: float = 1.0,
                   on_point: Optional[Callable[[FrontierPoint], None]] = None
                   ) -> List[FrontierPoint]:
    """One deterministic ``ClusterSim`` run per grid cell, all over the
    SAME trace/events, so differences are attributable to the knobs.
    The spot mix converts the first ``(1-frac)*N`` pipelines to
    on-demand (deterministic split — pipelines are interchangeable under
    the weighted-RR dispatcher). ``network_factory`` builds a FRESH
    topology per cell (links are stateful); None runs closed-form."""
    ft_base = ft_base or FTConfig()
    n = len(pipelines)
    points: List[FrontierPoint] = []
    for frac in spot_fracs:
        n_spot = int(round(frac * n))
        spot_mask = [i >= n - n_spot for i in range(n)]
        for grace in graces:
            for policy in policies:
                ft = dataclasses.replace(
                    ft_base, grace_period_s=grace, recovery_policy=policy,
                    kv_store_migration=(ft_base.kv_store_migration
                                        and policy != "recompute"))
                net = network_factory() if network_factory else None
                sim = ClusterSim(spec, pipelines, ft,
                                 mean_s_in=mean_s_in, mean_s_out=mean_s_out,
                                 efficiency=efficiency, network=net,
                                 regions=regions, spot=spot_mask)
                res = sim.run(requests, duration_s, events=events)
                pt = _point(res, frac, grace, policy)
                points.append(pt)
                if on_point is not None:
                    on_point(pt)
    return points


def pareto_front(points: Sequence[FrontierPoint]) -> List[FrontierPoint]:
    """Non-dominated subset, sorted by cost."""
    front = [p for p in points
             if not any(q.dominates(p) for q in points if q is not p)]
    return sorted(front, key=lambda p: (p.cost_per_mtok, p.p99_ttft_s))
