"""Spot availability traces + scenario selection (paper §2.2 / §7.2).

Availability per instance pool is a Markov on/off birth-death process whose
stationary availability and volatility are calibrated to the paper's
observations: high-end pools (p5/p6-class) rarely available (H100 28.64% of
the time, B200 never), mid-tier pools (g5/g6/g6e) more stable with
complementary patterns.

Scenario extraction follows §7.2: every candidate window gets a composite
score = (number of availability-change events) x (magnitude of affected
instances); the highest-scoring window is the worst-case evaluation
scenario. ~40% of windows score zero (no changes) in the paper — the
calibrated generator reproduces that regime.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PoolModel:
    """Markov model of one spot pool's available capacity."""
    name: str
    capacity: int                # instances the pool can offer when healthy
    p_loss_per_min: float        # chance an available instance is reclaimed
    p_gain_per_min: float        # chance an unavailable slot comes back
    correlated: float = 0.3      # prob. a loss event takes out many at once


PAPER_POOLS: Dict[str, PoolModel] = {
    # mid-tier: relatively stable, complementary. Loss rates calibrated so
    # ~40% of candidate 50-min windows see zero availability changes
    # (paper §7.2: 40.4% of 1701 windows scored zero).
    "g6.12xlarge": PoolModel("g6.12xlarge", 8, 0.0015, 0.04, 0.55),
    "g5.12xlarge": PoolModel("g5.12xlarge", 6, 0.0018, 0.04, 0.55),
    "g6e.xlarge": PoolModel("g6e.xlarge", 10, 0.0020, 0.04, 0.55),
    # high-end: scarce (paper: H100 28.64% availability, B200 never)
    "p5.48xlarge": PoolModel("p5.48xlarge", 2, 0.05, 0.02, 0.6),
    "p6.48xlarge": PoolModel("p6.48xlarge", 1, 1.0, 0.0, 1.0),
    # TPU analogs
    "v5e-8": PoolModel("v5e-8", 16, 0.004, 0.05, 0.25),
    "v4-8": PoolModel("v4-8", 10, 0.005, 0.05, 0.25),
    "v5p-8": PoolModel("v5p-8", 3, 0.03, 0.02, 0.5),
}


@dataclasses.dataclass
class AvailabilityTrace:
    """Per-minute available counts per pool."""
    minutes: int
    counts: Dict[str, np.ndarray]

    def events(self) -> List[Tuple[float, str, int]]:
        """(time_s, pool, delta) for every change."""
        out = []
        for pool, series in self.counts.items():
            for t in range(1, len(series)):
                d = int(series[t]) - int(series[t - 1])
                if d != 0:
                    out.append((t * 60.0, pool, d))
        return sorted(out)


def generate_trace(pools: Dict[str, PoolModel], minutes: int = 8640,
                   seed: int = 0) -> AvailabilityTrace:
    rng = np.random.RandomState(seed)
    counts = {}
    for name, pm in pools.items():
        avail = pm.capacity
        series = np.zeros(minutes, np.int32)
        for t in range(minutes):
            # reclaim events
            if avail > 0 and rng.rand() < pm.p_loss_per_min * avail:
                if rng.rand() < pm.correlated:
                    lost = rng.randint(1, avail + 1)   # correlated shortage
                else:
                    lost = 1
                avail -= lost
            # capacity returns
            missing = pm.capacity - avail
            if missing > 0 and rng.rand() < pm.p_gain_per_min * missing:
                avail += rng.randint(1, missing + 1)
            series[t] = max(0, min(pm.capacity, avail))
        counts[name] = series
    return AvailabilityTrace(minutes, counts)


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One region's (or cloud's) spot market: its pool set plus a
    region-level *capacity crunch* process. A crunch minute reclaims a
    fraction of EVERY pool in the region simultaneously — the SkyServe
    observation that preemptions correlate within a region/zone (demand
    surges hit the whole market, not one instance type), which is the
    regime where single-region clusters lose all replicas at once and
    multi-region placement pays off."""
    name: str
    pools: Dict[str, PoolModel]
    crunch_per_min: float = 0.0   # chance a region-wide crunch hits
    crunch_frac: float = 0.5      # fraction of each pool's avail reclaimed


def scaled_pools(scale: int, pools: Optional[Dict[str, PoolModel]] = None
                 ) -> Dict[str, PoolModel]:
    """PAPER_POOLS with capacities multiplied by ``scale`` — the knob that
    takes the paper's 24-GPU market to the 100–1000-node regime."""
    src = PAPER_POOLS if pools is None else pools
    return {n: dataclasses.replace(pm, capacity=pm.capacity * scale)
            for n, pm in src.items()}


def generate_multi_region_trace(regions: Sequence[RegionSpec],
                                minutes: int = 8640,
                                seed: int = 0) -> AvailabilityTrace:
    """Joint availability trace over several regions. Pool keys are
    namespaced ``region/pool`` (the simulator scopes these to pipelines
    placed in that region). Per-pool dynamics are the same Markov on/off
    process as ``generate_trace``; on top, each region's crunch process
    reclaims ``crunch_frac`` of every pool's available capacity in the
    same minute — correlated interruptions by construction. Regions draw
    from independent streams, so adding one never perturbs another."""
    counts: Dict[str, np.ndarray] = {}
    for ri, reg in enumerate(regions):
        rng = np.random.RandomState(seed * 7919 + ri)
        avail = {n: pm.capacity for n, pm in reg.pools.items()}
        series = {n: np.zeros(minutes, np.int32) for n in reg.pools}
        for t in range(minutes):
            crunch = (reg.crunch_per_min > 0
                      and rng.rand() < reg.crunch_per_min)
            for name, pm in reg.pools.items():
                a = avail[name]
                if crunch and a > 0:
                    a -= max(1, int(math.ceil(reg.crunch_frac * a)))
                if a > 0 and rng.rand() < pm.p_loss_per_min * a:
                    if rng.rand() < pm.correlated:
                        lost = rng.randint(1, a + 1)
                    else:
                        lost = 1
                    a -= lost
                missing = pm.capacity - a
                if missing > 0 and rng.rand() < pm.p_gain_per_min * missing:
                    a += rng.randint(1, missing + 1)
                avail[name] = max(0, min(pm.capacity, a))
                series[name][t] = avail[name]
        for name in reg.pools:
            counts[f"{reg.name}/{name}"] = series[name]
    return AvailabilityTrace(minutes, counts)


def correlated_interruption_count(events: Sequence[Tuple[float, str, int]]
                                  ) -> int:
    """Instances reclaimed by CORRELATED events: drops where ≥ 2 pools of
    the same region lose capacity in the same minute (the signature a
    region crunch leaves in the event stream). Bare (un-namespaced) pool
    names are skipped — correlation is a region-level notion."""
    drops: Dict[Tuple[float, str], int] = {}
    pools_hit: Dict[Tuple[float, str], set] = {}
    for (t, pool, d) in events:
        if d >= 0 or "/" not in pool:
            continue
        region = pool.rsplit("/", 1)[0]
        key = (t, region)
        drops[key] = drops.get(key, 0) - d
        pools_hit.setdefault(key, set()).add(pool)
    return sum(c for k, c in drops.items() if len(pools_hit[k]) >= 2)


def window_score(trace: AvailabilityTrace, start_min: int, dur_min: int,
                 pools: Optional[Sequence[str]] = None) -> float:
    """Paper §7.2 composite score: event frequency x affected magnitude.
    ``pools`` restricts scoring to the pools the evaluation cluster uses."""
    score = 0.0
    for pool, series in trace.counts.items():
        if pools is not None and pool not in pools:
            continue
        w = series[start_min:start_min + dur_min]
        diffs = np.diff(w)
        drops = diffs[diffs < 0]
        score += len(diffs[diffs != 0]) * float(np.sum(-drops))
    return score


def select_scenario(trace: AvailabilityTrace, dur_min: int = 50,
                    stride_min: int = 5,
                    pools: Optional[Sequence[str]] = None
                    ) -> Tuple[int, float, float]:
    """Worst-case window: (start_min, score, zero_score_fraction)."""
    scores = []
    for s in range(0, trace.minutes - dur_min, stride_min):
        scores.append((window_score(trace, s, dur_min, pools=pools), s))
    zero_frac = sum(1 for sc, _ in scores if sc == 0) / max(1, len(scores))
    best_score, best_start = max(scores)
    return best_start, best_score, zero_frac


def interruption_events_for_window(trace: AvailabilityTrace, start_min: int,
                                   dur_min: int) -> List[Tuple[float, str, int]]:
    """(t_rel_s, pool, delta) events inside the selected window."""
    out = []
    for pool, series in trace.counts.items():
        w = series[start_min:start_min + dur_min + 1]
        for t in range(1, len(w)):
            d = int(w[t]) - int(w[t - 1])
            if d != 0:
                out.append((t * 60.0, pool, d))
    return sorted(out)
