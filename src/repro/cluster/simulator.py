"""Discrete-event cluster serving simulator (iteration-level).

Reproduces the paper's evaluation methodology at any scale (24 GPUs to
1000+ nodes): pipelines run continuous batching whose per-iteration timing
comes from the SAME roofline estimator the placement optimizer uses; spot
interruptions, grace periods, output-preserving request migration and
concurrent initialization follow §5 / §7.2; cost accounting follows §7.2.3.

Architecture: a typed Event/handler core (``cluster/events.py``) over a
priority queue, with network links (``cluster/network.py``) as first-class
contended resources.  Two timing modes:

- ``network=None`` (default): the legacy closed-form timeline — every
  transfer priced as a constant, links assumed idle.  Kept as the
  uncontended-limit baseline.
- ``network=Topology(...)``: replacement-node warm-up is an actual
  transfer on the region's store link, overlapped with serving and
  contended with concurrent KV-publish / restore / prefix-warm traffic;
  ``recovery.decide`` pricing is re-derived from link state at decision
  time.  On an idle link the DES reproduces the closed form to float
  precision (parity gate in tests/test_cluster_des.py).

Fault-tolerance timeline per interruption (defaults = paper Fig 16):

  t_int                      notice; grace until t_int + grace (serving OK)
  CI:    warm-up transfer submitted at t_int + provision on the store
         link; ready = max(warmup_end, t_int + provision + engine_init)
         (idle link: = t_int + provision + max(store_load, engine_init))
         downtime = [grace_end, max(ready, grace_end)]
  no CI: old pipeline must die first (duplicate-memory OOM), and the fresh
         engine loads weights itself: warm-up submitted at
         max(grace_end, t_int + provision); ready = warmup_end + engine_init
  migration on: in-flight requests re-queued with generated tokens preserved
         (recompute = prefill over s_in + generated);
  off:   restart from scratch (all progress lost).

Link-contention model: store links serialize transmissions FIFO by
submission time (see network.py), so two simultaneous warm-ups in one
region queue behind each other and the second pipeline revives later —
the effect the closed form cannot express.  Pool-preemption round trips
(``kv_pool_tokens``) stay node-local (host-memory store, no network) per
``recovery.preemption_seconds``.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster import events as ev
from repro.cluster.network import Topology, Transfer
from repro.cluster.workload import Request
from repro.core.estimator import (Placement, estimate,
                                  max_batch_size, stage_latencies)
from repro.core.modelspec import ModelSpec


@dataclasses.dataclass
class FTConfig:
    use_spot: bool = True
    request_migration: bool = True
    concurrent_init: bool = True
    grace_period_s: float = 120.0
    node_provision_s: float = 41.55      # paper Fig 16 means
    store_load_s: float = 61.85
    engine_init_s: float = 64.51
    # 'recompute' (paper §5.1 default) | 'transfer' | 'hybrid' (§8.1 future
    # work, implemented in cluster/recovery.py)
    recovery_policy: str = "recompute"
    # engine chunked-prefill size for migration recompute (0 = single-shot);
    # prices re-admission via recovery.recompute_seconds(chunk=...)
    prefill_chunk: int = 0
    # paged engines publishing KV blocks to the shared tensor store during
    # the grace window (serving/server.py use_kv_migration): opens
    # recovery.decide's kv_restore branch — re-admission attaches blocks
    # instead of recomputing the context
    kv_store_migration: bool = False
    # per-pipeline KV block-pool capacity in TOKENS (0 = unbounded). Models
    # the demand-paged engine's overcommitted pool: when the live contexts
    # outgrow it mid-decode, the fewest-generated request is preempted to
    # the node-local store and re-admission is priced like a SELF-INFLICTED
    # kv_restore (recovery.preemption_seconds) instead of a re-prefill
    kv_pool_tokens: int = 0
    # networked mode: bytes of hot-prefix cache a revived replacement node
    # warms from the store (serving/server.py warm-up path). Rides the
    # store link at revival — pure background traffic, charged to no
    # request, but contending with concurrent warm-ups.
    prefix_warm_bytes: float = 0.0


@dataclasses.dataclass
class ReqState:
    req: Request
    generated: int = 0
    admit_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    migrations: int = 0
    # KV arrived via transfer or store restore: no re-prefill on re-admit
    transfer_recovered: bool = False
    # evicted by pool pressure: re-admit pays the preemption round trip
    kv_preempted: bool = False
    # region whose store holds this request's KV (networked restores that
    # land on a pipeline elsewhere ride the cross-region link)
    src_region: str = ""


class SimPipeline:
    def __init__(self, pid: int, spec: ModelSpec, placement: Placement,
                 mean_s_in: int, mean_s_out: int,
                 proto: Optional["SimPipeline"] = None,
                 region: str = "local"):
        """``proto``: an already-built pipeline over the SAME placement
        object — estimator results and timing caches are shared with it,
        so replicating one placement across hundreds of nodes costs one
        estimator evaluation, not hundreds."""
        self.pid = pid
        self.spec = spec
        self.placement = placement
        self.region = region
        self.spot = True      # False = on-demand node: never reclaimed
        if proto is not None and proto.placement is placement \
                and proto.mean_s_in == mean_s_in:
            self.b_max = proto.b_max
            self.weight = proto.weight
            self._iter_cache = proto._iter_cache          # shared dicts
            self._prefill_cache = proto._prefill_cache
        else:
            self.b_max = max(1, max_batch_size(spec, placement, mean_s_in,
                                               mean_s_out))
            perf = estimate(spec, placement, mean_s_in, mean_s_out)
            self.weight = max(perf.throughput_rps, 1e-6)
            self._iter_cache: Dict[int, float] = {}
            self._prefill_cache: Dict[Tuple[int, int, bool], float] = {}
        self.mean_s_in = mean_s_in
        self.eff = 1.0
        self.queue: List[ReqState] = []
        self.active: List[ReqState] = []
        self.alive = True
        self.next_free = 0.0          # busy-until (one iteration at a time)
        self.wake_pending = False
        # pools whose member was already replaced by the ON-DEMAND fallback
        # (paper §8.2: auxiliary on-demand fallback) — immune to further
        # spot events from that pool
        self.replaced_pools: set = set()
        self.down_until = 0.0

    def t_iter(self, batch: int) -> float:
        if batch not in self._iter_cache:
            pre, dec = stage_latencies(self.spec, self.placement, batch,
                                       self.mean_s_in, 1)
            self._iter_cache[batch] = max(dec)
        return self._iter_cache[batch] / self.eff

    def t_prefill(self, batch: int, s_in: int, pipelined: bool = True
                  ) -> float:
        """Admission prefill cost. ``pipelined`` charges the bottleneck
        stage (stages overlap in steady state — consistent with Eq. 5);
        sum-of-stages is the TTFT view, not the throughput view."""
        s_b = max(64, (s_in // 128) * 128)
        key = (batch, s_b, pipelined)
        if key not in self._prefill_cache:
            pre, _ = stage_latencies(self.spec, self.placement, batch, s_b, 1)
            self._prefill_cache[key] = max(pre) if pipelined else sum(pre)
        return self._prefill_cache[key] / self.eff

    def instances(self) -> List[str]:
        return [s.instance.name for s in self.placement.stages]

    def price_hr(self, spot: bool) -> float:
        return self.placement.price_hr(spot)


@dataclasses.dataclass
class SimResult:
    completed: List[ReqState]
    unfinished: List[ReqState]
    duration_s: float
    cost_usd: float
    downtime_s: Dict[int, float]
    interruptions: int
    kv_preemptions: int = 0
    # networked mode: per-link {"n", "bytes", "busy_s", "wait_s"}
    link_stats: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    transfers: int = 0

    @property
    def rps(self) -> float:
        return len(self.completed) / self.duration_s

    @property
    def makespan_rps(self) -> float:
        """Offline throughput: completed / time-of-last-completion (the
        window-based ratio saturates at the workload arrival rate once the
        cluster outruns the trace)."""
        if not self.completed:
            return 0.0
        makespan = max(r.finish_s for r in self.completed)
        return len(self.completed) / max(makespan, 1e-9)

    @property
    def total_downtime_s(self) -> float:
        return sum(self.downtime_s.values())

    def latencies(self, kind: str = "e2e") -> List[float]:
        out = []
        for r in self.completed:
            if kind == "e2e":
                out.append(r.finish_s - r.req.arrival_s)
            elif kind == "ttft":
                out.append(r.first_token_s - r.req.arrival_s)
            elif kind == "tpot":
                if r.req.s_out > 1 and r.first_token_s >= 0:
                    out.append((r.finish_s - r.first_token_s)
                               / max(1, r.req.s_out - 1))
        return out

    def percentile(self, kind: str, q: float) -> float:
        xs = sorted(self.latencies(kind))
        if not xs:
            return float("nan")
        i = min(len(xs) - 1, int(q * len(xs)))
        return xs[i]

    def mean(self, kind: str) -> float:
        xs = self.latencies(kind)
        return sum(xs) / len(xs) if xs else float("nan")


class ClusterSim:
    """Iteration-level continuous-batching simulation (discrete-event)."""

    def __init__(self, spec: ModelSpec, pipelines: Sequence[Placement],
                 ft: FTConfig, mean_s_in: int = 763, mean_s_out: int = 232,
                 seed: int = 0, efficiency: float = 1.0,
                 network: Optional[Topology] = None,
                 regions: Optional[Sequence[str]] = None,
                 spot: Optional[Sequence[bool]] = None):
        """efficiency: achieved/roofline serving efficiency. The estimator
        gives roofline-optimal iteration times; real engines (vLLM on L4s in
        the paper) land well below. Benchmarks calibrate this once against
        the paper's measured ShuntServe throughput (§7.1.2) so absolute
        scales match while all RELATIVE comparisons come from our model.

        network: a ``Topology`` switches transfer timing from closed-form
        constants to contended link transmissions (see module docstring).
        regions: per-pipeline region name (parallel to ``pipelines``;
        default all "local") — selects each pipeline's store link and
        scopes region-qualified pool events ("region/pool").
        spot: per-pipeline spot flag (default all True with
        ``ft.use_spot``). False = an on-demand node: billed at the OD
        rate and immune to pool reclaims — the frontier sweep's spot-mix
        axis."""
        self.spec = spec
        self.ft = ft
        self.efficiency = max(1e-3, efficiency)
        self.network = network
        if regions is not None and len(regions) != len(pipelines):
            raise ValueError("regions must parallel pipelines")
        if spot is not None and len(spot) != len(pipelines):
            raise ValueError("spot must parallel pipelines")
        shared: Dict[int, SimPipeline] = {}
        self.pipes: List[SimPipeline] = []
        for i, p in enumerate(pipelines):
            reg = regions[i] if regions is not None else "local"
            sp = SimPipeline(i, spec, p, mean_s_in, mean_s_out,
                             proto=shared.get(id(p)), region=reg)
            if spot is not None:
                sp.spot = bool(spot[i])
            shared.setdefault(id(p), sp)
            self.pipes.append(sp)
        for p in self.pipes:
            p.eff = self.efficiency
        self._rr = 0.0
        self._rr_credit = [0.0] * len(self.pipes)
        self.interruptions = 0
        self.kv_preemptions = 0
        self.downtime: Dict[int, float] = defaultdict(float)
        self.extra_cost = 0.0
        self._od_fallbacks: List[Tuple[float, float]] = []  # (t, delta_$/hr)
        self._orphans: List[ReqState] = []   # buffered while no pipeline up
        self.seed = seed
        self.transfer_log: List[Transfer] = []
        self._q: Optional[ev.EventQueue] = None
        self._completed: List[ReqState] = []

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, r: ReqState) -> Optional[SimPipeline]:
        """Throughput-weighted round robin over alive pipelines (paper §3)."""
        alive = [p for p in self.pipes if p.alive]
        if not alive:
            return None
        for p in self.pipes:
            if p.alive:
                self._rr_credit[p.pid] += p.weight
        best = max(alive, key=lambda p: self._rr_credit[p.pid])
        self._rr_credit[best.pid] -= sum(p.weight for p in alive)
        best.queue.append(r)
        return best

    # -- pools / regions -----------------------------------------------------
    @staticmethod
    def _pool_base(pool: str) -> str:
        return pool.rsplit("/", 1)[-1]

    def _pool_matches(self, pool: str, p: SimPipeline) -> bool:
        """Legacy bare pool names ("g6.12xlarge") match any region;
        region-qualified names ("us-east/g6.12xlarge") match only
        pipelines placed in that region. On-demand pipelines never match
        (reclaims only take spot capacity)."""
        if not p.spot:
            return False
        if "/" in pool and pool.rsplit("/", 1)[0] != p.region:
            return False
        return self._pool_base(pool) in p.instances()

    def _submit(self, link, t: float, kind: str, nbytes: float) -> Transfer:
        tr = link.submit(t, kind, nbytes)
        self.transfer_log.append(tr)
        if self._q is not None:
            self._q.push(tr.end_s, ev.TransferDone(tr))
        return tr

    # -- interruption handling -----------------------------------------------
    def _interrupt_pipeline(self, pipe: SimPipeline, t: float,
                            requeue: List[ReqState], pool: str = ""):
        ft = self.ft
        net = self.network
        self.interruptions += 1
        grace_end = t + ft.grace_period_s
        link = net.store_link(pipe.region) if net is not None else None
        # KV publishes ride the store link during the grace window: the
        # dying engine pushes every migrating request's blocks to the
        # region store (serving/server.py use_kv_migration). Overlapped
        # with serving — charged to nobody — but they occupy the link any
        # concurrent warm-up must queue behind.
        if net is not None and ft.kv_store_migration \
                and ft.request_migration:
            from repro.cluster.recovery import kv_bytes_for_ctx
            for r in list(pipe.active) + list(pipe.queue):
                if r.generated > 0:
                    self._submit(link, t, "kv_publish",
                                 kv_bytes_for_ctx(self.spec,
                                                  r.req.s_in + r.generated))
        if ft.concurrent_init:
            if net is None:
                ready = t + ft.node_provision_s + max(ft.store_load_s,
                                                      ft.engine_init_s)
            else:
                # replacement provisions for node_provision_s, then fetches
                # weights from the region store as a real transfer; engine
                # init overlaps the fetch (CI = both proceed concurrently)
                wu = self._submit(link, t + ft.node_provision_s, "warmup",
                                  link.bytes_for_duration(ft.store_load_s))
                ready = max(wu.end_s,
                            t + ft.node_provision_s + ft.engine_init_s)
            down_start = grace_end
            down_end = max(ready, grace_end)
            # replacement billed from t; old billed to grace_end: the overlap
            # (grace_end - t) double-bills one node (paper: ~$1.10)
            overlap_h = (grace_end - t) / 3600.0
            inst = pipe.placement.stages[0]
            self.extra_cost += inst.price_hr(ft.use_spot) * overlap_h
        else:
            if net is None:
                ready = (max(grace_end, t + ft.node_provision_s)
                         + ft.store_load_s + ft.engine_init_s)
            else:
                wu = self._submit(link,
                                  max(grace_end, t + ft.node_provision_s),
                                  "warmup",
                                  link.bytes_for_duration(ft.store_load_s))
                ready = wu.end_s + ft.engine_init_s
            down_start, down_end = grace_end, ready
        pipe.down_until = down_end
        self.downtime[pipe.pid] += down_end - down_start
        # restores happen after revival: the wait they inherit is whatever
        # link backlog outlives the downtime window (0 on an idle link —
        # the closed-form equivalence), re-derived here at decision time
        store_wait = 0.0
        if net is not None:
            store_wait = max(0.0, link.busy_until - down_end)
        # at grace end the old engine dies: migrate or restart in-flight work
        for r in list(pipe.active) + list(pipe.queue):
            # a pool-preempted payload lived in the dying node's local
            # store: it does not survive the interruption, so re-admission
            # must be priced by the recovery policy, not as a restore
            r.kv_preempted = False
            if not self.ft.request_migration:
                r.generated = 0
                r.first_token_s = -1.0
            elif (self.ft.recovery_policy != "recompute"
                  and r.generated > 0):
                from repro.cluster.recovery import decide
                d = decide(self.spec, pipe.placement,
                           r.req.s_in + r.generated, ft.grace_period_s,
                           policy=self.ft.recovery_policy,
                           efficiency=self.efficiency,
                           chunk=self.ft.prefill_chunk,
                           store_has_kv=self.ft.kv_store_migration,
                           store_wait_s=store_wait)
                # KV arrived by wire (transfer) or from the store
                # (kv_restore): either way re-admission skips re-prefill
                r.transfer_recovered = d.mechanism in ("transfer",
                                                       "kv_restore")
                r.src_region = pipe.region
            r.admit_s = -1.0
            r.migrations += 1
            requeue.append(r)
        pipe.active.clear()
        pipe.queue.clear()
        pipe.alive = False
        pipe.replaced_pools.add(pool)
        # the replacement runs on-demand until the window ends: bill the
        # price delta from now (accounted in _total_cost). The delta comes
        # from the interrupted pipeline's own matching stage instance, so
        # synthetic (non-catalog) instances price correctly too.
        base = self._pool_base(pool)
        delta_hr = 0.0
        for s in pipe.placement.stages:
            if s.instance.name == base:
                delta_hr = (s.instance.price_ondemand_hr
                            - s.instance.price_spot_hr)
                break
        else:
            from repro.hw.profiles import ALL_INSTANCES
            inst = ALL_INSTANCES.get(base)
            if inst is not None:
                delta_hr = inst.price_ondemand_hr - inst.price_spot_hr
        self._od_fallbacks.append((t, delta_hr))

    # -- event handlers ------------------------------------------------------
    def _push_wake(self, t_w: float, pipe: SimPipeline):
        if pipe.wake_pending:
            return
        pipe.wake_pending = True
        self._q.push(t_w, ev.Wake(pipe.pid))

    def _on_arrive(self, t: float, e: ev.Arrive):
        r = e.req
        p = self._dispatch(r)
        if p is None:
            self._orphans.append(r)   # total outage: buffer
        elif p.alive:
            self._push_wake(max(t, p.next_free), p)

    def _on_interrupt(self, t: float, e: ev.Interrupt):
        requeue: List[ReqState] = []
        hit = 0
        for p in self.pipes:
            if hit >= e.count:
                break
            if (p.alive and self._pool_matches(e.pool, p)
                    and e.pool not in p.replaced_pools):
                self._interrupt_pipeline(p, t, requeue, e.pool)
                hit += 1
                self._q.push(p.down_until, ev.Revive(p.pid))
        for r in requeue:
            p = self._dispatch(r)
            if p is None:
                self._orphans.append(r)
            elif p.alive:
                self._push_wake(max(t, p.next_free), p)

    def _on_revive(self, t: float, e: ev.Revive):
        p = self.pipes[e.pid]
        p.alive = True
        p.next_free = t
        # replacement node warms the hot-prefix cache from the store —
        # background traffic on the region link (server.py warm-up path)
        if self.network is not None and self.ft.prefix_warm_bytes > 0:
            self._submit(self.network.store_link(p.region), t,
                         "prefix_warm", self.ft.prefix_warm_bytes)
        if self._orphans:        # flush buffered requests
            orphans, self._orphans = self._orphans, []
            for r in orphans:
                q = self._dispatch(r)
                if q is None:
                    self._orphans.append(r)
        self._push_wake(t, p)

    def _on_wake(self, t: float, e: ev.Wake):
        p = self.pipes[e.pid]
        p.wake_pending = False
        if not p.alive:
            return
        if t < p.next_free - 1e-12:      # still mid-iteration
            self._push_wake(p.next_free, p)
            return
        dt = self._pipeline_iteration(p, t, self._completed)
        if dt > 0:
            p.next_free = t + dt
            self._push_wake(t + dt, p)

    def _on_transfer_done(self, t: float, e: ev.TransferDone):
        # completion bookkeeping only: serialized links fix end times at
        # submit, so nothing re-plans here — but the event keeps transfer
        # lifecycles on the queue in time order for tracing/extension
        pass

    # -- main loop -----------------------------------------------------------
    def run(self, requests: Sequence[Request], duration_s: float,
            events: Sequence[Tuple[float, str, int]] = (),
            offline: bool = False) -> SimResult:
        """events: (t_s, pool_name, delta) availability changes (delta<0
        interrupts pipelines containing instances of that pool; pool may
        be region-qualified as "region/pool")."""
        arrivals = sorted(requests, key=lambda r: r.arrival_s)
        if offline:
            arrivals = [dataclasses.replace(r, arrival_s=0.0)
                        for r in arrivals]
        self._q = ev.EventQueue()
        self._completed = []
        for r in arrivals:
            self._q.push(r.arrival_s, ev.Arrive(ReqState(r)))
        for (te, pool, delta) in events:
            if self.ft.use_spot and delta < 0:
                self._q.push(te, ev.Interrupt(pool, -delta))
        for p in self.pipes:
            self._push_wake(0.0, p)
        handlers = {
            ev.Arrive: self._on_arrive,
            ev.Interrupt: self._on_interrupt,
            ev.Revive: self._on_revive,
            ev.Wake: self._on_wake,
            ev.TransferDone: self._on_transfer_done,
        }
        ev.dispatch(self._q, handlers, until=duration_s)
        completed = self._completed
        unfinished = []
        for p in self.pipes:
            unfinished.extend(p.active)
            unfinished.extend(p.queue)
        cost = self._total_cost(duration_s)
        stats = self.network.stats() if self.network is not None else {}
        return SimResult(completed, unfinished, duration_s, cost,
                         dict(self.downtime), self.interruptions,
                         self.kv_preemptions, stats,
                         len(self.transfer_log))

    def _kv_preempt(self, p: SimPipeline, live_tok: int) -> int:
        """Demand-paged pool pressure: this iteration writes one token per
        active request, so the pool must cover live_tok + batch. Preempt
        fewest-generated victims (the engine's policy) to the queue front
        until the batch fits; returns the updated live token count."""
        pool = self.ft.kv_pool_tokens
        while p.active and live_tok + len(p.active) > pool:
            victim = min(p.active,
                         key=lambda r: (r.generated, r.req.arrival_s))
            p.active.remove(victim)
            live_tok -= victim.req.s_in + victim.generated
            victim.kv_preempted = True
            victim.admit_s = -1.0
            p.queue.insert(0, victim)
            self.kv_preemptions += 1
        return live_tok

    def _pipeline_iteration(self, p: SimPipeline, t: float,
                            completed: List[ReqState]) -> float:
        """Admit + one decode iteration; returns elapsed time (0 = idle)."""
        dt = 0.0
        pool = self.ft.kv_pool_tokens
        live_tok = sum(r.req.s_in + r.generated for r in p.active) \
            if pool else 0
        if pool:
            live_tok = self._kv_preempt(p, live_tok)
        # admit newcomers up to b_max (and, pool-bounded, up to capacity —
        # an empty pipeline always admits one so a request larger than the
        # pool still makes progress via the preempt/grow cycle)
        new = []
        while p.queue and len(p.active) + len(new) < p.b_max:
            need = p.queue[0].req.s_in + p.queue[0].generated + 1
            if pool and (p.active or new) and live_tok + need > pool:
                break
            new.append(p.queue.pop(0))
            live_tok += need
        if new:
            # transfer-recovered requests carry their KV with them (moved
            # during the downtime window); pool-preempted ones re-attach
            # from the node-local store at the preemption round-trip price
            # — only the rest pay recompute
            recompute = [r for r in new
                         if not r.transfer_recovered and not r.kv_preempted]
            if recompute:
                ctx = int(sum(r.req.s_in + r.generated for r in recompute)
                          / len(recompute))
                dt += p.t_prefill(len(recompute), ctx)
            restored = [r for r in new if r.kv_preempted]
            if restored:
                from repro.cluster.recovery import preemption_seconds
                dt += sum(preemption_seconds(self.spec,
                                             r.req.s_in + r.generated)
                          for r in restored)
            if self.network is not None:
                # store restores ride the admitting region's link (or the
                # cross-region link when the KV was published elsewhere):
                # overlapped with the downtime window in the closed form,
                # so charged to nobody — but real bytes on a real link
                from repro.cluster.recovery import kv_bytes_for_ctx
                for r in new:
                    if not r.transfer_recovered:
                        continue
                    if r.src_region and r.src_region != p.region:
                        link = self.network.cross_link(r.src_region,
                                                       p.region)
                    else:
                        link = self.network.store_link(p.region)
                    self._submit(link, t, "kv_restore",
                                 kv_bytes_for_ctx(self.spec,
                                                  r.req.s_in + r.generated))
            for r in new:
                r.admit_s = t
                r.transfer_recovered = False
                r.src_region = ""
                if r.kv_preempted:
                    # re-attach resumes decode exactly where the preempt
                    # parked it: no token is emitted at admission
                    r.kv_preempted = False
                    p.active.append(r)
                    continue
                if r.first_token_s < 0:
                    r.first_token_s = t + dt      # first new token emitted
                r.generated += 1                   # prefill emits one token
                p.active.append(r)
        if not p.active:
            return dt
        dt += p.t_iter(len(p.active))
        done = []
        for r in p.active:
            r.generated += 1
            if r.generated >= r.req.s_out:
                r.finish_s = t + dt
                done.append(r)
        for r in done:
            p.active.remove(r)
            completed.append(r)
        return dt

    def _total_cost(self, duration_s: float) -> float:
        hours = duration_s / 3600.0
        base = sum(p.price_hr(self.ft.use_spot and p.spot)
                   for p in self.pipes) * hours
        # on-demand fallback premium for each replaced instance
        od_premium = 0.0
        if self.ft.use_spot:
            for (t, delta_hr) in self._od_fallbacks:
                od_premium += delta_hr * max(0.0, duration_s - t) / 3600.0
        return base + self.extra_cost + od_premium
