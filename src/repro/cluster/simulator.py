"""Event-driven cluster serving simulator (iteration-level).

Reproduces the paper's evaluation methodology at any scale (24 GPUs to
1000+ nodes): pipelines run continuous batching whose per-iteration timing
comes from the SAME roofline estimator the placement optimizer uses; spot
interruptions, grace periods, output-preserving request migration and
concurrent initialization follow §5 / §7.2; cost accounting follows §7.2.3.

Fault-tolerance timeline per interruption (defaults = paper Fig 16):

  t_int                      notice; grace until t_int + grace (serving OK)
  CI:    ready = t_int + provision + max(store_load, engine_init)
         downtime = [grace_end, max(ready, grace_end)]
  no CI: old pipeline must die first (duplicate-memory OOM), and the fresh
         engine loads weights itself:
         ready = max(grace_end, t_int + provision) + store_load + engine_init
  migration on: in-flight requests re-queued with generated tokens preserved
         (recompute = prefill over s_in + generated);
  off:   restart from scratch (all progress lost).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster.workload import Request
from repro.core.estimator import (Placement, estimate,
                                  max_batch_size, stage_latencies)
from repro.core.modelspec import ModelSpec


@dataclasses.dataclass
class FTConfig:
    use_spot: bool = True
    request_migration: bool = True
    concurrent_init: bool = True
    grace_period_s: float = 120.0
    node_provision_s: float = 41.55      # paper Fig 16 means
    store_load_s: float = 61.85
    engine_init_s: float = 64.51
    # 'recompute' (paper §5.1 default) | 'transfer' | 'hybrid' (§8.1 future
    # work, implemented in cluster/recovery.py)
    recovery_policy: str = "recompute"
    # engine chunked-prefill size for migration recompute (0 = single-shot);
    # prices re-admission via recovery.recompute_seconds(chunk=...)
    prefill_chunk: int = 0
    # paged engines publishing KV blocks to the shared tensor store during
    # the grace window (serving/server.py use_kv_migration): opens
    # recovery.decide's kv_restore branch — re-admission attaches blocks
    # instead of recomputing the context
    kv_store_migration: bool = False
    # per-pipeline KV block-pool capacity in TOKENS (0 = unbounded). Models
    # the demand-paged engine's overcommitted pool: when the live contexts
    # outgrow it mid-decode, the fewest-generated request is preempted to
    # the node-local store and re-admission is priced like a SELF-INFLICTED
    # kv_restore (recovery.preemption_seconds) instead of a re-prefill
    kv_pool_tokens: int = 0


@dataclasses.dataclass
class ReqState:
    req: Request
    generated: int = 0
    admit_s: float = -1.0
    first_token_s: float = -1.0
    finish_s: float = -1.0
    migrations: int = 0
    # KV arrived via transfer or store restore: no re-prefill on re-admit
    transfer_recovered: bool = False
    # evicted by pool pressure: re-admit pays the preemption round trip
    kv_preempted: bool = False


class SimPipeline:
    def __init__(self, pid: int, spec: ModelSpec, placement: Placement,
                 mean_s_in: int, mean_s_out: int):
        self.pid = pid
        self.spec = spec
        self.placement = placement
        self.b_max = max(1, max_batch_size(spec, placement, mean_s_in,
                                           mean_s_out))
        self.mean_s_in = mean_s_in
        self.eff = 1.0
        self.queue: List[ReqState] = []
        self.active: List[ReqState] = []
        self.alive = True
        self.next_free = 0.0          # busy-until (one iteration at a time)
        self.wake_pending = False
        # pools whose member was already replaced by the ON-DEMAND fallback
        # (paper §8.2: auxiliary on-demand fallback) — immune to further
        # spot events from that pool
        self.replaced_pools: set = set()
        self.down_until = 0.0
        self._iter_cache: Dict[int, float] = {}
        self._prefill_cache: Dict[Tuple[int, int], float] = {}
        perf = estimate(spec, placement, mean_s_in, mean_s_out)
        self.weight = max(perf.throughput_rps, 1e-6)

    def t_iter(self, batch: int) -> float:
        if batch not in self._iter_cache:
            pre, dec = stage_latencies(self.spec, self.placement, batch,
                                       self.mean_s_in, 1)
            self._iter_cache[batch] = max(dec)
        return self._iter_cache[batch] / self.eff

    def t_prefill(self, batch: int, s_in: int, pipelined: bool = True
                  ) -> float:
        """Admission prefill cost. ``pipelined`` charges the bottleneck
        stage (stages overlap in steady state — consistent with Eq. 5);
        sum-of-stages is the TTFT view, not the throughput view."""
        s_b = max(64, (s_in // 128) * 128)
        key = (batch, s_b, pipelined)
        if key not in self._prefill_cache:
            pre, _ = stage_latencies(self.spec, self.placement, batch, s_b, 1)
            self._prefill_cache[key] = max(pre) if pipelined else sum(pre)
        return self._prefill_cache[key] / self.eff

    def instances(self) -> List[str]:
        return [s.instance.name for s in self.placement.stages]

    def price_hr(self, spot: bool) -> float:
        return self.placement.price_hr(spot)


@dataclasses.dataclass
class SimResult:
    completed: List[ReqState]
    unfinished: List[ReqState]
    duration_s: float
    cost_usd: float
    downtime_s: Dict[int, float]
    interruptions: int
    kv_preemptions: int = 0

    @property
    def rps(self) -> float:
        return len(self.completed) / self.duration_s

    @property
    def makespan_rps(self) -> float:
        """Offline throughput: completed / time-of-last-completion (the
        window-based ratio saturates at the workload arrival rate once the
        cluster outruns the trace)."""
        if not self.completed:
            return 0.0
        makespan = max(r.finish_s for r in self.completed)
        return len(self.completed) / max(makespan, 1e-9)

    def latencies(self, kind: str = "e2e") -> List[float]:
        out = []
        for r in self.completed:
            if kind == "e2e":
                out.append(r.finish_s - r.req.arrival_s)
            elif kind == "ttft":
                out.append(r.first_token_s - r.req.arrival_s)
            elif kind == "tpot":
                if r.req.s_out > 1 and r.first_token_s >= 0:
                    out.append((r.finish_s - r.first_token_s)
                               / max(1, r.req.s_out - 1))
        return out

    def percentile(self, kind: str, q: float) -> float:
        xs = sorted(self.latencies(kind))
        if not xs:
            return float("nan")
        i = min(len(xs) - 1, int(q * len(xs)))
        return xs[i]

    def mean(self, kind: str) -> float:
        xs = self.latencies(kind)
        return sum(xs) / len(xs) if xs else float("nan")


class ClusterSim:
    """Iteration-level continuous-batching simulation."""

    def __init__(self, spec: ModelSpec, pipelines: Sequence[Placement],
                 ft: FTConfig, mean_s_in: int = 763, mean_s_out: int = 232,
                 seed: int = 0, efficiency: float = 1.0):
        """efficiency: achieved/roofline serving efficiency. The estimator
        gives roofline-optimal iteration times; real engines (vLLM on L4s in
        the paper) land well below. Benchmarks calibrate this once against
        the paper's measured ShuntServe throughput (§7.1.2) so absolute
        scales match while all RELATIVE comparisons come from our model."""
        self.spec = spec
        self.ft = ft
        self.efficiency = max(1e-3, efficiency)
        self.pipes = [SimPipeline(i, spec, p, mean_s_in, mean_s_out)
                      for i, p in enumerate(pipelines)]
        for p in self.pipes:
            p.eff = self.efficiency
        self._rr = 0.0
        self._rr_credit = [0.0] * len(self.pipes)
        self.interruptions = 0
        self.kv_preemptions = 0
        self.downtime: Dict[int, float] = defaultdict(float)
        self.extra_cost = 0.0
        self._od_fallbacks: List[Tuple[float, str]] = []
        self._orphans: List[ReqState] = []   # buffered while no pipeline up
        self.seed = seed

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, r: ReqState) -> Optional[SimPipeline]:
        """Throughput-weighted round robin over alive pipelines (paper §3)."""
        alive = [p for p in self.pipes if p.alive]
        if not alive:
            return None
        for p in self.pipes:
            if p.alive:
                self._rr_credit[p.pid] += p.weight
        best = max(alive, key=lambda p: self._rr_credit[p.pid])
        self._rr_credit[best.pid] -= sum(p.weight for p in alive)
        best.queue.append(r)
        return best

    # -- interruption handling -------------------------------------------------
    def _interrupt_pipeline(self, pipe: SimPipeline, t: float,
                            requeue: List[ReqState], pool: str = ""):
        ft = self.ft
        self.interruptions += 1
        grace_end = t + ft.grace_period_s
        if ft.concurrent_init:
            ready = t + ft.node_provision_s + max(ft.store_load_s,
                                                  ft.engine_init_s)
            down_start = grace_end
            down_end = max(ready, grace_end)
            # replacement billed from t; old billed to grace_end: the overlap
            # (grace_end - t) double-bills one node (paper: ~$1.10)
            overlap_h = (grace_end - t) / 3600.0
            inst = pipe.placement.stages[0]
            self.extra_cost += inst.price_hr(ft.use_spot) * overlap_h
        else:
            ready = (max(grace_end, t + ft.node_provision_s)
                     + ft.store_load_s + ft.engine_init_s)
            down_start, down_end = grace_end, ready
        pipe.down_until = down_end
        self.downtime[pipe.pid] += down_end - down_start
        # at grace end the old engine dies: migrate or restart in-flight work
        for r in list(pipe.active) + list(pipe.queue):
            # a pool-preempted payload lived in the dying node's local
            # store: it does not survive the interruption, so re-admission
            # must be priced by the recovery policy, not as a restore
            r.kv_preempted = False
            if not self.ft.request_migration:
                r.generated = 0
                r.first_token_s = -1.0
            elif (self.ft.recovery_policy != "recompute"
                  and r.generated > 0):
                from repro.cluster.recovery import decide
                d = decide(self.spec, pipe.placement,
                           r.req.s_in + r.generated, ft.grace_period_s,
                           policy=self.ft.recovery_policy,
                           efficiency=self.efficiency,
                           chunk=self.ft.prefill_chunk,
                           store_has_kv=self.ft.kv_store_migration)
                # KV arrived by wire (transfer) or from the store
                # (kv_restore): either way re-admission skips re-prefill
                r.transfer_recovered = d.mechanism in ("transfer",
                                                       "kv_restore")
            r.admit_s = -1.0
            r.migrations += 1
            requeue.append(r)
        pipe.active.clear()
        pipe.queue.clear()
        pipe.alive = False
        pipe.replaced_pools.add(pool)
        # the replacement runs on-demand until the window ends: bill the
        # price delta from now (accounted in _total_cost)
        self._od_fallbacks.append((t, pool))

    # -- main loop ------------------------------------------------------------
    def run(self, requests: Sequence[Request], duration_s: float,
            events: Sequence[Tuple[float, str, int]] = (),
            offline: bool = False) -> SimResult:
        """events: (t_s, pool_name, delta) availability changes (delta<0
        interrupts pipelines containing instances of that pool)."""
        arrivals = sorted(requests, key=lambda r: r.arrival_s)
        if offline:
            arrivals = [dataclasses.replace(r, arrival_s=0.0)
                        for r in arrivals]
        heap: List[Tuple[float, int, str, object]] = []
        seq = 0

        def push_wake(t_w: float, pipe: SimPipeline):
            nonlocal seq
            if pipe.wake_pending:
                return
            pipe.wake_pending = True
            heapq.heappush(heap, (t_w, seq, "wake", pipe.pid))
            seq += 1
        for r in arrivals:
            heapq.heappush(heap, (r.arrival_s, seq, "arrive", ReqState(r)))
            seq += 1
        for (te, pool, delta) in events:
            if self.ft.use_spot and delta < 0:
                heapq.heappush(heap, (te, seq, "interrupt", (pool, -delta)))
                seq += 1
        for p in self.pipes:
            heapq.heappush(heap, (0.0, seq, "wake", p.pid))
            seq += 1
        completed: List[ReqState] = []
        t = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > duration_s:
                break
            if kind == "arrive":
                r = payload  # type: ignore[assignment]
                p = self._dispatch(r)
                if p is None:
                    self._orphans.append(r)   # total outage: buffer
                elif p.alive:
                    push_wake(max(t, p.next_free), p)
            elif kind == "interrupt":
                pool, n = payload  # type: ignore[misc]
                requeue: List[ReqState] = []
                hit = 0
                for p in self.pipes:
                    if hit >= n:
                        break
                    if (p.alive and pool in p.instances()
                            and pool not in p.replaced_pools):
                        self._interrupt_pipeline(p, t, requeue, pool)
                        hit += 1
                        heapq.heappush(heap, (p.down_until, seq, "revive",
                                              p.pid))
                        seq += 1
                for r in requeue:
                    p = self._dispatch(r)
                    if p is None:
                        self._orphans.append(r)
                    elif p.alive:
                        push_wake(max(t, p.next_free), p)
            elif kind == "revive":
                p = self.pipes[payload]  # type: ignore[index]
                p.alive = True
                p.next_free = t
                if self._orphans:        # flush buffered requests
                    orphans, self._orphans = self._orphans, []
                    for r in orphans:
                        q = self._dispatch(r)
                        if q is None:
                            self._orphans.append(r)
                push_wake(t, p)
            elif kind == "wake":
                p = self.pipes[payload]  # type: ignore[index]
                p.wake_pending = False
                if not p.alive:
                    continue
                if t < p.next_free - 1e-12:      # still mid-iteration
                    push_wake(p.next_free, p)
                    continue
                dt = self._pipeline_iteration(p, t, completed)
                if dt > 0:
                    p.next_free = t + dt
                    push_wake(t + dt, p)
        unfinished = []
        for p in self.pipes:
            unfinished.extend(p.active)
            unfinished.extend(p.queue)
        cost = self._total_cost(duration_s)
        return SimResult(completed, unfinished, duration_s, cost,
                         dict(self.downtime), self.interruptions,
                         self.kv_preemptions)

    def _kv_preempt(self, p: SimPipeline, live_tok: int) -> int:
        """Demand-paged pool pressure: this iteration writes one token per
        active request, so the pool must cover live_tok + batch. Preempt
        fewest-generated victims (the engine's policy) to the queue front
        until the batch fits; returns the updated live token count."""
        pool = self.ft.kv_pool_tokens
        while p.active and live_tok + len(p.active) > pool:
            victim = min(p.active,
                         key=lambda r: (r.generated, r.req.arrival_s))
            p.active.remove(victim)
            live_tok -= victim.req.s_in + victim.generated
            victim.kv_preempted = True
            victim.admit_s = -1.0
            p.queue.insert(0, victim)
            self.kv_preemptions += 1
        return live_tok

    def _pipeline_iteration(self, p: SimPipeline, t: float,
                            completed: List[ReqState]) -> float:
        """Admit + one decode iteration; returns elapsed time (0 = idle)."""
        dt = 0.0
        pool = self.ft.kv_pool_tokens
        live_tok = sum(r.req.s_in + r.generated for r in p.active) \
            if pool else 0
        if pool:
            live_tok = self._kv_preempt(p, live_tok)
        # admit newcomers up to b_max (and, pool-bounded, up to capacity —
        # an empty pipeline always admits one so a request larger than the
        # pool still makes progress via the preempt/grow cycle)
        new = []
        while p.queue and len(p.active) + len(new) < p.b_max:
            need = p.queue[0].req.s_in + p.queue[0].generated + 1
            if pool and (p.active or new) and live_tok + need > pool:
                break
            new.append(p.queue.pop(0))
            live_tok += need
        if new:
            # transfer-recovered requests carry their KV with them (moved
            # during the downtime window); pool-preempted ones re-attach
            # from the node-local store at the preemption round-trip price
            # — only the rest pay recompute
            recompute = [r for r in new
                         if not r.transfer_recovered and not r.kv_preempted]
            if recompute:
                ctx = int(sum(r.req.s_in + r.generated for r in recompute)
                          / len(recompute))
                dt += p.t_prefill(len(recompute), ctx)
            restored = [r for r in new if r.kv_preempted]
            if restored:
                from repro.cluster.recovery import preemption_seconds
                dt += sum(preemption_seconds(self.spec,
                                             r.req.s_in + r.generated)
                          for r in restored)
            for r in new:
                r.admit_s = t
                r.transfer_recovered = False
                if r.kv_preempted:
                    # re-attach resumes decode exactly where the preempt
                    # parked it: no token is emitted at admission
                    r.kv_preempted = False
                    p.active.append(r)
                    continue
                if r.first_token_s < 0:
                    r.first_token_s = t + dt      # first new token emitted
                r.generated += 1                   # prefill emits one token
                p.active.append(r)
        if not p.active:
            return dt
        dt += p.t_iter(len(p.active))
        done = []
        for r in p.active:
            r.generated += 1
            if r.generated >= r.req.s_out:
                r.finish_s = t + dt
                done.append(r)
        for r in done:
            p.active.remove(r)
            completed.append(r)
        return dt

    def _total_cost(self, duration_s: float) -> float:
        hours = duration_s / 3600.0
        base = sum(p.price_hr(self.ft.use_spot) for p in self.pipes) * hours
        # on-demand fallback premium for each replaced instance
        od_premium = 0.0
        if self.ft.use_spot:
            from repro.hw.profiles import ALL_INSTANCES
            for (t, pool) in self._od_fallbacks:
                inst = ALL_INSTANCES.get(pool)
                if inst is not None:
                    od_premium += ((inst.price_ondemand_hr
                                    - inst.price_spot_hr)
                                   * max(0.0, duration_s - t) / 3600.0)
        return base + self.extra_cost + od_premium
