from repro.cluster.simulator import ClusterSim, FTConfig, SimResult
from repro.cluster.spot_trace import (PAPER_POOLS, AvailabilityTrace,
                                      generate_trace,
                                      interruption_events_for_window,
                                      select_scenario)
from repro.cluster.workload import (Request, azure_conversation_like,
                                    length_histogram)

__all__ = ["ClusterSim", "FTConfig", "SimResult", "PAPER_POOLS",
           "AvailabilityTrace", "generate_trace", "select_scenario",
           "interruption_events_for_window", "Request",
           "azure_conversation_like", "length_histogram"]
