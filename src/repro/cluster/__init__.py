from repro.cluster.events import (Arrive, Event, EventQueue, Interrupt,
                                  Revive, TransferDone, Wake)
from repro.cluster.frontier import (FrontierPoint, pareto_front,
                                    sweep_frontier)
from repro.cluster.network import (LinkSpec, NetworkLink, Topology,
                                   Transfer)
from repro.cluster.simulator import ClusterSim, FTConfig, SimResult
from repro.cluster.spot_trace import (PAPER_POOLS, AvailabilityTrace,
                                      RegionSpec,
                                      correlated_interruption_count,
                                      generate_multi_region_trace,
                                      generate_trace,
                                      interruption_events_for_window,
                                      scaled_pools, select_scenario)
from repro.cluster.workload import (Request, azure_conversation_like,
                                    diurnal_rate, length_histogram)

__all__ = ["ClusterSim", "FTConfig", "SimResult", "PAPER_POOLS",
           "AvailabilityTrace", "generate_trace", "select_scenario",
           "interruption_events_for_window", "Request",
           "azure_conversation_like", "length_histogram",
           # discrete-event core
           "Event", "EventQueue", "Arrive", "Interrupt", "Revive", "Wake",
           "TransferDone",
           # network
           "NetworkLink", "LinkSpec", "Topology", "Transfer",
           # multi-region spot markets
           "RegionSpec", "scaled_pools", "generate_multi_region_trace",
           "correlated_interruption_count",
           # frontier sweep
           "FrontierPoint", "sweep_frontier", "pareto_front",
           "diurnal_rate"]
