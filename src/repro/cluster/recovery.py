"""Hybrid request-recovery policy — the paper's §8.1 future work,
implemented.

The paper adopts recomputation-only migration because KV transfer must
complete inside the grace period and fails catastrophically mid-transfer.
It then notes (Discussion §8.1) that recomputation loses at very long
contexts (~9.6% slower at 64k on L40S) and sketches a hybrid: "track the
progress of in-flight requests and the remaining grace period, and select
an appropriate request recovery mechanism for each request individually."

This module is that policy. Per interrupted request:

    recompute_cost = bottleneck-stage prefill over (s_in + generated)
    transfer_cost  = setup + kv_bytes(ctx) / effective_bw     [paper Fig 5]
    pick transfer iff  transfer_cost < recompute_cost
                   and transfer fits in the REMAINING grace budget
                   (the paper's §5.1 safety constraint — otherwise a
                   mid-transfer reclaim forces paying both costs)

The cluster simulator charges the chosen mechanism's cost on re-admission,
so Fig-13/14-style runs quantify the hybrid's benefit on long-context
workloads (see benchmarks/bench_fault_tolerance.py hybrid variant and
tests/test_recovery.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.estimator import Placement, stage_latencies
from repro.core.modelspec import ModelSpec

# Fig-5-calibrated transfer path constants (see bench_migration_tradeoff)
TRANSFER_SETUP_S = 1.0
TRANSFER_EFF = 0.25


@dataclasses.dataclass(frozen=True)
class RecoveryDecision:
    mechanism: str            # "recompute" | "transfer"
    recompute_s: float
    transfer_s: float
    fits_grace: bool


def kv_bytes_for_ctx(spec: ModelSpec, ctx: int) -> float:
    total = 0.0
    for l in spec.layers:
        tokens = ctx if l.window is None else min(ctx, l.window)
        total += l.kv_bytes_per_token(spec.dtype_bytes) * tokens
        total += l.state_bytes_per_seq(spec.dtype_bytes)
    return total


def recompute_seconds(spec: ModelSpec, placement: Placement, ctx: int,
                      efficiency: float = 1.0, chunk: int = 0,
                      max_len: int = 0) -> float:
    """Bottleneck-stage prefill over the full context (pipelined view).

    chunk > 0 models the engine's chunked recompute: the same prefill FLOPs
    split into chunks interleaved with live decode, so the migrated
    request's re-admission completes one bottleneck decode step later per
    extra chunk (live slots, in exchange, never stall for the whole
    context — the §5.1 interruption-storm head-of-line fix). Mirrors the
    engine's actual admission rules: only ctx-1 tokens re-prefill (the
    last generated token is fed to decode, ``Engine._prefill_tokens``),
    and when max_len > 0 and the padded span ceil(toks/chunk)*chunk would
    exceed it the engine single-shots (``Engine._use_chunked``)."""
    pre, dec = stage_latencies(spec, placement, 1, max(16, ctx), 1)
    total = max(pre)
    toks = max(ctx - 1, 1)
    if chunk and 0 < chunk < toks:
        n_chunks = -(-toks // chunk)
        if max_len <= 0 or n_chunks * chunk <= max_len:
            total += (n_chunks - 1) * max(dec)
    return total / max(efficiency, 1e-3)


def transfer_seconds(spec: ModelSpec, placement: Placement, ctx: int
                     ) -> float:
    nbytes = kv_bytes_for_ctx(spec, ctx)
    link = placement.stages[0].inter_link()
    return (TRANSFER_SETUP_S + link.alpha_s
            + nbytes / (TRANSFER_EFF * link.beta_bps))


def decide(spec: ModelSpec, placement: Placement, ctx: int,
           remaining_grace_s: float, policy: str = "hybrid",
           efficiency: float = 1.0, chunk: int = 0,
           max_len: int = 0) -> RecoveryDecision:
    """policy: 'recompute' (paper default), 'transfer', or 'hybrid'
    (paper §8.1 future work). chunk > 0 prices recompute under the
    engine's chunked-prefill admission (max_len bounds it as the engine
    does)."""
    rc = recompute_seconds(spec, placement, ctx, efficiency, chunk=chunk,
                           max_len=max_len)
    tr = transfer_seconds(spec, placement, ctx)
    fits = tr <= remaining_grace_s
    if policy == "recompute":
        mech = "recompute"
    elif policy == "transfer":
        mech = "transfer" if fits else "recompute"   # safety fallback
    else:
        mech = "transfer" if (fits and tr < rc) else "recompute"
    return RecoveryDecision(mech, rc, tr, fits)
