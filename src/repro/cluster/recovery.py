"""Hybrid request-recovery policy — the paper's §8.1 future work,
implemented.

The paper adopts recomputation-only migration because KV transfer must
complete inside the grace period and fails catastrophically mid-transfer.
It then notes (Discussion §8.1) that recomputation loses at very long
contexts (~9.6% slower at 64k on L40S) and sketches a hybrid: "track the
progress of in-flight requests and the remaining grace period, and select
an appropriate request recovery mechanism for each request individually."

This module is that policy. Per interrupted request:

    recompute_cost  = bottleneck-stage prefill over (s_in + generated)
    transfer_cost   = setup + kv_bytes(ctx) / effective_bw    [paper Fig 5]
    kv_restore_cost = setup + kv_bytes(ctx) / store_bw        [§5.2 store]
    pick transfer iff  transfer_cost < recompute_cost
                   and transfer fits in the REMAINING grace budget
                   (the paper's §5.1 safety constraint — otherwise a
                   mid-transfer reclaim forces paying both costs)
    pick kv_restore iff the store already HOLDS the request's blocks
                   (``store_has_kv`` — the paged engine published them
                   during the grace window, see serving/server.py) and it
                   beats the other eligible mechanisms. Restoring from the
                   store happens after revival, so it carries no grace-
                   period constraint — publication already completed.

The cluster simulator charges the chosen mechanism's cost on re-admission,
so Fig-13/14-style runs quantify the hybrid's benefit on long-context
workloads (see benchmarks/bench_fault_tolerance.py hybrid variant and
tests/test_recovery.py).
"""

from __future__ import annotations

import dataclasses

from repro.core.estimator import Placement, stage_latencies
from repro.core.modelspec import ModelSpec

# Fig-5-calibrated transfer path constants (see bench_migration_tradeoff)
TRANSFER_SETUP_S = 1.0
TRANSFER_EFF = 0.25
# tensor-store restore path: node-local host memory -> device, no network
# race — a pinned-host-to-HBM copy (order PCIe/DMA bandwidth) plus the
# attach round trip
KV_RESTORE_SETUP_S = 0.05
KV_RESTORE_BW_BPS = 8e9


@dataclasses.dataclass(frozen=True)
class RecoveryDecision:
    mechanism: str            # "recompute" | "transfer" | "kv_restore"
    recompute_s: float
    transfer_s: float
    fits_grace: bool
    kv_restore_s: float = float("inf")


def kv_bytes_for_ctx(spec: ModelSpec, ctx: int) -> float:
    total = 0.0
    for l in spec.layers:
        tokens = ctx if l.window is None else min(ctx, l.window)
        total += l.kv_bytes_per_token(spec.dtype_bytes) * tokens
        total += l.state_bytes_per_seq(spec.dtype_bytes)
    return total


def recompute_seconds(spec: ModelSpec, placement: Placement, ctx: int,
                      efficiency: float = 1.0, chunk: int = 0,
                      max_len: int = 0) -> float:
    """Bottleneck-stage prefill over the full context (pipelined view).

    chunk > 0 models the engine's chunked recompute: the same prefill FLOPs
    split into chunks interleaved with live decode, so the migrated
    request's re-admission completes one bottleneck decode step later per
    extra chunk (live slots, in exchange, never stall for the whole
    context — the §5.1 interruption-storm head-of-line fix). Mirrors the
    engine's actual admission rules: only ctx-1 tokens re-prefill (the
    last generated token is fed to decode, ``Engine._prefill_tokens``),
    and when max_len > 0 and the padded span ceil(toks/chunk)*chunk would
    exceed it the engine single-shots (``Engine._use_chunked``)."""
    pre, dec = stage_latencies(spec, placement, 1, max(16, ctx), 1)
    total = max(pre)
    toks = max(ctx - 1, 1)
    if chunk and 0 < chunk < toks:
        n_chunks = -(-toks // chunk)
        if max_len <= 0 or n_chunks * chunk <= max_len:
            total += (n_chunks - 1) * max(dec)
    return total / max(efficiency, 1e-3)


def transfer_seconds(spec: ModelSpec, placement: Placement, ctx: int
                     ) -> float:
    nbytes = kv_bytes_for_ctx(spec, ctx)
    link = placement.stages[0].inter_link()
    return (TRANSFER_SETUP_S + link.alpha_s
            + nbytes / (TRANSFER_EFF * link.beta_bps))


def kv_restore_seconds(spec: ModelSpec, ctx: int,
                       store_bw_bps: float = KV_RESTORE_BW_BPS) -> float:
    """Cost of re-attaching a request's KV blocks from the shared tensor
    store (paged engines publish them during the grace window)."""
    return KV_RESTORE_SETUP_S + kv_bytes_for_ctx(spec, ctx) / store_bw_bps


def preemption_seconds(spec: ModelSpec, ctx: int,
                       store_bw_bps: float = KV_RESTORE_BW_BPS) -> float:
    """Cost of a KV-pool preemption round trip: a demand-paged engine that
    overcommitted its block pool evicts a victim mid-decode, publishing its
    blocks to the node-local store and re-attaching them on re-admission —
    a SELF-INFLICTED kv_restore that also pays the export write (same
    store bandwidth both ways, no grace constraint, no network). Spot
    interruptions hide the publish inside the grace window; a preemption
    has no such window, so both copies land on the serving timeline."""
    return (KV_RESTORE_SETUP_S
            + 2.0 * kv_bytes_for_ctx(spec, ctx) / store_bw_bps)


def decide(spec: ModelSpec, placement: Placement, ctx: int,
           remaining_grace_s: float, policy: str = "hybrid",
           efficiency: float = 1.0, chunk: int = 0,
           max_len: int = 0, store_has_kv: bool = False,
           store_bw_bps: float = KV_RESTORE_BW_BPS,
           store_wait_s: float = 0.0,
           transfer_wait_s: float = 0.0) -> RecoveryDecision:
    """policy: 'recompute' (paper default), 'transfer', or 'hybrid'
    (paper §8.1 future work). chunk > 0 prices recompute under the
    engine's chunked-prefill admission (max_len bounds it as the engine
    does). store_has_kv opens the kv_restore branch for the non-recompute
    policies: the tensor store already holds the request's blocks, so
    restore competes on cost without a grace constraint.

    store_wait_s / transfer_wait_s: queueing delay the respective link
    would impose right now (``NetworkLink.queue_wait_s`` — the discrete-
    event simulator re-derives pricing from link state at decision time).
    0.0 keeps the closed-form uncontended-limit costs. A contended wire
    eats into the grace budget too, so ``fits_grace`` is evaluated on the
    waited transfer time."""
    rc = recompute_seconds(spec, placement, ctx, efficiency, chunk=chunk,
                           max_len=max_len)
    tr = transfer_seconds(spec, placement, ctx) + max(0.0, transfer_wait_s)
    kv = (kv_restore_seconds(spec, ctx, store_bw_bps)
          + max(0.0, store_wait_s)) if store_has_kv else float("inf")
    fits = tr <= remaining_grace_s
    if policy == "recompute":
        mech = "recompute"
    elif policy == "transfer":
        if kv < tr or (kv < float("inf") and not fits):
            mech = "kv_restore"            # resident blocks beat the wire
        else:
            mech = "transfer" if fits else "recompute"   # safety fallback
    else:
        mech, best = "recompute", rc
        if fits and tr < best:
            mech, best = "transfer", tr
        if kv < best:
            mech, best = "kv_restore", kv
    return RecoveryDecision(mech, rc, tr, fits, kv)
