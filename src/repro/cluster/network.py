"""Network links as first-class contended resources (Helix-style).

The closed-form simulator priced every transfer — replacement-node weight
fetch, KV publish, prefix warm-up — as a constant, which silently assumes
every link is idle.  The §5 fault-tolerance argument is about exactly the
opposite regime: control-plane transfers *overlap* with serving and with
each other, and two warm-ups racing on one store link finish later than
either alone.

``NetworkLink`` models a serialized (FIFO) full-duplex-agnostic pipe:
transmissions queue behind ``busy_until`` and occupy the link back to
back.  Because service order is submission order and rates are constant,
the completion time of a transfer is known at submit time:

    start = max(t_submit, busy_until)
    end   = start + latency_s + nbytes / bw_bps

This keeps the discrete-event simulator deterministic (no re-sorting of
in-flight transfers) while still producing real contention: the *wait*
component (start - submit) is exactly the queueing delay other traffic
imposed.  ``Topology`` wires per-region store links (store ↔ every node
in the region) and pairwise cross-region links.

Uncontended-limit calibration: ``bytes_for_duration`` inverts the service
curve so a transfer submitted on an idle link takes exactly the closed
form's constant (e.g. ``FTConfig.store_load_s``) — the DES then reproduces
the legacy timeline to float precision when nothing contends, which is the
parity gate in tests/test_cluster_des.py.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Transfer:
    """One serialized transmission on a link (all times absolute seconds)."""
    kind: str                 # "warmup" | "kv_publish" | "prefix_warm" | ...
    nbytes: float
    submit_s: float
    start_s: float
    end_s: float
    link: "NetworkLink"

    @property
    def wait_s(self) -> float:
        """Queueing delay imposed by traffic ahead of us on the link."""
        return self.start_s - self.submit_s


class NetworkLink:
    """A bandwidth-limited pipe that serializes its transmissions."""

    def __init__(self, name: str, bw_bps: float, latency_s: float = 0.0):
        self.name = name
        self.bw_bps = float(bw_bps)
        self.latency_s = float(latency_s)
        self.busy_until = 0.0
        # accounting
        self.n_transfers = 0
        self.total_bytes = 0.0
        self.busy_s = 0.0
        self.wait_s = 0.0
        self.by_kind: Dict[str, int] = defaultdict(int)

    def duration_s(self, nbytes: float) -> float:
        return self.latency_s + nbytes / self.bw_bps

    def bytes_for_duration(self, duration_s: float) -> float:
        """Payload size whose uncontended transfer takes ``duration_s``."""
        return max(0.0, duration_s - self.latency_s) * self.bw_bps

    def queue_wait_s(self, t: float) -> float:
        """Wait a transfer submitted now (at ``t``) would incur — the link
        state recovery pricing reads at decision time."""
        return max(0.0, self.busy_until - t)

    def submit(self, t: float, kind: str, nbytes: float) -> Transfer:
        start = max(t, self.busy_until)
        dur = self.duration_s(nbytes)
        end = start + dur
        self.busy_until = end
        self.n_transfers += 1
        self.total_bytes += nbytes
        self.busy_s += dur
        self.wait_s += start - t
        self.by_kind[kind] += 1
        return Transfer(kind, nbytes, t, start, end, self)


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Bandwidth/latency of one link class in a region's topology."""
    bw_bps: float
    latency_s: float = 0.0


# Defaults sized like the paper's store path: ~25 Gbit/s effective to the
# shared tensor store inside a region, ~5 Gbit/s across regions.
STORE_LINK = LinkSpec(bw_bps=25e9 / 8, latency_s=0.05)
CROSS_REGION_LINK = LinkSpec(bw_bps=5e9 / 8, latency_s=0.15)


class Topology:
    """Per-region store links + pairwise cross-region links.

    One store link per region models the shared tensor store's ingress/
    egress NIC — the §5.2 bottleneck every warm-up, KV publish, and prefix
    warm in that region rides.  Cross-region links are created lazily per
    unordered region pair.
    """

    def __init__(self, regions: Optional[Dict[str, LinkSpec]] = None,
                 cross: LinkSpec = CROSS_REGION_LINK):
        self._store_spec: Dict[str, LinkSpec] = dict(regions or {})
        self._cross_spec = cross
        self._store: Dict[str, NetworkLink] = {}
        self._cross: Dict[Tuple[str, str], NetworkLink] = {}

    def store_link(self, region: str = "local") -> NetworkLink:
        if region not in self._store:
            spec = self._store_spec.get(region, STORE_LINK)
            self._store[region] = NetworkLink(f"store:{region}", spec.bw_bps,
                                              spec.latency_s)
        return self._store[region]

    def cross_link(self, a: str, b: str) -> NetworkLink:
        key = (a, b) if a <= b else (b, a)
        if key not in self._cross:
            s = self._cross_spec
            self._cross[key] = NetworkLink(f"xr:{key[0]}<->{key[1]}",
                                           s.bw_bps, s.latency_s)
        return self._cross[key]

    def links(self) -> List[NetworkLink]:
        return list(self._store.values()) + list(self._cross.values())

    def stats(self) -> Dict[str, Dict[str, float]]:
        return {ln.name: {"n": ln.n_transfers, "bytes": ln.total_bytes,
                          "busy_s": ln.busy_s, "wait_s": ln.wait_s}
                for ln in self.links()}
