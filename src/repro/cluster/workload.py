"""Workload generation: Azure-Conversation-like request traces (paper §7).

The paper uses the Azure LLM inference conversation trace (1h, fluctuating
arrivals; after pruning >2048-token inputs: mean input 763, mean output 232,
mean rate 4.67 req/s). We generate a statistically matched trace: lognormal
input/output lengths clipped to [16, 2048] / [8, 1024] with the paper's
means, and a doubly-stochastic (bursty) arrival process.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    s_in: int
    s_out: int


def _lognormal_with_mean(rng, mean: float, sigma: float, size: int):
    mu = math.log(mean) - sigma ** 2 / 2.0
    return rng.lognormal(mu, sigma, size)


def diurnal_rate(t_s: float, peak_factor: float = 2.0,
                 period_s: float = 86400.0, phase_s: float = 0.0) -> float:
    """Smooth day/night multiplier around 1.0: peaks at ``peak_factor``,
    troughs at ``2 - peak_factor`` (floored at 0.1). Multi-region sweeps
    phase-shift this per region so load follows the sun."""
    swing = peak_factor - 1.0
    x = 1.0 + swing * math.sin(2.0 * math.pi * (t_s - phase_s) / period_s)
    return max(0.1, x)


def azure_conversation_like(duration_s: float = 3600.0,
                            rate_rps: float = 4.67,
                            mean_in: float = 763.0,
                            mean_out: float = 232.0,
                            max_in: int = 2048,
                            max_out: int = 1024,
                            burstiness: float = 0.6,
                            seed: int = 0,
                            rate_profile=None) -> List[Request]:
    """Bursty arrivals: piecewise-constant rate modulated by a lognormal
    AR(1) process (15s segments), Poisson within a segment.

    rate_profile: optional ``f(t_s) -> multiplier`` composed on top of the
    AR(1) burstiness (e.g. ``diurnal_rate``) — deterministic macro trend
    over stochastic micro bursts. None keeps the trace bit-identical to
    the pre-profile generator."""
    rng = np.random.RandomState(seed)
    seg = 15.0
    n_seg = int(math.ceil(duration_s / seg))
    # AR(1) log-rate modulation
    log_mod = np.zeros(n_seg)
    for i in range(1, n_seg):
        log_mod[i] = 0.8 * log_mod[i - 1] + rng.normal(0, burstiness * 0.5)
    mod = np.exp(log_mod - np.mean(log_mod))
    mod = mod / np.mean(mod)
    reqs: List[Request] = []
    rid = 0
    for i in range(n_seg):
        lam = rate_rps * mod[i] * seg
        if rate_profile is not None:
            lam *= rate_profile((i + 0.5) * seg)
        n = rng.poisson(lam)
        times = np.sort(rng.uniform(i * seg, min((i + 1) * seg, duration_s),
                                    n))
        s_ins = np.clip(_lognormal_with_mean(rng, mean_in, 0.9, n), 16,
                        max_in).astype(int)
        s_outs = np.clip(_lognormal_with_mean(rng, mean_out, 0.9, n), 8,
                         max_out).astype(int)
        for t, si, so in zip(times, s_ins, s_outs):
            reqs.append(Request(rid, float(t), int(si), int(so)))
            rid += 1
    return reqs


def scale_rate(reqs: List[Request], factor: float) -> List[Request]:
    """Paper §7.2.2: scale arrival *intervals* by ``factor`` (keep pattern)."""
    return [dataclasses.replace(r, rid=i, arrival_s=r.arrival_s * factor)
            for i, r in enumerate(reqs)]


def length_histogram(reqs: List[Request], buckets=None) -> List[List[float]]:
    """Normalized (input-len, output-len) bucket weights of a trace — the
    traffic histogram the $/token placement objective
    (``core.buckets.HistogramCostObjective``) and bucket-aware dispatch
    are parameterized by."""
    from repro.core.buckets import workload_histogram
    return workload_histogram([(r.s_in, r.s_out) for r in reqs], buckets)


def zipf_shared_prompts(n: int, n_prefixes: int = 4, prefix_len: int = 48,
                        suffix_len: int = 8, share_ratio: float = 0.5,
                        vocab: int = 32000, zipf_a: float = 1.2,
                        seed: int = 0) -> List[List[int]]:
    """Token-level prompts with production-like prefix reuse: a
    ``share_ratio`` fraction of prompts opens with one of ``n_prefixes``
    common system prompts (chosen Zipf-distributed, so a few prefixes are
    hot and the tail is cold — the regime where a prefix-sharing KV cache
    pays off), followed by a unique suffix; the rest are fully unique.
    Token ids start at 1 (0 is reserved as pad across the repo)."""
    rng = np.random.RandomState(seed)
    def draw(m):
        return (rng.randint(0, vocab - 1, size=m) + 1).tolist()
    prefixes = [draw(prefix_len) for _ in range(n_prefixes)]
    # Zipf over prefix ranks, truncated to the available set
    ranks = np.arange(1, n_prefixes + 1, dtype=float)
    pz = ranks ** -zipf_a
    pz /= pz.sum()
    prompts: List[List[int]] = []
    for _ in range(n):
        if rng.rand() < share_ratio:
            pick = int(rng.choice(n_prefixes, p=pz))
            prompts.append(prefixes[pick] + draw(suffix_len))
        else:
            prompts.append(draw(prefix_len + suffix_len))
    return prompts
