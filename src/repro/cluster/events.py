"""Typed event core for the discrete-event cluster simulator.

The round-lockstep ``ClusterSim`` grew a priority queue organically —
``(t, seq, kind_str, payload)`` tuples dispatched through an if/elif
ladder.  That shape cannot express what the §5 fault-tolerance claims
actually depend on: *overlapping* control-plane work (a replacement-node
weight fetch racing a KV publish on the same store link) and resources
whose state at event time changes the cost of the next decision.

This module is the Helix-style core (SNIPPETS.md §3): a frozen ``Event``
hierarchy, a stable-ordered ``EventQueue``, and a ``dispatch`` loop that
routes each popped event to the handler registered for its type.  The
simulator owns the handlers; this module owns ordering and dispatch, so
event semantics live in exactly one place and new event kinds (transfers,
region-correlated preemptions) are a dataclass + a handler, not another
elif arm.

Ordering contract: events pop by (time, insertion sequence) — ties break
FIFO, which the parity gate in tests/test_cluster_des.py relies on (the
closed-form and networked paths must interleave identically in the
uncontended limit).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class Event:
    """Base class; concrete events below carry their payloads."""


@dataclasses.dataclass(frozen=True)
class Arrive(Event):
    """A request enters the cluster (payload: simulator ReqState)."""
    req: object


@dataclasses.dataclass(frozen=True)
class Interrupt(Event):
    """A spot pool reclaims ``count`` instances (availability delta < 0)."""
    pool: str
    count: int = 1


@dataclasses.dataclass(frozen=True)
class Revive(Event):
    """A replaced pipeline comes back up (its warm-up completed)."""
    pid: int


@dataclasses.dataclass(frozen=True)
class Wake(Event):
    """A pipeline should run its next scheduling iteration."""
    pid: int


@dataclasses.dataclass(frozen=True)
class TransferDone(Event):
    """A network transfer finished occupying its link (payload:
    ``network.Transfer``).  Completion times are known at submit for
    serialized links; this event closes the transfer's lifecycle on the
    queue so handlers can account per-kind completions in time order."""
    transfer: object


Handler = Callable[[float, Event], None]


class EventQueue:
    """Priority queue of (time, event) with FIFO tie-breaking."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()

    def push(self, t: float, ev: Event) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), ev))

    def pop(self) -> Tuple[float, Event]:
        t, _, ev = heapq.heappop(self._heap)
        return t, ev

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


def dispatch(queue: EventQueue, handlers: Dict[Type[Event], Handler],
             until: float = float("inf")) -> float:
    """Drain ``queue`` through ``handlers`` until it empties or the next
    event lies beyond ``until``.  Returns the time of the last handled
    event (0.0 if none ran).  Unregistered event types raise — a missing
    handler is a simulator bug, not an ignorable event."""
    t_last = 0.0
    while queue:
        t, ev = queue.pop()
        if t > until:
            break
        handlers[type(ev)](t, ev)
        t_last = t
    return t_last
