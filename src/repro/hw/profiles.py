"""Hardware device/instance profiles.

The paper (Table 1) grades GPUs by peak BF16 FLOPS, memory capacity and HBM
bandwidth, plus per-instance network characteristics (alpha/beta for both
intra-stage TP fabric and inter-stage PP fabric) and spot/on-demand pricing.

We carry BOTH the paper's AWS GPU instances (to reproduce its evaluation) and
TPU profiles (our target runtime). The estimator/optimizer only ever sees
``DeviceProfile``/``InstanceProfile`` and is agnostic to the vendor.

Effective (calibrated) numbers differ from white-paper peaks (paper §7.1.5:
L4 reports 121 TFLOPS but measures ~55). Profiles store *peak* values;
``hw.calibration`` produces *effective* values and ``derate()`` applies them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A single accelerator die."""

    name: str
    mem_gb: float                 # HBM capacity
    flops_bf16: float             # peak dense BF16 FLOP/s
    mem_bw: float                 # HBM bytes/s
    # Intra-stage fabric (TP): PCIe/NVLink on GPU, ICI on TPU.
    intra_alpha_s: float          # per-message latency, seconds
    intra_beta_bps: float         # bytes/s per device
    kind: str = "gpu"             # "gpu" | "tpu"

    def derate(self, flops_scale: float = 1.0, bw_scale: float = 1.0,
               net_scale: float = 1.0) -> "DeviceProfile":
        return dataclasses.replace(
            self,
            flops_bf16=self.flops_bf16 * flops_scale,
            mem_bw=self.mem_bw * bw_scale,
            intra_beta_bps=self.intra_beta_bps * net_scale,
        )


@dataclasses.dataclass(frozen=True)
class InstanceProfile:
    """A rentable node: N devices of one type + inter-node fabric + price."""

    name: str
    device: DeviceProfile
    num_devices: int
    # Inter-stage fabric (PP): Ethernet/EFA on AWS, DCN between TPU pods.
    inter_alpha_s: float
    inter_beta_bps: float
    price_ondemand_hr: float
    price_spot_hr: float
    spot_pool: str = ""           # pools with correlated interruption

    @property
    def mem_bytes_total(self) -> float:
        return self.num_devices * self.device.mem_gb * 1e9

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        return f"{self.name}({self.num_devices}x{self.device.name})"


GB = 1e9
TFLOPS = 1e12

# ---------------------------------------------------------------------------
# Paper Table 1 GPUs. FLOPS are BF16 non-sparse peaks; paper's calibration
# found effective ~0.45-0.6x of peak — DEFAULT_DERATE reflects that (§7.1.5).
# ---------------------------------------------------------------------------
L4 = DeviceProfile("L4", 24, 121 * TFLOPS, 300 * GB, 5e-6, 32 * GB)
A10G = DeviceProfile("A10G", 24, 70 * TFLOPS, 600 * GB, 5e-6, 32 * GB)
L40S = DeviceProfile("L40S", 48, 362 * TFLOPS, 864 * GB, 5e-6, 32 * GB)
A100_40 = DeviceProfile("A100", 40, 312 * TFLOPS, 1555 * GB, 3e-6, 300 * GB)
H100 = DeviceProfile("H100", 80, 989 * TFLOPS, 3350 * GB, 3e-6, 450 * GB)
B200 = DeviceProfile("B200", 180, 4500 * TFLOPS, 7700 * GB, 3e-6, 900 * GB)

# TPU profiles (target runtime). ICI is the intra-"stage" fabric; DCN the
# inter-pod fabric. v5e numbers come from the brief: 197 bf16 TFLOP/s,
# 819 GB/s HBM, ~50 GB/s per ICI link.
TPU_V5E = DeviceProfile("TPUv5e", 16, 197 * TFLOPS, 819 * GB, 1e-6, 50 * GB,
                        kind="tpu")
TPU_V4 = DeviceProfile("TPUv4", 32, 275 * TFLOPS, 1228 * GB, 1e-6, 100 * GB,
                       kind="tpu")
TPU_V5P = DeviceProfile("TPUv5p", 95, 459 * TFLOPS, 2765 * GB, 1e-6, 100 * GB,
                        kind="tpu")

# Paper's effective-vs-peak derates observed during calibration (§7.1.5).
DEFAULT_DERATE = {
    "L4": (55.0 / 121.0, 0.85),     # (flops_scale, bw_scale)
    "A10G": (0.60, 0.85),
    "L40S": (0.55, 0.85),
    "A100": (0.60, 0.80),
    "H100": (0.60, 0.80),
    "B200": (0.55, 0.80),
    "TPUv5e": (0.72, 0.90),
    "TPUv4": (0.70, 0.90),
    "TPUv5p": (0.70, 0.90),
}


def effective(dev: DeviceProfile) -> DeviceProfile:
    """Apply the default calibration derate (stand-in for hw.calibration)."""
    fs, bs = DEFAULT_DERATE.get(dev.name, (0.6, 0.85))
    return dev.derate(flops_scale=fs, bw_scale=bs)


# ---------------------------------------------------------------------------
# AWS instances used in the paper's evaluation cluster (§7 Model and Cluster
# Setup): 3x g6.12xlarge (4xL4), 2x g5.12xlarge (4xA10G), 4x g6e.xlarge
# (1xL40S). Prices are us-west-2 on-demand / representative spot.
# ---------------------------------------------------------------------------
def _inst(name, dev, n, od, spot, pool, inter_beta=25 * GB / 8 * 1.0):
    # Default inter-node: 25 Gbps-class Ethernet unless overridden.
    return InstanceProfile(name, dev, n, 5e-5, inter_beta, od, spot, pool)


AWS_INSTANCES: Dict[str, InstanceProfile] = {
    "g6.12xlarge": _inst("g6.12xlarge", L4, 4, 4.601, 1.61, "g6",
                         inter_beta=40e9 / 8),
    "g5.12xlarge": _inst("g5.12xlarge", A10G, 4, 5.672, 1.98, "g5",
                         inter_beta=40e9 / 8),
    "g6e.xlarge": _inst("g6e.xlarge", L40S, 1, 1.861, 0.65, "g6e",
                        inter_beta=20e9 / 8),
    "g6e.12xlarge": _inst("g6e.12xlarge", L40S, 4, 10.493, 3.67, "g6e",
                          inter_beta=100e9 / 8),
    "g6.48xlarge": _inst("g6.48xlarge", L4, 8, 13.350, 4.67, "g6",
                         inter_beta=100e9 / 8),
    "g5.48xlarge": _inst("g5.48xlarge", A10G, 8, 16.288, 5.70, "g5",
                         inter_beta=100e9 / 8),
    "g6e.48xlarge": _inst("g6e.48xlarge", L40S, 8, 30.131, 10.55, "g6e",
                          inter_beta=400e9 / 8),
    "p4d.24xlarge": _inst("p4d.24xlarge", A100_40, 8, 32.773, 11.47, "p4d",
                          inter_beta=400e9 / 8),
    "p5.48xlarge": _inst("p5.48xlarge", H100, 8, 98.32, 34.41, "p5",
                         inter_beta=3200e9 / 8),
}

# TPU "instances": a slice of chips rentable as one unit. Preemptible slices
# are GCP's spot analog. Inter = DCN per host (~25 GB/s).
TPU_INSTANCES: Dict[str, InstanceProfile] = {
    "v5e-4": InstanceProfile("v5e-4", TPU_V5E, 4, 2e-5, 25 * GB, 4.8, 1.7,
                             "v5e"),
    "v5e-8": InstanceProfile("v5e-8", TPU_V5E, 8, 2e-5, 25 * GB, 9.6, 3.4,
                             "v5e"),
    "v4-8": InstanceProfile("v4-8", TPU_V4, 8, 2e-5, 25 * GB, 12.9, 4.5,
                            "v4"),
    "v5p-8": InstanceProfile("v5p-8", TPU_V5P, 8, 2e-5, 25 * GB, 33.1, 11.6,
                             "v5p"),
}

ALL_INSTANCES: Dict[str, InstanceProfile] = {**AWS_INSTANCES, **TPU_INSTANCES}


def paper_cluster() -> Dict[str, int]:
    """The paper's 24-GPU evaluation cluster (counts per instance type)."""
    return {"g6.12xlarge": 3, "g5.12xlarge": 2, "g6e.xlarge": 4}


def get_instance(name: str) -> InstanceProfile:
    return ALL_INSTANCES[name]
