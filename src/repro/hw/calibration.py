"""One-time hardware calibration (paper §4.1.3 / §7.1.5, Table 4).

The paper found white-paper peaks diverge from effective rates (L4: 121
reported vs ~55 measured TFLOPS), so ShuntServe calibrates each device type
once with three microbenchmarks that saturate distinct resources:

  * compute-bound GEMM      -> effective FLOP/s
  * memory-bound GEMV       -> effective HBM bytes/s
  * network-bound AllReduce -> effective link bytes/s (+ latency alpha)

We run the same protocol with JAX on whatever backend is present (CPU here,
TPU in production). Per the paper, each feature is measured at multiple batch
sizes and summarized by the **median**, giving one scalar per feature that is
invariant to serving configuration.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.hw.profiles import DeviceProfile


@dataclasses.dataclass
class CalibrationResult:
    device_name: str
    eff_flops: float
    eff_mem_bw: float
    eff_net_bps: float
    net_alpha_s: float
    wall_time_s: float
    samples: Dict[str, List[float]]

    def apply(self, dev: DeviceProfile) -> DeviceProfile:
        return dataclasses.replace(
            dev,
            flops_bf16=self.eff_flops,
            mem_bw=self.eff_mem_bw,
            intra_beta_bps=self.eff_net_bps,
            intra_alpha_s=self.net_alpha_s,
        )


def _time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def calibrate_gemm(sizes: Sequence[int] = (256, 512, 1024),
                   dtype=jnp.float32) -> List[float]:
    """Effective FLOP/s from square matmuls (2*m*n*k FLOPs each)."""
    rates = []
    f = jax.jit(lambda a, b: a @ b)
    for n in sizes:
        key = jax.random.PRNGKey(n)
        a = jax.random.normal(key, (n, n), dtype)
        b = jax.random.normal(key, (n, n), dtype)
        dt = _time_fn(f, a, b)
        rates.append(2.0 * n ** 3 / dt)
    return rates


def calibrate_gemv(sizes: Sequence[int] = (1024, 2048, 4096),
                   dtype=jnp.float32) -> List[float]:
    """Effective HBM bytes/s from matrix-vector products (reads n*n matrix)."""
    rates = []
    f = jax.jit(lambda a, x: a @ x)
    for n in sizes:
        key = jax.random.PRNGKey(n)
        a = jax.random.normal(key, (n, n), dtype)
        x = jax.random.normal(key, (n,), dtype)
        dt = _time_fn(f, a, x)
        rates.append(n * n * a.dtype.itemsize / dt)
    return rates


def calibrate_allreduce(sizes_bytes: Sequence[int] = (1 << 16, 1 << 20),
                        dtype=jnp.float32) -> Dict[str, float]:
    """Effective collective beta (bytes/s) and alpha (s).

    With >=2 local devices uses a real psum over a mesh; on a single device
    falls back to a copy-based bound (the collective degenerates).
    Fits (alpha, beta) by least squares over message sizes:
        t(N) = alpha + N / beta
    """
    devs = jax.devices()
    times, sizes = [], []
    if len(devs) >= 2:
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = Mesh(np.array(devs), ("x",))
        for nbytes in sizes_bytes:
            n = max(1, nbytes // jnp.dtype(dtype).itemsize)
            x = jnp.ones((len(devs), n), dtype)
            f = jax.jit(
                shard_map(lambda a: jax.lax.psum(a, "x"), mesh=mesh,
                          in_specs=P("x", None), out_specs=P("x", None)))
            dt = _time_fn(f, x)
            times.append(dt)
            sizes.append(nbytes)
    else:
        for nbytes in sizes_bytes:
            n = max(1, nbytes // jnp.dtype(dtype).itemsize)
            x = jnp.ones((n,), dtype)
            f = jax.jit(lambda a: a + 1.0)
            dt = _time_fn(f, x)
            times.append(dt)
            sizes.append(nbytes)
    # Least-squares fit of t = alpha + N/beta.
    A = np.stack([np.ones(len(sizes)), np.array(sizes, float)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.array(times), rcond=None)
    alpha = max(float(coef[0]), 1e-7)
    inv_beta = max(float(coef[1]), 1e-15)
    return {"alpha_s": alpha, "beta_bps": 1.0 / inv_beta}


def calibrate(device_name: str = "local",
              gemm_sizes: Sequence[int] = (256, 512, 1024),
              gemv_sizes: Sequence[int] = (1024, 2048, 4096),
              net_sizes: Sequence[int] = (1 << 16, 1 << 20),
              ) -> CalibrationResult:
    """Full calibration pass; median-summarized per the paper."""
    t0 = time.perf_counter()
    gemm = calibrate_gemm(gemm_sizes)
    gemv = calibrate_gemv(gemv_sizes)
    net = calibrate_allreduce(net_sizes)
    wall = time.perf_counter() - t0
    return CalibrationResult(
        device_name=device_name,
        eff_flops=statistics.median(gemm),
        eff_mem_bw=statistics.median(gemv),
        eff_net_bps=net["beta_bps"],
        net_alpha_s=net["alpha_s"],
        wall_time_s=wall,
        samples={"gemm_flops": gemm, "gemv_bps": gemv},
    )
