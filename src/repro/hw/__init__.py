from repro.hw.calibration import CalibrationResult, calibrate
from repro.hw.profiles import (ALL_INSTANCES, AWS_INSTANCES, TPU_INSTANCES,
                               DeviceProfile, InstanceProfile, effective,
                               get_instance, paper_cluster)

__all__ = [
    "ALL_INSTANCES", "AWS_INSTANCES", "TPU_INSTANCES", "DeviceProfile",
    "InstanceProfile", "effective", "get_instance", "paper_cluster",
    "CalibrationResult", "calibrate",
]
