from repro.hw.profiles import (ALL_INSTANCES, AWS_INSTANCES, TPU_INSTANCES,
                               DeviceProfile, InstanceProfile, effective,
                               get_instance, paper_cluster)
from repro.hw.calibration import CalibrationResult, calibrate

__all__ = [
    "ALL_INSTANCES", "AWS_INSTANCES", "TPU_INSTANCES", "DeviceProfile",
    "InstanceProfile", "effective", "get_instance", "paper_cluster",
    "CalibrationResult", "calibrate",
]
