"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,Sq,nh,d), k/v: (B,Sk,nkv,d) -> (B,Sq,nh,d). GQA by head
    grouping; causal assumes q and k start at position 0."""
    from repro.models.attention import causal_mask, sdpa
    mask = causal_mask(q.shape[1], k.shape[1], 0, window) if causal else None
    return sdpa(q, k, v, mask)


def decode_attention_ref(q: jax.Array, cache_k: jax.Array,
                         cache_v: jax.Array, pos: jax.Array,
                         window: Optional[int] = None) -> jax.Array:
    """q: (B,1,nh,d) vs linear cache (B,S,nkv,d); pos scalar or (B,)."""
    from repro.models.attention import decode_attention
    return decode_attention(q, cache_k, cache_v, pos, None, window=window)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, chunk: int = 64
                 ) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD: x (B,S,nh,hd), dt (B,S,nh) (post-softplus), a (nh,)<0,
    b/c (B,S,N). Returns (y, final state (B,nh,hd,N) fp32)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, a, b, c, chunk=chunk)


def ssd_scan_sequential_ref(x, dt, a, b, c):
    """O(S) sequential recurrence — the independent second oracle that the
    chunked algorithm itself is validated against."""
    from repro.models.ssm import ssd_step
    B, S, nh, hd = x.shape
    n = b.shape[-1]
    h = jnp.zeros((B, nh, hd, n), jnp.float32)
    ys = []
    for t in range(S):
        y, h = ssd_step(x[:, t], dt[:, t], a, b[:, t], c[:, t], h)
        ys.append(y)
    return jnp.stack(ys, axis=1), h
