"""Flash chunk-prefill attention Pallas TPU kernels (a C-token chunk of
new queries against the full KV history written so far).

One online-softmax kernel body serves every chunked-prefill read path:

* ``chunk_attention`` — contiguous cache (B, S, nkv, d). The chunk's C
  queries sit at absolute positions ``bases[b] + j``; KV blocks stream
  through VMEM on the innermost grid axis with running-softmax scratch,
  the same q-tiling as ``flash_attention`` but against a cache operand.
* ``chunk_attention_paged`` — block-pool cache (n_blocks, block, nkv, d)
  plus per-row block tables walked via scalar prefetch (the
  ``PrefetchScalarGridSpec`` pattern of ``decode_attention_paged``): the
  BlockSpec index_map reads ``tbl[b, ik]`` so each grid step DMAs exactly
  the pool block backing virtual positions ``[ik*block, (ik+1)*block)``
  of row ``b``. No gathered page view is ever materialized — the jnp
  oracle's O(B*max_blocks*block) ``_gather_pages`` copy disappears.

``bases`` is a scalar (engine chunk groups share one base) or per-row
(the prefix-share suffix path); masking is causal against the prefix
(``k_pos <= bases[b] + j``) with optional sliding-window attention.
Covers decode as the C=1 special case, so the one body also backs
``prefill_suffix``'s attention.

Debug ``probe`` mode (KV sanitizer follow-up): an extra (B, nh) output
carries the max |K|/|V| magnitude seen at *readable* (mask-valid)
positions; the ops wrapper checkifies it against ``KV_POISON`` so a
stale block-table entry fires at the op itself instead of only via
final byte-identity.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _chunk_kernel(bases_ref, q_ref, k_ref, v_ref, o_ref, *rest, scale: float,
                  window: Optional[int], block_q: int, block_kv: int,
                  n_kv_blocks: int, probe: bool):
    if probe:
        p_ref, m_scr, l_scr, acc_scr = rest
    else:
        p_ref, (m_scr, l_scr, acc_scr) = None, rest
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    if probe:
        @pl.when((ik == 0) & (iq == 0))
        def _init_probe():
            p_ref[...] = jnp.zeros_like(p_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bkv, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # ik indexes VIRTUAL blocks of this row; in the paged layout the pool
    # block holding them was selected by the index_map through the table
    q_pos = (bases_ref[ib] + iq * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    if probe:
        readable = jnp.any(mask, axis=0)                   # (bkv,)
        mag = jnp.maximum(jnp.max(jnp.abs(k), axis=1),
                          jnp.max(jnp.abs(v), axis=1))
        p_ref[0, 0] = jnp.maximum(
            p_ref[0, 0], jnp.max(jnp.where(readable, mag, 0.0)))

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[...] = m_cur

    @pl.when(ik == n_kv_blocks - 1)
    def _out():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _chunk_paged_kernel(tbl_ref, bases_ref, *rest, **kw):
    # the block table is consumed by the BlockSpec index maps only
    del tbl_ref
    _chunk_kernel(bases_ref, *rest, **kw)


def _norm_bases(bases, b: int) -> jax.Array:
    bases = jnp.asarray(bases, jnp.int32)
    if bases.ndim == 0:
        bases = jnp.broadcast_to(bases, (b,))
    return bases


def _out_tree(b, c, nh, d, dtype, block_q, nargs, probe):
    """(out_shape, out_specs) — plus the probe max-|KV| row when armed.
    ``nargs`` index-map arity matches the grid spec's scalar prefetch."""
    if nargs == 2:
        o_map = lambda ib, ih, iq, ik, tbl, bases: (ib, iq, ih, 0)
        p_map = lambda ib, ih, iq, ik, tbl, bases: (ib, ih)
    else:
        o_map = lambda ib, ih, iq, ik, bases: (ib, iq, ih, 0)
        p_map = lambda ib, ih, iq, ik, bases: (ib, ih)
    shapes = [jax.ShapeDtypeStruct((b, c, nh, d), dtype)]
    specs = [pl.BlockSpec((1, block_q, 1, d), o_map)]
    if probe:
        shapes.append(jax.ShapeDtypeStruct((b, nh), jnp.float32))
        specs.append(pl.BlockSpec((1, 1), p_map))
        return shapes, specs
    return shapes[0], specs[0]


_SCRATCH_F32 = jnp.float32


def _scratch(block_q: int, d: int):
    return [
        pltpu.VMEM((block_q,), _SCRATCH_F32),
        pltpu.VMEM((block_q,), _SCRATCH_F32),
        pltpu.VMEM((block_q, d), _SCRATCH_F32),
    ]


def chunk_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                    bases, *, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    probe: bool = False, interpret: bool = False):
    """q: (B,C,nh,d); cache_k/v: (B,S,nkv,d) with the chunk already
    written; bases scalar or (B,) — row b's queries sit at absolute
    positions ``bases[b] + [0, C)``. Returns o, or (o, probe_max) when
    ``probe`` is armed."""
    b, c, nh, d = q.shape
    s, nkv = cache_k.shape[1], cache_k.shape[2]
    assert nh % nkv == 0
    g = nh // nkv
    block_q = min(block_q, c)
    block_kv = min(block_kv, s)
    assert c % block_q == 0, (c, block_q)
    assert s % block_kv == 0, (s, block_kv)
    nq = c // block_q
    nk = s // block_kv
    bases = _norm_bases(bases, b)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_chunk_kernel, scale=scale, window=window,
                               block_q=block_q, block_kv=block_kv,
                               n_kv_blocks=nk, probe=probe)
    out_shape, out_specs = _out_tree(b, c, nh, d, q.dtype, block_q,
                                     nargs=1, probe=probe)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                      # query base positions
        grid=(b, nh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda ib, ih, iq, ik, bases: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, iq, ik, bases, g=g:
                         (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, iq, ik, bases, g=g:
                         (ib, ik, ih // g, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=_scratch(block_q, d),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(bases, q, cache_k, cache_v)


def chunk_attention_paged(q: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, block_tbl: jax.Array,
                          bases, *, window: Optional[int] = None,
                          block_q: int = 128, probe: bool = False,
                          interpret: bool = False):
    """q: (B,C,nh,d); cache_k/v: (n_blocks, block, nkv, d) pool with the
    chunk already written; block_tbl: (B, max_blocks) int32 pool-block id
    per virtual block (0 = trash block, masked); bases scalar or (B,).
    Returns o, or (o, probe_max) when ``probe`` is armed."""
    b, c, nh, d = q.shape
    block, nkv = cache_k.shape[1], cache_k.shape[2]
    assert nh % nkv == 0
    g = nh // nkv
    mb = block_tbl.shape[1]
    block_q = min(block_q, c)
    assert c % block_q == 0, (c, block_q)
    nq = c // block_q
    bases = _norm_bases(bases, b)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_chunk_paged_kernel, scale=scale,
                               window=window, block_q=block_q,
                               block_kv=block, n_kv_blocks=mb, probe=probe)
    out_shape, out_specs = _out_tree(b, c, nh, d, q.dtype, block_q,
                                     nargs=2, probe=probe)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # block table + bases
        grid=(b, nh, nq, mb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda ib, ih, iq, ik, tbl, bases: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block, 1, d),
                         lambda ib, ih, iq, ik, tbl, bases, g=g:
                         (tbl[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, block, 1, d),
                         lambda ib, ih, iq, ik, tbl, bases, g=g:
                         (tbl[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=_scratch(block_q, d),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tbl.astype(jnp.int32), bases, q, cache_k, cache_v)
