# Pallas TPU kernels for the serving hot spots (DESIGN.md §8):
#   flash_attention.py  — prefill attention (online softmax, causal/SWA, GQA)
#   decode_attention.py — single-token GQA decode, contiguous or paged KV
#   chunk_attention.py  — flash chunk-prefill vs a contiguous or paged prefix
#   ssd_scan.py         — Mamba2 SSD chunked scan
# ops.py — jit'd dispatch (interpret=True on CPU); ref.py — pure-jnp oracles.
