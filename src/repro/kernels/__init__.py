# Pallas TPU kernels for the serving hot spots (DESIGN.md §8):
#   flash_attention.py  — prefill attention (online softmax, causal/SWA, GQA)
#   decode_attention.py — single-token GQA decode vs a contiguous KV cache
#   ssd_scan.py         — Mamba2 SSD chunked scan
# ops.py — jit'd dispatch (interpret=True on CPU); ref.py — pure-jnp oracles.
