"""Mamba2 SSD chunked-scan Pallas TPU kernel.

State-space duality: within a chunk the recurrence becomes a masked
quadratic form (MXU matmuls); across chunks a small (head_dim x state)
recurrence carries in VMEM scratch. Grid = (batch, head, chunk) with the
chunk axis innermost (TPU executes it sequentially, so scratch persists).
Every contraction is a 2-D dot — MXU-clean; chunk length defaults to 64 so
the (Q x Q) decay matrix and chunk tiles stay well inside VMEM.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, hd)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0]                                       # scalar < 0
    bmat = b_ref[0].astype(jnp.float32)                # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)                # (Q, N)

    da = dt * a                                        # (Q,)
    l = jnp.cumsum(da)                                 # (Q,)
    li = l[:, None]
    lj = l[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = jq <= iq
    decay = jnp.where(mask, jnp.exp(li - lj), 0.0)     # (Q, Q)
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = cb * decay
    xdt = x * dt[:, None]                              # (Q, hd)
    y_intra = jax.lax.dot_general(m, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(l_i) * c_i . h
    h = h_scr[...]                                     # (hd, N)
    ch = jax.lax.dot_general(cmat, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, hd)
    y = y_intra + jnp.exp(l)[:, None] * ch
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    # state update: h' = h * exp(l_last) + (xdt * w)^T @ b,  w = exp(l_last-l)
    l_last = l[chunk - 1]
    w = jnp.exp(l_last - l)                            # (Q,)
    hb = jax.lax.dot_general((xdt * w[:, None]), bmat,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (hd, N)
    h_scr[...] = h * jnp.exp(l_last) + hb

    @pl.when(ic == n_chunks - 1)
    def _out():
        hout_ref[0, 0, :, :] = h_scr[...]


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int = 64, interpret: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,nh,hd), dt: (B,S,nh) (post-softplus), a: (nh,) negative,
    b/c: (B,S,N). Returns (y (B,S,nh,hd), h_final (B,nh,hd,N) fp32)."""
    B, S, nh, hd = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_pad = S + pad
    nc = s_pad // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, hd, N), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, s_pad, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    if pad:
        y = y[:, :S]
    return y, h
