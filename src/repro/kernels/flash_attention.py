"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax tiling over (block_q x block_kv) with explicit BlockSpec VMEM
placement, causal + sliding-window masking, GQA via head->kv-head mapping in
the index maps. The KV-block loop is the innermost grid dimension: TPU
executes it sequentially per (batch, head, q-block), so the running max /
denominator / accumulator live in VMEM scratch across iterations — the
standard Pallas accumulation pattern (a TPU-native re-think of the CUDA
flash kernel: DMA-prefetched VMEM tiles + MXU matmuls instead of SMEM tiles
+ warp shuffles).

MXU alignment: block_q/block_kv default 128, head_dim padded to 128 by the
wrapper (ops.py) when needed.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               block_q: int, block_kv: int, n_kv_blocks: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bkv, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_cur

    @pl.when(ik == n_kv_blocks - 1)
    def _out():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B,Sq,nh,d), k/v: (B,Sk,nkv,d) -> (B,Sq,nh,d)."""
    b, sq, nh, d = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    assert nh % nkv == 0, (nh, nkv)
    g = nh // nkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0, (sq, sk)
    nq, nk = sq // block_q, sk // block_kv
    grid = (b, nh, nq, nk)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, iq, ik, g=g: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, nh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
