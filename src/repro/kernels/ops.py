"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation. On TPU they compile to
Mosaic. Models call these through ``use_pallas=True``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import chunk_attention as _ca
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _check_probe(out, probe: bool):
    """Discharge a kernel's sanitizer probe output: checkify the max
    readable |K|/|V| magnitude against the freed-block poison sentinel.
    The surrounding dispatch (engine jit) is checkify-transformed whenever
    the probe is armed."""
    if not probe:
        return out
    import jax.numpy as jnp
    from jax.experimental import checkify
    from repro.serving.kv_blocks import KV_POISON
    o, pmax = out
    worst = jnp.max(pmax)
    checkify.check(worst < KV_POISON,
                   "poisoned KV block read through the block table "
                   "(max readable |kv| = {m})", m=worst)
    return o


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


def decode_attention(q, cache_k, cache_v, pos, slot_pos=None, *,
                     window: Optional[int] = None, block_kv: int = 128):
    """Matches models.attention.decode_attention's signature; ring caches
    (slot_pos) fall back to the jnp path — the kernel serves linear caches."""
    if slot_pos is not None:
        from repro.models.attention import decode_attention as jref
        return jref(q, cache_k, cache_v, pos, slot_pos, window=window)
    return _decode_jit(q, cache_k, cache_v, pos, window=window,
                       block_kv=block_kv)


@functools.partial(jax.jit, static_argnames=("window", "block_kv"))
def _decode_jit(q, cache_k, cache_v, pos, *, window, block_kv):
    return _da.decode_attention(q, cache_k, cache_v, pos, window=window,
                                block_kv=min(block_kv, cache_k.shape[1]),
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "probe"))
def decode_attention_paged(q, cache_k, cache_v, block_tbl, pos, *,
                           window: Optional[int] = None,
                           probe: bool = False):
    """Block-pool decode kernel; matches
    models.attention.decode_attention_paged's signature."""
    out = _da.decode_attention_paged(q, cache_k, cache_v, block_tbl, pos,
                                     window=window, probe=probe,
                                     interpret=_interpret())
    return _check_probe(out, probe)


@functools.partial(jax.jit, static_argnames=("window", "block_q",
                                             "block_kv"))
def chunk_attention(q, cache_k, cache_v, bases, *,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128):
    """Flash chunk kernel against a linear cache. ``bases`` is scalar or
    (B,): row b's C queries sit at absolute positions ``bases[b]+[0,C)``.
    Non-tiling shapes fall back to the jnp oracle (shape checks are
    trace-time static)."""
    c, s = q.shape[1], cache_k.shape[1]
    if c % min(block_q, c) or s % min(block_kv, s):
        from repro.models import attention as _attn
        import jax.numpy as jnp
        bases = jnp.asarray(bases, jnp.int32)
        q_pos = (jnp.broadcast_to(bases, (q.shape[0],))[:, None]
                 + jnp.arange(c)[None] if bases.ndim == 0
                 else bases[:, None] + jnp.arange(c)[None])
        return _attn.chunk_attention(q, cache_k, cache_v, q_pos,
                                     window=window)
    return _ca.chunk_attention(q, cache_k, cache_v, bases, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "block_q", "probe"))
def chunk_attention_paged(q, cache_k, cache_v, block_tbl, bases, *,
                          window: Optional[int] = None, block_q: int = 128,
                          probe: bool = False):
    """Flash chunk kernel against the block pool, walking the block table
    via scalar prefetch — no gathered page view is materialized. Covers
    the engine chunk path (scalar base) and the prefix-share suffix path
    (per-row bases)."""
    c = q.shape[1]
    if c % min(block_q, c):
        from repro.models import attention as _attn
        import jax.numpy as jnp
        bases = jnp.asarray(bases, jnp.int32)
        q_pos = (jnp.broadcast_to(bases, (q.shape[0],))[:, None]
                 + jnp.arange(c)[None] if bases.ndim == 0
                 else bases[:, None] + jnp.arange(c)[None])
        return _attn.chunk_attention_paged(q, cache_k, cache_v, block_tbl,
                                           q_pos, window=window, probe=probe)
    out = _ca.chunk_attention_paged(q, cache_k, cache_v, block_tbl, bases,
                                    window=window, block_q=block_q,
                                    probe=probe, interpret=_interpret())
    return _check_probe(out, probe)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, b, c, chunk: int = 64) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_interpret())
