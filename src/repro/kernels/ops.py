"""Jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation. On TPU they compile to
Mosaic. Models call these through ``use_pallas=True``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


def decode_attention(q, cache_k, cache_v, pos, slot_pos=None, *,
                     window: Optional[int] = None, block_kv: int = 128):
    """Matches models.attention.decode_attention's signature; ring caches
    (slot_pos) fall back to the jnp path — the kernel serves linear caches."""
    if slot_pos is not None:
        from repro.models.attention import decode_attention as jref
        return jref(q, cache_k, cache_v, pos, slot_pos, window=window)
    return _decode_jit(q, cache_k, cache_v, pos, window=window,
                       block_kv=block_kv)


@functools.partial(jax.jit, static_argnames=("window", "block_kv"))
def _decode_jit(q, cache_k, cache_v, pos, *, window, block_kv):
    return _da.decode_attention(q, cache_k, cache_v, pos, window=window,
                                block_kv=min(block_kv, cache_k.shape[1]),
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window",))
def decode_attention_paged(q, cache_k, cache_v, block_tbl, pos, *,
                           window: Optional[int] = None):
    """Block-pool decode kernel; matches
    models.attention.decode_attention_paged's signature."""
    return _da.decode_attention_paged(q, cache_k, cache_v, block_tbl, pos,
                                      window=window, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a, b, c, chunk: int = 64) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_interpret())
