"""GQA decode attention Pallas TPU kernels (single new token vs KV cache).

Two layouts:

* ``decode_attention`` — contiguous cache (B, S, nkv, d); per-sequence
  validity comes from a position vector, masked while KV blocks stream
  through VMEM with a running-softmax accumulator in scratch. Memory-bound
  by design — the roofline term is the cache scan.
* ``decode_attention_paged`` — block-pool cache (n_blocks, block, nkv, d)
  plus a per-row block table. The grid's KV axis walks the table via
  scalar prefetch: the BlockSpec index_map reads ``tbl[b, ik]`` so each
  grid step DMAs exactly the pool block that backs virtual positions
  ``[ik*block, (ik+1)*block)`` of row ``b`` — TPU-friendly because blocks
  stay contiguous and the gather happens at DMA-descriptor granularity,
  not per-element.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale: float, window: Optional[int], block_kv: int,
                n_kv_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0, :].astype(jnp.float32)              # (d,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bkv, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # (bkv,)

    pos = pos_ref[0]
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_kv,), 0)
    mask = k_pos <= pos
    if window is not None:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)            # (bkv,)
    l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
    acc_scr[0, :] = (acc_scr[0, :] * alpha
                     + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[0] = m_cur

    @pl.when(ik == n_kv_blocks - 1)
    def _out():
        denom = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0, 0, :] = (acc_scr[0, :] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     block_kv: int = 128, interpret: bool = False
                     ) -> jax.Array:
    """q: (B,1,nh,d); cache_k/v: (B,S,nkv,d); pos scalar or (B,) — the
    position of the current (already written) token per sequence."""
    b, _, nh, d = q.shape
    s, nkv = cache_k.shape[1], cache_k.shape[2]
    assert nh % nkv == 0
    g = nh // nkv
    block_kv = min(block_kv, s)
    assert s % block_kv == 0, (s, block_kv)
    nk = s // block_kv
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    pos = pos.astype(jnp.int32)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_dec_kernel, scale=scale, window=window,
                               block_kv=block_kv, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, nh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ik: (ib,)),
            pl.BlockSpec((1, 1, 1, d), lambda ib, ih, ik: (ib, 0, ih, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, ik, g=g: (ib, ik, ih // g, 0)),
            pl.BlockSpec((1, block_kv, 1, d),
                         lambda ib, ih, ik, g=g: (ib, ik, ih // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda ib, ih, ik: (ib, 0, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, nh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, cache_k, cache_v)


def _dec_paged_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                      scale: float, window: Optional[int], block: int,
                      n_virt_blocks: int, probe: bool):
    if probe:
        p_ref, m_scr, l_scr, acc_scr = rest
    else:
        p_ref, (m_scr, l_scr, acc_scr) = None, rest
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        if probe:
            p_ref[...] = jnp.zeros_like(p_ref)

    q = q_ref[0, 0, 0, :].astype(jnp.float32)              # (d,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (block, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale

    # ik indexes VIRTUAL blocks of this row; the pool block holding them was
    # selected by the index_map through the block table
    pos = pos_ref[ib]
    k_pos = ik * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    mask = k_pos <= pos
    if window is not None:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    if probe:
        # sanitizer probe: max |K|/|V| over readable (mask-valid) positions
        mag = jnp.maximum(jnp.max(jnp.abs(k), axis=1),
                          jnp.max(jnp.abs(v), axis=1))
        p_ref[0, 0] = jnp.maximum(
            p_ref[0, 0], jnp.max(jnp.where(mask, mag, 0.0)))

    m_prev = m_scr[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(mask, jnp.exp(s - m_cur), 0.0)
    l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
    acc_scr[0, :] = (acc_scr[0, :] * alpha
                     + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_scr[0] = m_cur

    @pl.when(ik == n_virt_blocks - 1)
    def _out():
        denom = jnp.maximum(l_scr[0], 1e-30)
        o_ref[0, 0, 0, :] = (acc_scr[0, :] / denom).astype(o_ref.dtype)


def decode_attention_paged(q: jax.Array, cache_k: jax.Array,
                           cache_v: jax.Array, block_tbl: jax.Array,
                           pos: jax.Array, *, window: Optional[int] = None,
                           probe: bool = False, interpret: bool = False):
    """q: (B,1,nh,d); cache_k/v: (n_blocks, block, nkv, d) pool;
    block_tbl: (B, max_blocks) int32 pool-block id per virtual block
    (0 = trash block, masked); pos scalar or (B,) — the position of the
    current (already written) token per sequence. With ``probe`` armed
    (KV sanitizer), also returns a (B, nh) max readable |K|/|V| magnitude
    for the caller to checkify against ``KV_POISON``."""
    b, _, nh, d = q.shape
    block, nkv = cache_k.shape[1], cache_k.shape[2]
    assert nh % nkv == 0
    g = nh // nkv
    mb = block_tbl.shape[1]
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    pos = pos.astype(jnp.int32)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_dec_paged_kernel, scale=scale, window=window,
                               block=block, n_virt_blocks=mb, probe=probe)
    out_shape = [jax.ShapeDtypeStruct((b, 1, nh, d), q.dtype)]
    out_specs = [pl.BlockSpec((1, 1, 1, d),
                              lambda ib, ih, ik, tbl, pos: (ib, 0, ih, 0))]
    if probe:
        out_shape.append(jax.ShapeDtypeStruct((b, nh), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda ib, ih, ik, tbl, pos: (ib, ih)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                      # block table + positions
        grid=(b, nh, mb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda ib, ih, ik, tbl, pos: (ib, 0, ih, 0)),
            pl.BlockSpec((1, block, 1, d),
                         lambda ib, ih, ik, tbl, pos, g=g:
                         (tbl[ib, ik], 0, ih // g, 0)),
            pl.BlockSpec((1, block, 1, d),
                         lambda ib, ih, ik, tbl, pos, g=g:
                         (tbl[ib, ik], 0, ih // g, 0)),
        ],
        out_specs=out_specs if probe else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape if probe else out_shape[0],
        interpret=interpret,
    )(block_tbl.astype(jnp.int32), pos, q, cache_k, cache_v)
