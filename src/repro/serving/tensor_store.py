"""Shared tensor store — paper §5.2, adapted to JAX (DESIGN.md §3).

The paper's store is a separate process exporting CUDA-IPC handles so that a
NEW inference-engine process can attach to model weights already resident in
GPU memory, decoupling the engine lifecycle from weight lifetime and
avoiding the duplicate-allocation OOM that forces vLLM to terminate the old
engine before starting the new one.

JAX has no cross-process device-memory export, but the *insight* transfers:
weights live in the store, keyed by (model, partition); engines hold
references, never copies. Creating a new engine against a partition already
in the store is O(1) — ``attach`` returns the same ``jax.Array`` objects —
while a cold partition pays the (simulated or real) load cost once. The
store also tracks load timings so concurrent-initialization benchmarks can
report the paper's Fig-16 breakdown.

Beyond weights, the store carries migrated KV-block payloads (one key per
interrupted request — see serving/server.py), so residency is no longer
monotone: ``evict_to`` reclaims unreferenced keys in LRU order down to a
byte budget (``budget_bytes`` enforces it automatically on every insert),
keeping published KV from pinning memory forever.

Accounting invariant (regression-tested): every resident key has exactly
one entry in each of the params/refcount/bytes/LRU maps, whichever path
inserted it (``put``, ``put_or_attach`` or ``load``), so
``resident_bytes``/``refcount`` can never drift between paths.

SANITIZER MODE (``TensorStore(sanitize=True)`` or ``REPRO_KV_SANITIZE=1``,
same switch as the BlockManager shadow ledger): a shadow ledger mirrors
every publish/evict/pin/refcount transition through the store's own
notification points and cross-checks the real maps after every operation.
It turns silent misuse into typed errors at the offending call:

- ``DoubleEvictError``    — a key dropped that the ledger says is not
                            resident (evicted twice, or never published)
- ``PinnedEvictError``    — a key dropped while the ledger holds
                            references on it (an engine still attached)
- ``RefcountUnderflowError`` — ``detach`` on a key with no outstanding
                            reference (unbalanced attach/detach)
- ``StoreSanitizerError`` — shadow/real divergence: some path mutated
                            store state without going through the single
                            bookkeeping path

The tolerant production behavior (``detach`` no-ops on underflow, ``take``
returns None) is unchanged when disarmed.

BANDWIDTH HOOK: ``on_transfer(kind, nbytes)`` fires on every byte-moving
operation ("put" inserts, "take" consumes, "load" cold loads) so a host —
e.g. the discrete-event cluster simulator's ``NetworkLink`` — can account
store traffic on a contended link instead of assuming it free.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional, Tuple

Key = Tuple[str, str]


class StoreSanitizerError(RuntimeError):
    """A TensorStore accounting invariant was violated (sanitize mode)."""


class DoubleEvictError(StoreSanitizerError):
    """A key was dropped that the shadow ledger has no record of."""


class PinnedEvictError(StoreSanitizerError):
    """A key was dropped while references were still outstanding."""


class RefcountUnderflowError(StoreSanitizerError):
    """``detach`` on a key with no outstanding reference."""


def _env_sanitize() -> bool:
    return os.environ.get("REPRO_KV_SANITIZE", "0").lower() not in (
        "", "0", "false", "off")


class _StoreShadow:
    """Independent mirror of the store's residency/refcount state.

    Maintained through explicit transition notifications (never by reading
    the store's maps), so a store-side bookkeeping bug shows up as a
    divergence instead of being silently mirrored."""

    def __init__(self) -> None:
        self.entries: Dict[Key, list] = {}    # key -> [refcount, nbytes]

    def on_register(self, key: Key, nbytes: int) -> None:
        e = self.entries.get(key)
        if e is None:
            self.entries[key] = [0, nbytes]
        else:
            e[1] = nbytes          # re-publish over a resident key

    def on_acquire(self, key: Key) -> None:
        if key not in self.entries:
            raise DoubleEvictError(
                f"attach of non-resident key {key} (evicted or never put)")
        self.entries[key][0] += 1

    def on_detach(self, key: Key) -> None:
        e = self.entries.get(key)
        if e is None or e[0] <= 0:
            raise RefcountUnderflowError(
                f"detach of key {key} with no outstanding reference")
        e[0] -= 1

    def on_drop(self, key: Key) -> None:
        e = self.entries.get(key)
        if e is None:
            raise DoubleEvictError(f"evict of non-resident key {key} "
                                   "(double evict)")
        if e[0] > 0:
            raise PinnedEvictError(
                f"evict of key {key} with refcount {e[0]} "
                "(engines still attached)")
        del self.entries[key]

    def crosscheck(self, store: "TensorStore", op: str) -> None:
        real_keys = set(store._store)
        if real_keys != set(self.entries):
            raise StoreSanitizerError(
                f"after {op}: resident keys diverged "
                f"(store-only={real_keys - set(self.entries)}, "
                f"shadow-only={set(self.entries) - real_keys})")
        for k, (rc, nb) in self.entries.items():
            if store._refcount.get(k, 0) != rc:
                raise StoreSanitizerError(
                    f"after {op}: refcount of {k} diverged "
                    f"(store={store._refcount.get(k, 0)}, shadow={rc})")
            if store._bytes.get(k, -1) != nb:
                raise StoreSanitizerError(
                    f"after {op}: bytes of {k} diverged "
                    f"(store={store._bytes.get(k, -1)}, shadow={nb})")


@dataclasses.dataclass
class LoadRecord:
    key: Key
    wall_s: float
    cold: bool


class TensorStore:
    def __init__(self, load_time_model: Optional[Callable[[int], float]] = None,
                 budget_bytes: Optional[int] = None,
                 pin_hot_k: int = 0,
                 sanitize: Optional[bool] = None,
                 on_transfer: Optional[Callable[[str, int], None]] = None):
        """load_time_model: bytes -> seconds, used by the virtual clock to
        model remote-storage fetch (paper: custom raw-binary shards so each
        node downloads only its partition). budget_bytes: soft cap enforced
        by LRU eviction of unreferenced keys on every insert (None = no
        cap; referenced keys are never evicted, so the store may exceed the
        budget while every byte is pinned). pin_hot_k: budget-capped LRU
        additionally skips the top-k keys by read-hit count — a hot
        published prefix is read (``peek``/``attach``) far more often than
        it is inserted, so pure recency would evict exactly the payload
        every pipeline warms from (``evict_unreferenced`` still reclaims
        everything). sanitize: arm the shadow ledger (None = follow
        REPRO_KV_SANITIZE). on_transfer: ``f(kind, nbytes)`` byte-movement
        hook ("put" inserts, "take" consumes) for link accounting."""
        self._store: Dict[Key, Any] = {}
        self._refcount: Dict[Key, int] = {}
        self._bytes: Dict[Key, int] = {}
        self._last_used: Dict[Key, int] = {}
        self._hits: Dict[Key, int] = {}
        self._clock = 0
        self.loads: list[LoadRecord] = []
        self.load_time_model = load_time_model or (lambda nbytes: 0.0)
        self.budget_bytes = budget_bytes
        self.pin_hot_k = pin_hot_k
        self.sanitize = _env_sanitize() if sanitize is None else sanitize
        self._shadow = _StoreShadow() if self.sanitize else None
        self.on_transfer = on_transfer

    # -- internal bookkeeping (single path for every insert/acquire) ------------
    def _check(self, op: str) -> None:
        if self._shadow is not None:
            self._shadow.crosscheck(self, op)

    def _touch(self, key: Key) -> None:
        self._clock += 1
        self._last_used[key] = self._clock

    def _register(self, key: Key, params: Any) -> None:
        self._store[key] = params
        self._bytes[key] = _tree_bytes(params)
        self._refcount.setdefault(key, 0)
        self._touch(key)
        if self._shadow is not None:
            self._shadow.on_register(key, self._bytes[key])
        if self.on_transfer is not None:
            self.on_transfer("put", self._bytes[key])
        if self.budget_bytes is not None:
            self.evict_to(self.budget_bytes)

    def _hit(self, key: Key) -> None:
        self._hits[key] = self._hits.get(key, 0) + 1

    def _acquire(self, key: Key) -> Any:
        if self._shadow is not None:
            self._shadow.on_acquire(key)
        self._refcount[key] += 1
        self._hit(key)
        self._touch(key)
        return self._store[key]

    # -- public API -------------------------------------------------------------
    def put(self, model: str, partition: str, params: Any) -> None:
        """Publish without acquiring: the key is resident at refcount 0
        (evictable) until someone attaches."""
        self._register((model, partition), params)
        self._check("put")

    def contains(self, model: str, partition: str) -> bool:
        return (model, partition) in self._store

    def attach(self, model: str, partition: str) -> Any:
        """Zero-copy: returns the stored arrays themselves."""
        out = self._acquire((model, partition))
        self._check("attach")
        return out

    def put_or_attach(self, model: str, partition: str,
                      params: Any) -> Tuple[Any, bool]:
        """Idempotent publish: the first caller stores the partition (cold);
        every later caller attaches to the resident arrays. Returns
        (params, cold) — the concurrent-initialization fast path, §5.2."""
        key = (model, partition)
        cold = key not in self._store
        if cold:
            self._register(key, params)
        out = self._acquire(key), cold
        self._check("put_or_attach")
        return out

    def peek(self, model: str, partition: str) -> Optional[Any]:
        """Non-consuming read: return the resident params (or None) WITHOUT
        acquiring a reference or dropping the key. Multi-consumer payloads
        — e.g. shared-prefix warm-up, where every new pipeline reads the
        same published blocks — use this instead of ``take``. Touches the
        LRU clock so hot payloads outlive cold ones under a byte budget."""
        key = (model, partition)
        if key not in self._store:
            return None
        self._hit(key)
        self._touch(key)
        return self._store[key]

    def keys(self, model: Optional[str] = None) -> list[Key]:
        """Resident (model, partition) keys, LRU order (stalest first),
        optionally filtered to one model namespace."""
        ks = sorted(self._store, key=lambda k: self._last_used[k])
        return [k for k in ks if model is None or k[0] == model]

    def take(self, model: str, partition: str) -> Optional[Any]:
        """Consume a key: return its params and drop it from the store
        (single-consumer payloads, e.g. a migrated request's KV blocks).
        None when absent — or when the key is PINNED (refcount > 0):
        ``evict_to`` promises referenced keys stay resident, so consuming
        one would yank a partition out from under its attached engines."""
        key = (model, partition)
        if key not in self._store or self._refcount.get(key, 0) > 0:
            return None
        params = self._store[key]
        nbytes = self._bytes.get(key, 0)
        self._drop(key)
        if self.on_transfer is not None:
            self.on_transfer("take", nbytes)
        self._check("take")
        return params

    def resident_bytes(self) -> int:
        """Total bytes pinned by the store (capacity-planning metric)."""
        return sum(self._bytes.values())

    def detach(self, model: str, partition: str) -> None:
        key = (model, partition)
        if self._shadow is not None:
            self._shadow.on_detach(key)     # raises on underflow
        if key in self._refcount and self._refcount[key] > 0:
            self._refcount[key] -= 1
        self._check("detach")

    def refcount(self, model: str, partition: str) -> int:
        return self._refcount.get((model, partition), 0)

    def hits(self, model: str, partition: str) -> int:
        """Read hits (peek/attach) recorded against a key."""
        return self._hits.get((model, partition), 0)

    def hot_keys(self) -> list[Key]:
        """The resident keys pinned by ``pin_hot_k`` (top-k by hit count,
        hottest first; zero-hit keys never pin)."""
        if self.pin_hot_k <= 0:
            return []
        ranked = sorted(
            (k for k in self._store if self._hits.get(k, 0) > 0),
            key=lambda k: (-self._hits[k], -self._last_used[k]))
        return ranked[:self.pin_hot_k]

    def _drop(self, key: Key) -> None:
        if self._shadow is not None:
            self._shadow.on_drop(key)       # raises on double/pinned evict
        self._store.pop(key, None)
        self._refcount.pop(key, None)
        self._bytes.pop(key, None)
        self._last_used.pop(key, None)
        self._hits.pop(key, None)

    def evict_unreferenced(self) -> int:
        """Drop partitions with no attached engine (memory reclamation)."""
        dead = [k for k, c in self._refcount.items() if c == 0]
        for k in dead:
            self._drop(k)
        self._check("evict_unreferenced")
        return len(dead)

    def evict_to(self, budget_bytes: int) -> int:
        """LRU-evict unreferenced keys until ``resident_bytes`` fits the
        budget (referenced keys are pinned and never touched; so are the
        ``pin_hot_k`` hottest keys by hit count — the budget may stay
        exceeded rather than evict the prefix every pipeline warms from).
        Returns bytes freed."""
        freed = 0
        resident = self.resident_bytes()
        hot = set(self.hot_keys())
        victims = sorted((k for k, c in self._refcount.items()
                          if c == 0 and k not in hot),
                         key=lambda k: self._last_used[k])
        for k in victims:
            if resident <= budget_bytes:
                break
            freed += self._bytes[k]
            resident -= self._bytes[k]
            self._drop(k)
        self._check("evict_to")
        return freed

    def load(self, model: str, partition: str,
             loader: Callable[[], Any]) -> Tuple[Any, float]:
        """Fetch-or-load. Returns (params, virtual_load_seconds)."""
        key = (model, partition)
        if key in self._store:
            self.loads.append(LoadRecord(key, 0.0, cold=False))
            out = self._acquire(key), 0.0
            self._check("load")
            return out
        t0 = time.perf_counter()
        params = loader()
        virtual = self.load_time_model(_tree_bytes(params))
        self._register(key, params)
        self.loads.append(LoadRecord(key, time.perf_counter() - t0,
                                     cold=True))
        out = self._acquire(key), virtual
        self._check("load")
        return out

    def check_consistent(self) -> bool:
        """The accounting invariant: all four maps key-identical."""
        keys = set(self._store)
        return (keys == set(self._refcount) == set(self._bytes)
                == set(self._last_used))


def _tree_bytes(tree: Any) -> int:
    import jax
    return sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(tree))
