"""Shared tensor store — paper §5.2, adapted to JAX (DESIGN.md §3).

The paper's store is a separate process exporting CUDA-IPC handles so that a
NEW inference-engine process can attach to model weights already resident in
GPU memory, decoupling the engine lifecycle from weight lifetime and
avoiding the duplicate-allocation OOM that forces vLLM to terminate the old
engine before starting the new one.

JAX has no cross-process device-memory export, but the *insight* transfers:
weights live in the store, keyed by (model, partition); engines hold
references, never copies. Creating a new engine against a partition already
in the store is O(1) — ``attach`` returns the same ``jax.Array`` objects —
while a cold partition pays the (simulated or real) load cost once. The
store also tracks load timings so concurrent-initialization benchmarks can
report the paper's Fig-16 breakdown.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass
class LoadRecord:
    key: Tuple[str, str]
    wall_s: float
    cold: bool


class TensorStore:
    def __init__(self, load_time_model: Optional[Callable[[int], float]] = None):
        """load_time_model: bytes -> seconds, used by the virtual clock to
        model remote-storage fetch (paper: custom raw-binary shards so each
        node downloads only its partition)."""
        self._store: Dict[Tuple[str, str], Any] = {}
        self._refcount: Dict[Tuple[str, str], int] = {}
        self.loads: list[LoadRecord] = []
        self.load_time_model = load_time_model or (lambda nbytes: 0.0)

    def put(self, model: str, partition: str, params: Any) -> None:
        self._store[(model, partition)] = params
        self._refcount.setdefault((model, partition), 0)

    def contains(self, model: str, partition: str) -> bool:
        return (model, partition) in self._store

    def attach(self, model: str, partition: str) -> Any:
        """Zero-copy: returns the stored arrays themselves."""
        key = (model, partition)
        self._refcount[key] = self._refcount.get(key, 0) + 1
        return self._store[key]

    def put_or_attach(self, model: str, partition: str,
                      params: Any) -> Tuple[Any, bool]:
        """Idempotent publish: the first caller stores the partition (cold);
        every later caller attaches to the resident arrays. Returns
        (params, cold) — the concurrent-initialization fast path, §5.2."""
        key = (model, partition)
        cold = key not in self._store
        if cold:
            self._store[key] = params
        self._refcount[key] = self._refcount.get(key, 0) + 1
        return self._store[key], cold

    def resident_bytes(self) -> int:
        """Total bytes pinned by the store (capacity-planning metric)."""
        return sum(_tree_bytes(v) for v in self._store.values())

    def detach(self, model: str, partition: str) -> None:
        key = (model, partition)
        if key in self._refcount and self._refcount[key] > 0:
            self._refcount[key] -= 1

    def refcount(self, model: str, partition: str) -> int:
        return self._refcount.get((model, partition), 0)

    def evict_unreferenced(self) -> int:
        """Drop partitions with no attached engine (memory reclamation)."""
        dead = [k for k, c in self._refcount.items() if c == 0]
        for k in dead:
            self._store.pop(k, None)
            self._refcount.pop(k, None)
        return len(dead)

    def load(self, model: str, partition: str,
             loader: Callable[[], Any]) -> Tuple[Any, float]:
        """Fetch-or-load. Returns (params, virtual_load_seconds)."""
        key = (model, partition)
        if key in self._store:
            self.loads.append(LoadRecord(key, 0.0, cold=False))
            self._refcount[key] = self._refcount.get(key, 0) + 1
            return self._store[key], 0.0
        t0 = time.perf_counter()
        params = loader()
        nbytes = _tree_bytes(params)
        virtual = self.load_time_model(nbytes)
        self._store[key] = params
        self._refcount[key] = 1
        self.loads.append(LoadRecord(key, time.perf_counter() - t0,
                                     cold=True))
        return params, virtual


def _tree_bytes(tree: Any) -> int:
    import jax
    return sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(tree))
