"""Block-aligned prefix index for the prefix-sharing KV cache.

Maps token runs to resident pool blocks so admission can reuse the KV of a
shared prompt prefix instead of recomputing it (the largest source of
redundant prefill compute under production traffic with common system
prompts — ROADMAP item 1 / ISSUE 6).

Structure — a radix tree over BLOCK-ALIGNED runs, stored flat:

* ``_full``:  tuple(toks[: (i+1) * block_size])  ->  pool block id holding
  that run's last block. An exact-tuple key per depth is the flattened form
  of a radix path; Python's tuple hashing makes lookup O(len) with NO
  collision false-positives (a hash-only index could alias two prompts).
* ``_partial``: tuple(full-block prefix) -> [(block id, tail tokens)] for
  prompts whose last block is only partially filled. A partial match is
  shared by COPY-ON-WRITE: the matching block is copied into the new
  slot's first fresh block before any divergent write lands. At most
  ``max_partials`` divergent tails are kept per aligned prefix; under cap
  pressure the COLDEST tail is evicted — fewest boundary-match hits,
  least-recently-used as the tie-break — so the hot tail survives however
  many one-off suffixes share its boundary block (hit-count LRU; the old
  FIFO evicted the hottest tail first precisely because it arrived
  first).

Indexed blocks may be LIVE (mapped by slots) or FREE (their owners
finished; content stays valid until the block manager reallocates them —
that is what lets a hot prefix survive request completion). The manager
prefers un-indexed free blocks and calls ``invalidate_block`` when it must
overwrite an indexed one.

Sharing always leaves at least ONE token to prefill: the suffix dispatch
must produce logits for the first sampled token, so a full-prompt match is
capped at ``len(prompt) - 1`` tokens.

``hits`` counts matches per full run; ``hot()`` surfaces the most-reused
maximal runs for cluster-wide publication through the tensor store.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.kv_blocks import BlockManager

TokenRun = Tuple[int, ...]


@dataclasses.dataclass
class PrefixMatch:
    n_tokens: int               # shared tokens (full blocks + partial tail)
    full: List[int]             # full shared block ids, prefix order
    boundary: Optional[int]     # partially-shared block to copy-on-write
    boundary_tokens: int        # valid tokens inside the boundary block


class PrefixIndex:
    def __init__(self, block_size: int, bm: BlockManager,
                 max_partials: int = 4):
        self.block_size = block_size
        self.bm = bm
        self.max_partials = max_partials
        self._full: Dict[TokenRun, int] = {}
        self._partial: Dict[TokenRun, List[Tuple[int, TokenRun]]] = {}
        # block id -> entries referencing it, for O(1) invalidation
        self._rev: Dict[int, List[Tuple]] = {}
        self.hits: Dict[TokenRun, int] = {}
        # (pkey, tail) -> [boundary hits, last-touched tick] driving the
        # hit-count LRU eviction of partial entries
        self._pstat: Dict[Tuple[TokenRun, TokenRun], List[int]] = {}
        self._tick = 0

    def __len__(self) -> int:
        return len(self._full) + sum(len(v) for v in self._partial.values())

    # -- insert -----------------------------------------------------------------
    def _link(self, bid: int, entry: Tuple) -> None:
        self._rev.setdefault(bid, []).append(entry)
        self.bm.indexed.add(bid)

    def insert(self, toks: Sequence[int], block_ids: Sequence[int]) -> None:
        """Register a freshly-prefilled context: ``block_ids`` (table
        order) hold its KV. Existing entries win — the first block to hold
        a run keeps serving it, so duplicates never fork the tree."""
        toks = [int(t) for t in toks]
        bs = self.block_size
        n_full = len(toks) // bs
        assert len(block_ids) >= self.bm.blocks_for(len(toks)) or not toks
        for i in range(n_full):
            key = tuple(toks[:(i + 1) * bs])
            if key in self._full:
                continue
            bid = int(block_ids[i])
            self._full[key] = bid
            self.hits.setdefault(key, 0)
            self._link(bid, ("f", key))
        rem = len(toks) - n_full * bs
        if rem > 0:
            pkey = tuple(toks[:n_full * bs])
            tail = tuple(toks[n_full * bs:])
            entries = self._partial.setdefault(pkey, [])
            bid = int(block_ids[n_full])
            if any(t == tail for _, t in entries):
                # duplicate tail re-inserted: evidence of reuse — bump it
                # so it outlives colder tails under cap pressure
                self._pbump(pkey, tail, hit=True)
                return
            if len(entries) >= self.max_partials:
                # hit-count LRU: evict the tail with the fewest boundary
                # hits, least-recently-touched as the tie-break
                old_bid, old_tail = min(
                    entries,
                    key=lambda e: tuple(self._pstat.get((pkey, e[1]),
                                                        [0, 0])))
                entries.remove((old_bid, old_tail))
                self._pstat.pop((pkey, old_tail), None)
                self._unlink(old_bid, ("p", pkey, old_tail))
            entries.append((bid, tail))
            self._pbump(pkey, tail, hit=False)
            self._link(bid, ("p", pkey, tail))

    def _pbump(self, pkey: TokenRun, tail: TokenRun, hit: bool) -> None:
        """Touch a partial entry's LRU stat (optionally counting a hit)."""
        self._tick += 1
        st = self._pstat.setdefault((pkey, tail), [0, 0])
        if hit:
            st[0] += 1
        st[1] = self._tick

    # -- match ------------------------------------------------------------------
    def match(self, toks: Sequence[int]) -> Optional[PrefixMatch]:
        """Longest indexed prefix of ``toks``, capped at ``len(toks) - 1``
        (at least one token must prefill to produce first-token logits).
        Returns None when nothing (useful) matches."""
        toks = [int(t) for t in toks]
        bs = self.block_size
        limit = len(toks) - 1
        full_ids: List[int] = []
        covered = 0
        while covered + bs <= limit:
            bid = self._full.get(tuple(toks[:covered + bs]))
            if bid is None:
                break
            full_ids.append(bid)
            covered += bs
        boundary, btoks, btail = None, 0, None
        pkey = tuple(toks[:covered])
        for bid, tail in self._partial.get(pkey, []):
            t = 0
            cap = min(len(tail), limit - covered)
            while t < cap and tail[t] == toks[covered + t]:
                t += 1
            if t > btoks:
                boundary, btoks, btail = bid, t, tail
        if covered == 0 and btoks == 0:
            return None
        if btail is not None:
            self._pbump(pkey, btail, hit=True)
        if full_ids:
            self.hits[tuple(toks[:covered])] += 1
        return PrefixMatch(covered + btoks, full_ids, boundary, btoks)

    def full_run(self, toks: Sequence[int]) -> List[int]:
        """Block ids of the longest FULLY-indexed block run of ``toks``
        (no one-token cap — used for export, not admission)."""
        toks = [int(t) for t in toks]
        bs, ids = self.block_size, []
        for i in range(len(toks) // bs):
            bid = self._full.get(tuple(toks[:(i + 1) * bs]))
            if bid is None:
                break
            ids.append(bid)
        return ids

    # -- invalidation -----------------------------------------------------------
    def _unlink(self, bid: int, entry: Tuple) -> None:
        entries = self._rev.get(bid)
        if entries is not None and entry in entries:
            entries.remove(entry)
            if not entries:
                del self._rev[bid]
                self.bm.indexed.discard(bid)

    def invalidate_block(self, bid: int) -> None:
        """The manager reallocated an indexed block: its content is about
        to be overwritten, so every entry referencing it — and every DEEPER
        full entry extending through it — must go."""
        for entry in self._rev.pop(bid, []):
            if entry[0] == "f":
                key = entry[1]
                self._full.pop(key, None)
                self.hits.pop(key, None)
                # runs extending through the dead block are unreachable
                # (match walks block-by-block) but would leak; sweep them
                dead = [k for k in self._full
                        if len(k) > len(key) and k[:len(key)] == key]
                for k in dead:
                    b2 = self._full.pop(k)
                    self.hits.pop(k, None)
                    self._unlink(b2, ("f", k))
                deadp = [pk for pk in self._partial
                         if len(pk) >= len(key) and pk[:len(key)] == key]
                for pk in deadp:
                    for b2, tail in self._partial.pop(pk):
                        self._pstat.pop((pk, tail), None)
                        self._unlink(b2, ("p", pk, tail))
            else:
                _, pkey, tail = entry
                entries = self._partial.get(pkey)
                if entries is not None:
                    entries[:] = [(b, t) for b, t in entries
                                  if not (b == bid and t == tail)]
                    if not entries:
                        del self._partial[pkey]
                self._pstat.pop((pkey, tail), None)
        self.bm.indexed.discard(bid)

    # -- hot runs (cluster warm-up) ---------------------------------------------
    def hot(self, min_hits: int = 2) -> List[TokenRun]:
        """Maximal full-block runs matched at least ``min_hits`` times,
        hottest first — candidates for tensor-store publication."""
        cand = [k for k, h in self.hits.items()
                if h >= min_hits and k in self._full]
        maximal = [k for k in cand
                   if not any(len(o) > len(k) and o[:len(k)] == k
                              for o in cand)]
        return sorted(maximal, key=lambda k: (-self.hits[k], len(k)))
