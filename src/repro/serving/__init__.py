from repro.serving.engine import Engine
from repro.serving.kv_blocks import BlockManager
from repro.serving.prefix_index import PrefixIndex
from repro.serving.request import ServeRequest
from repro.serving.server import FTTimes, GlobalServer, ServingPipeline
from repro.serving.tensor_store import TensorStore

__all__ = ["BlockManager", "Engine", "PrefixIndex", "ServeRequest",
           "FTTimes", "GlobalServer", "ServingPipeline", "TensorStore"]
