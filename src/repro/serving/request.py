"""Serving request objects."""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional

_ids = itertools.count()


@dataclasses.dataclass
class ServeRequest:
    prompt: List[int]                      # token ids (or frontend embeds id)
    max_new_tokens: int
    rid: int = dataclasses.field(default_factory=lambda: next(_ids))
    eos_id: Optional[int] = None
    arrival_s: float = 0.0
    # progress (preserved across migrations — paper §5.1)
    generated: List[int] = dataclasses.field(default_factory=list)
    migrations: int = 0
    # timestamps (virtual clock)
    first_token_s: float = -1.0
    finish_s: float = -1.0

    @property
    def ctx_len(self) -> int:
        """Current context length (prompt + generated so far)."""
        return len(self.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)

    def full_context(self) -> List[int]:
        """Prompt + already-generated output — the recomputation input for
        output-preserving migration."""
        return list(self.prompt) + list(self.generated)
