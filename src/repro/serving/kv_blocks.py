"""Block-granular KV allocation for the paged cache layout.

The contiguous layout pins a full ``max_len`` KV row per slot, so memory
utilization collapses at high slot counts with mixed context lengths — the
ROADMAP's paged-KV lift. Here the engine's KV pool is ``n_blocks`` fixed-size
token blocks shared by every slot; the ``BlockManager`` owns the free list
and a per-slot block table mapping virtual token positions to pool blocks:

    virtual position t of slot s  ->  pool block table[s, t // block_size],
                                      offset t % block_size

Block id 0 is RESERVED as the trash block: unallocated table entries point
at it, so jit'd scatters can route pad/dead-row writes somewhere harmless
without data-dependent shapes, and gathers through an unallocated entry read
garbage that position masking already hides. Real allocations hand out ids
from [1, n_blocks).

Allocation is DEMAND-PAGED through a reservation ledger. Admission books a
request's worst-case token need (``ceil(total_tokens / block_size)`` blocks)
as a *reservation* — so admission control stays sound — but only allocates
blocks covering the tokens it will write now (the prefill context);
``grow`` allocates the next block when decode crosses a block boundary.
The ledger may overcommit the pool (``overcommit`` > 1 books more reserved
blocks than physically exist), betting that EOS-early requests release
capacity before everyone reaches worst case; when the bet loses and a grow
finds the free list dry, the engine preempts a victim slot (its KV blocks
round-trip through the shared tensor store — see serving/engine.py).
A single request's worst case must always fit the pool physically, so a
slot that is alone can never wedge on its own reservation.

Blocks are SHAREABLE (prefix-sharing KV cache): a slot may map blocks
already mapped by other slots — its leading ``n_shared`` table entries are
read-only shared-prefix blocks, refcounted per block. ``free(slot)``
decrements refcounts and only blocks reaching zero return to the free
list. The ledger books only the FRESH (non-shared) worst case per slot and
admission is gated on *unique blocks in use + outstanding demand*
(outstanding = reserved-but-not-yet-allocated), so already-written blocks
no longer count against the ledger twice — the "shrinking reservation"
that lets ``kv_overcommit`` stay less aggressive for the same admitted
capacity. Without sharing this gate is numerically identical to the old
sum-of-reservations one.

A freed block's CONTENT stays valid until the block is reallocated, which
is what lets a prefix index keep pointing at free-list-resident blocks
(warm prefixes survive request completion). Blocks registered in
``indexed`` are handed out LAST by the free list, and when one is finally
overwritten the ``on_reuse`` callback lets the index drop its entries.

``reserve(slot, n, live_tokens=None)`` with the default ``live_tokens``
allocates everything up front — the pre-ledger behavior, kept as the
``kv_alloc="upfront"`` A/B baseline (``alloc`` is its alias).

``note_live`` records tokens actually written so ``frag_tokens`` reports
TRUE internal fragmentation (allocated capacity minus live occupancy), not
the smaller waste-vs-lifetime-reservation number.

SANITIZER MODE (``BlockManager(sanitize=True)`` or ``REPRO_KV_SANITIZE=1``,
see ``repro.analysis``): the manager keeps a SHADOW ledger — an
independently-updated mirror of the free set, per-slot mappings, and
refcounts — cross-checked against the primary structures after every
``reserve``/``grow``/``free``/warm op, so corruption (tampered refcounts,
free-list duplicates, table rows diverging from mappings) raises
``KVSanitizerError`` at the op that caused it instead of failing
``check_no_leak()`` at end of test. On top of the ledger it detects:

* double-free — ``free(slot)`` on an unmapped slot (the non-sanitizing
  path deliberately no-ops for engine convenience);
* refcount underflow — a block's refcount would go negative;
* use-after-free — ``check_read(slot, n)`` sees a table entry that is
  TRASH, unmapped, or whose content was released (poisoned);
* shared-block write — ``check_write(slot, start, end)`` (driven by the
  ``note_live`` write delta) covers a read-only shared-prefix entry or a
  block with refcount > 1 (COW should have run first).

``last_released`` lists the blocks whose content died at the most recent
``free`` (refcount hit 0 and no prefix index references them) — the
engine overwrites those device blocks with ``KV_POISON`` so any stale
gather produces blatant garbage. The sentinel is FINITE on purpose:
masked attention positions get probability exactly 0.0 and ``0.0 * 1e9 ==
0.0``, so poison is output-neutral for correct code, while NaN would
propagate through ``p @ v`` even at masked positions.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Sequence, Set

import numpy as np

TRASH_BLOCK = 0

# Poison sentinel for released KV block content (sanitize mode). Finite:
# masked positions contribute exactly 0.0 * KV_POISON = 0.0, so correct
# masking hides it, while a genuine stale read is unmissable.
KV_POISON = 1e9


class KVSanitizerError(RuntimeError):
    """A KV-block invariant was violated (sanitize mode)."""


def _env_sanitize() -> bool:
    return os.environ.get("REPRO_KV_SANITIZE", "0").lower() not in (
        "", "0", "false", "off")


class BlockManager:
    def __init__(self, n_blocks: int, block_size: int, max_slots: int,
                 max_blocks_per_slot: int, overcommit: float = 1.0,
                 sanitize: Optional[bool] = None):
        assert n_blocks >= 2, "need at least the trash block plus one"
        assert block_size >= 1
        assert overcommit >= 1.0, "overcommit < 1 would idle physical blocks"
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks_per_slot = max_blocks_per_slot
        self.overcommit = float(overcommit)
        # LIFO free list keeps recently-freed (cache-warm) blocks hot
        self._free: List[int] = list(range(n_blocks - 1, TRASH_BLOCK, -1))
        # per-slot block table; row width = blocks needed for max_len
        self.table = np.full((max_slots, max_blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self._mapped: Dict[int, List[int]] = {}   # table-order block ids
        self._n_shared: Dict[int, int] = {}       # leading read-only blocks
        self._reserved: Dict[int, int] = {}       # ledger: worst-case FRESH
        self._tokens: Dict[int, int] = {}         # requested lifetime tokens
        self._live: Dict[int, int] = {}           # tokens actually written
        self.refcount: Dict[int, int] = {}        # block id -> #slots mapping
        # free-list-resident blocks whose content a prefix index still
        # references; reallocated only when nothing else is free
        self.indexed: set = set()
        self.on_reuse: Optional[Callable[[int], None]] = None
        self.peak_blocks = 0
        self.grows = 0                        # decode-time block allocations
        # -- sanitizer shadow ledger (see module docstring) ------------------
        self.sanitize = _env_sanitize() if sanitize is None else bool(sanitize)
        self._sh_free: Set[int] = set(self._free)
        self._sh_borrowed: Set[int] = set()   # warm_blocks .. warm_release
        self._sh_slots: Dict[int, List[int]] = {}
        self._sh_shared: Dict[int, int] = {}
        self._sh_rc: Dict[int, int] = {}
        self._sh_poison: Set[int] = set()     # released, content dead
        self.last_released: List[int] = []    # content-dead blocks, last free

    # -- sizing -----------------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.block_size)

    def reservation_cap(self) -> int:
        """Ledger capacity: physical blocks scaled by the overcommit bet."""
        return int(self.overcommit * (self.n_blocks - 1))

    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    def outstanding_blocks(self) -> int:
        """Reserved-but-not-yet-allocated fresh blocks across all slots —
        the demand the ledger still has to be able to satisfy."""
        return sum(max(0, self._reserved[s]
                       - (len(ids) - self._n_shared[s]))
                   for s, ids in self._mapped.items())

    def committed_blocks(self) -> int:
        """Unique blocks in use plus outstanding demand — the quantity the
        admission ledger actually gates on."""
        return self.blocks_in_use() + self.outstanding_blocks()

    def can_reserve(self, n_tokens: int, live_tokens: int = None,
                    n_shared: int = 0, n_reclaim: int = 0) -> bool:
        live = n_tokens if live_tokens is None else min(live_tokens, n_tokens)
        need_phys = self.blocks_for(n_tokens)
        fresh_live = max(0, self.blocks_for(live) - n_shared)
        fresh_total = max(0, need_phys - n_shared)
        return (need_phys <= self.max_blocks_per_slot
                # worst case must fit the pool physically: a slot running
                # alone must be able to grow to its reservation, or
                # preemption could thrash without ever making room
                and need_phys <= self.n_blocks - 1
                # committed = unique in-use + outstanding; without sharing
                # this equals the old sum-of-reservations gate exactly
                and self.committed_blocks() + n_reclaim + fresh_total
                <= self.reservation_cap()
                and fresh_live + n_reclaim <= len(self._free))

    def can_alloc(self, n_tokens: int) -> bool:
        return self.can_reserve(n_tokens)

    # -- free-list internals ----------------------------------------------------
    def _pop_free(self, avoid: Sequence[int] = ()) -> int:
        """Pop a free block, preferring blocks no prefix index references;
        overwriting an indexed block notifies ``on_reuse`` so the index
        drops its (now stale) entries."""
        for i in range(len(self._free) - 1, -1, -1):
            bid = self._free[i]
            if bid in avoid or bid in self.indexed:
                continue
            return self._free.pop(i)
        for i in range(len(self._free) - 1, -1, -1):
            bid = self._free[i]
            if bid in avoid:
                continue
            self._free.pop(i)
            self.indexed.discard(bid)
            if self.on_reuse is not None:
                self.on_reuse(bid)
            return bid
        raise AssertionError("pop from an exhausted free list")

    def _reclaim(self, bid: int) -> None:
        """Pull a specific free-list block back into use WITHOUT touching
        its content — re-sharing a warm prefix block."""
        self._free.remove(bid)

    # -- sanitizer (shadow ledger; see module docstring) ------------------------
    def _sh_take(self, bid: int, op: str) -> None:
        """Shadow side of a block entering use from the free set."""
        if bid in self._sh_free:
            self._sh_free.discard(bid)
            self._sh_poison.discard(bid)     # about to be overwritten
        else:
            raise KVSanitizerError(
                f"{op}: block {bid} entered use but the shadow ledger "
                f"does not have it free")

    def _sh_check(self, op: str) -> None:
        """Cross-check every primary structure against the shadow ledger;
        any divergence means an op (or outside tampering) corrupted state
        between the previous check and this one."""
        if len(set(self._free)) != len(self._free):
            raise KVSanitizerError(f"{op}: duplicate free-list entries")
        if set(self._free) != self._sh_free:
            raise KVSanitizerError(
                f"{op}: free list diverged from shadow "
                f"(only-real={sorted(set(self._free) - self._sh_free)}, "
                f"only-shadow={sorted(self._sh_free - set(self._free))})")
        if set(self._mapped) != set(self._sh_slots):
            raise KVSanitizerError(
                f"{op}: mapped slots diverged from shadow")
        mapped: Set[int] = set()
        for s, ids in self._mapped.items():
            mapped.update(ids)
            if ids != self._sh_slots[s]:
                raise KVSanitizerError(
                    f"{op}: slot {s} mapping diverged from shadow")
            if self._n_shared[s] != self._sh_shared[s]:
                raise KVSanitizerError(
                    f"{op}: slot {s} shared count diverged from shadow")
            row = self.table[s]
            if [int(b) for b in row[:len(ids)]] != ids or any(
                    int(b) != TRASH_BLOCK for b in row[len(ids):]):
                raise KVSanitizerError(
                    f"{op}: slot {s} table row diverged from its mapping")
        every = set(range(TRASH_BLOCK + 1, self.n_blocks))
        if self._sh_free | mapped | self._sh_borrowed != every \
                or self._sh_free & mapped:
            raise KVSanitizerError(
                f"{op}: blocks leaked or double-owned "
                f"(free+mapped+borrowed != pool)")
        if self._sh_poison & mapped:
            raise KVSanitizerError(
                f"{op}: poisoned (released) blocks are mapped: "
                f"{sorted(self._sh_poison & mapped)}")
        for b in set(self.refcount) | set(self._sh_rc):
            if self.refcount.get(b, 0) != self._sh_rc.get(b, 0):
                raise KVSanitizerError(
                    f"{op}: refcount of block {b} diverged "
                    f"({self.refcount.get(b, 0)} != shadow "
                    f"{self._sh_rc.get(b, 0)})")

    def check_read(self, slot: int, n_tokens: int) -> None:
        """Raise if reading ``slot``'s first ``n_tokens`` would touch a
        TRASH entry, a block the ledger doesn't map to this slot, or a
        block whose content was released (use-after-free)."""
        if not self.sanitize or n_tokens <= 0:
            return
        ids = self._mapped.get(slot)
        if ids is None:
            raise KVSanitizerError(
                f"use-after-free: read of unmapped slot {slot}")
        need = self.blocks_for(n_tokens)
        if need > len(ids):
            raise KVSanitizerError(
                f"read past allocation: slot {slot} covers {len(ids)} "
                f"block(s) but {n_tokens} tokens need {need}")
        for i in range(need):
            bid = int(self.table[slot, i])
            if bid == TRASH_BLOCK or bid != ids[i]:
                raise KVSanitizerError(
                    f"use-after-free: slot {slot} entry {i} reads block "
                    f"{bid}, ledger maps {ids[i]}")
            if bid in self._sh_poison or self._sh_rc.get(bid, 0) <= 0:
                raise KVSanitizerError(
                    f"use-after-free: slot {slot} entry {i} reads "
                    f"released block {bid}")

    def check_write(self, slot: int, start: int, end: int) -> None:
        """Raise if writing tokens ``[start, end)`` of ``slot`` would land
        in a read-only shared-prefix entry or a block mapped by another
        slot (refcount > 1 — COW must run first)."""
        if not self.sanitize or end <= start:
            return
        ids = self._mapped.get(slot)
        if ids is None:
            raise KVSanitizerError(
                f"use-after-free: write to unmapped slot {slot}")
        last = self.blocks_for(end)
        if last > len(ids):
            raise KVSanitizerError(
                f"write past allocation: slot {slot} covers {len(ids)} "
                f"block(s) but the write ends at token {end}")
        nsh = self._n_shared.get(slot, 0)
        for i in range(start // self.block_size, last):
            bid = ids[i]
            rc = self._sh_rc.get(bid, 0)
            if i < nsh:
                raise KVSanitizerError(
                    f"write to read-only shared-prefix block {bid} "
                    f"(slot {slot} entry {i})")
            if rc > 1 or self.refcount.get(bid, 0) > 1:
                raise KVSanitizerError(
                    f"write to shared block {bid} with refcount {rc} "
                    f"(slot {slot} entry {i}; COW required first)")

    def note_cow(self, src: int, dst: int) -> None:
        """Record a copy-on-write ``src -> dst``: the source's content
        must still be valid and the destination must be a private
        (refcount 1) block."""
        if not self.sanitize:
            return
        if src in self._sh_poison:
            raise KVSanitizerError(
                f"COW reads released block {src} (use-after-free)")
        if self._sh_rc.get(dst, 0) != 1:
            raise KVSanitizerError(
                f"COW into block {dst} with refcount "
                f"{self._sh_rc.get(dst, 0)} != 1")

    # -- reserve / grow / free --------------------------------------------------
    def reserve(self, slot: int, n_tokens: int, live_tokens: int = None,
                shared: Optional[Sequence[int]] = None,
                boundary: Optional[int] = None) -> bool:
        """Book ``slot``'s worst-case ``n_tokens`` in the ledger and
        allocate only the blocks covering ``live_tokens`` (demand paging;
        default = everything up front). All-or-nothing: returns False
        leaving ledger and free list untouched when the reservation or the
        immediate allocation can't be covered.

        ``shared``: full prefix blocks to map read-only (refcount++; blocks
        sitting on the free list are reclaimed content-intact).
        ``boundary``: a partially-matching prefix block to copy-on-write —
        the first FRESH block (``table[slot, len(shared)]``) is its
        destination; the caller copies content before any write lands. The
        boundary source itself is never popped within this reservation."""
        assert slot not in self._mapped, f"slot {slot} already allocated"
        live = n_tokens if live_tokens is None else min(live_tokens, n_tokens)
        sh = list(shared or [])
        assert len(sh) * self.block_size <= live, \
            "shared prefix exceeds the live context"
        n_reclaim = sum(1 for b in sh if self.refcount.get(b, 0) == 0)
        if not self.can_reserve(n_tokens, live, n_shared=len(sh),
                                n_reclaim=n_reclaim):
            return False
        fresh_live = max(0, self.blocks_for(live) - len(sh))
        avoid = set()
        if boundary is not None and self.refcount.get(boundary, 0) == 0:
            # the COW source lives on the free list: it must survive until
            # the caller's copy, so this reservation may not pop it
            avoid.add(boundary)
            if fresh_live + n_reclaim + 1 > len(self._free):
                return False
        for b in sh:
            if self.refcount.get(b, 0) == 0:
                self._reclaim(b)
        fresh = [self._pop_free(avoid) for _ in range(fresh_live)]
        ids = sh + fresh
        for b in ids:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        self._mapped[slot] = ids
        self._n_shared[slot] = len(sh)
        self._reserved[slot] = max(0, self.blocks_for(n_tokens) - len(sh))
        self._tokens[slot] = n_tokens
        self._live[slot] = live
        self.table[slot, :len(ids)] = ids
        self.table[slot, len(ids):] = TRASH_BLOCK
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use())
        if self.sanitize:
            for b in sh:
                # shared blocks may already be mapped (rc > 0); the ones
                # reclaimed off the free list leave the shadow free set
                if self._sh_rc.get(b, 0) == 0:
                    self._sh_take(b, "reserve")
                self._sh_rc[b] = self._sh_rc.get(b, 0) + 1
            for b in fresh:
                # FRESH blocks must come from the free set, period — a
                # free-list entry aliasing a mapped block trips here
                self._sh_take(b, "reserve")
                self._sh_rc[b] = self._sh_rc.get(b, 0) + 1
            self._sh_slots[slot] = list(ids)
            self._sh_shared[slot] = len(sh)
            self._sh_check("reserve")
        return True

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Whole-request upfront allocation (the pre-ledger behavior, kept
        as the ``kv_alloc='upfront'`` baseline)."""
        return self.reserve(slot, n_tokens)

    def grow(self, slot: int, n_tokens: int, ahead: int = 0) -> bool:
        """Ensure ``slot``'s allocation covers ``n_tokens``, allocating the
        missing blocks (decode crossed a block boundary) plus up to
        ``ahead`` extra look-ahead blocks when the free list can spare them
        (grow hysteresis — fewer grow dispatches near block boundaries).
        True when the capacity already suffices; False when the free list
        can't cover the REQUIRED part (the caller preempts a victim and
        retries; look-ahead never forces a preemption)."""
        ids = self._mapped.get(slot)
        if self.sanitize and ids is None:
            raise KVSanitizerError(
                f"use-after-free: grow on unmapped slot {slot}")
        assert ids is not None, f"grow on unallocated slot {slot}"
        need = self.blocks_for(n_tokens)
        cap = self._n_shared[slot] + self._reserved[slot]
        assert need <= cap, f"slot {slot} growing past its reservation"
        must = need - len(ids)
        if must <= 0:
            return True
        if must > len(self._free):
            return False
        want = min(need + max(0, ahead), cap) - len(ids)
        take = max(must, min(want, len(self._free)))
        base = len(ids)
        new = [self._pop_free() for _ in range(take)]
        for b in new:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        ids.extend(new)
        self.table[slot, base:base + take] = new
        self.grows += take
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use())
        if self.sanitize:
            for b in new:
                self._sh_take(b, "grow")
                self._sh_rc[b] = self._sh_rc.get(b, 0) + 1
            self._sh_slots[slot].extend(new)
            self._sh_check("grow")
        return True

    def note_live(self, slot: int, n_tokens: int) -> None:
        """Record tokens actually written to ``slot`` (frag accounting).
        In sanitize mode the live-token DELTA is the declared write range,
        so growing it through a shared block raises."""
        if slot in self._mapped:
            if self.sanitize and n_tokens > self._live[slot]:
                self.check_write(slot, self._live[slot], n_tokens)
            self._live[slot] = n_tokens

    def free(self, slot: int) -> int:
        """Unmap ``slot``'s blocks, release its reservation, zero its table
        row. Shared blocks only return to the pool once their LAST sharer
        frees (refcount 0); returns the number of blocks actually released.
        Released blocks keep their content until reallocated, so a prefix
        index may go on referencing them (``indexed``). Sanitize mode
        raises on double-free (the plain path deliberately no-ops) and on
        refcount underflow, and records content-dead releases in
        ``last_released`` for the engine to poison on device."""
        if self.sanitize and slot not in self._sh_slots:
            raise KVSanitizerError(
                f"double free: slot {slot} has no mapping")
        ids = self._mapped.pop(slot, [])
        self._n_shared.pop(slot, None)
        self._reserved.pop(slot, None)
        self._tokens.pop(slot, None)
        self._live.pop(slot, None)
        released = 0
        dead: List[int] = []
        for bid in reversed(ids):
            if self.sanitize:
                if self.refcount.get(bid, 0) <= 0 \
                        or self._sh_rc.get(bid, 0) <= 0:
                    raise KVSanitizerError(
                        f"refcount underflow on block {bid} freeing "
                        f"slot {slot}")
                self._sh_rc[bid] -= 1
                if self._sh_rc[bid] == 0:
                    self._sh_free.add(bid)
                    if bid not in self.indexed:
                        self._sh_poison.add(bid)
                        dead.append(bid)
            self.refcount[bid] -= 1
            assert self.refcount[bid] >= 0, f"refcount underflow on {bid}"
            if self.refcount[bid] == 0:
                self._free.append(bid)
                released += 1
        self.table[slot, :] = TRASH_BLOCK
        if self.sanitize:
            self._sh_slots.pop(slot)
            self._sh_shared.pop(slot)
            self.last_released = dead
            self._sh_check("free")
        return released

    def free_all(self) -> None:
        for slot in list(self._mapped):
            self.free(slot)

    # -- warm-up (cluster prefix warm path) -------------------------------------
    def warm_blocks(self, n: int) -> Optional[List[int]]:
        """Borrow ``n`` free blocks to fill with a published prefix payload.
        The caller writes their content, registers them with its index, and
        hands them straight back via ``warm_release`` — warm blocks stay on
        the free list (refcount 0, fully reclaimable), so warming NEVER
        reduces usable capacity."""
        if n <= 0 or n > len(self._free):
            return None
        ids = [self._pop_free() for _ in range(n)]
        if self.sanitize:
            for b in ids:
                self._sh_take(b, "warm_blocks")
                self._sh_borrowed.add(b)
            self._sh_check("warm_blocks")
        return ids

    def warm_release(self, ids: Sequence[int]) -> None:
        """Return warm blocks to the BOTTOM of the LIFO free list so they
        are overwritten last."""
        if self.sanitize:
            for b in ids:                     # validate BEFORE mutating
                if b not in self._sh_borrowed:
                    raise KVSanitizerError(
                        f"warm_release of non-borrowed block {b}")
        self._free[:0] = list(ids)
        if self.sanitize:
            for b in ids:
                self._sh_borrowed.discard(b)
                self._sh_free.add(b)
                self._sh_poison.discard(b)    # warm content is valid
            self._sh_check("warm_release")

    # -- introspection ----------------------------------------------------------
    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._mapped.get(slot, []))

    def shared_blocks(self, slot: int) -> int:
        return self._n_shared.get(slot, 0)

    def covered_blocks(self, slot: int) -> int:
        return len(self._mapped.get(slot, ()))

    def blocks_in_use(self) -> int:
        """UNIQUE blocks in use: shared blocks count once however many
        slots map them."""
        return self.n_blocks - 1 - len(self._free)

    def blocks_free(self) -> int:
        return len(self._free)

    def live_tokens(self, slot: int) -> int:
        return self._live.get(slot, 0)

    def frag_tokens(self) -> int:
        """TRUE internal fragmentation: allocated token capacity beyond
        what the owning requests have actually written (live occupancy,
        not the lifetime reservation — mid-flight waste counts)."""
        return sum(len(ids) * self.block_size - self._live[s]
                   for s, ids in self._mapped.items())

    def check_no_leak(self) -> bool:
        """Every non-trash block is either free or mapped (shared blocks by
        several slots, counted once), refcounts match the mappings exactly
        (0 <= refcount; a block returns to the free list only at refcount
        0), and the ledger brackets every slot's allocation:
        live <= allocated capacity, fresh allocated <= fresh reserved."""
        rc: Dict[int, int] = {}
        for ids in self._mapped.values():
            for b in ids:
                rc[b] = rc.get(b, 0) + 1
        mapped = set(rc)
        free = set(self._free)
        if len(free) != len(self._free):             # free-list duplicates
            return False
        if free & mapped or TRASH_BLOCK in free or TRASH_BLOCK in mapped:
            return False
        if free | mapped != set(range(1, self.n_blocks)):
            return False
        for bid, c in self.refcount.items():
            if c < 0 or c != rc.get(bid, 0):
                return False
        if any(bid not in self.refcount for bid in mapped):
            return False
        if not (set(self._mapped) == set(self._reserved) == set(self._live)
                == set(self._n_shared) == set(self._tokens)):
            return False
        if not self.indexed <= set(range(1, self.n_blocks)):
            return False
        return all(self._live[s] <= len(ids) * self.block_size
                   and 0 <= self._n_shared[s] <= len(ids)
                   and len(ids) - self._n_shared[s] <= self._reserved[s]
                   for s, ids in self._mapped.items())
