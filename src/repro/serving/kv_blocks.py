"""Block-granular KV allocation for the paged cache layout.

The contiguous layout pins a full ``max_len`` KV row per slot, so memory
utilization collapses at high slot counts with mixed context lengths — the
ROADMAP's paged-KV lift. Here the engine's KV pool is ``n_blocks`` fixed-size
token blocks shared by every slot; the ``BlockManager`` owns the free list
and a per-slot block table mapping virtual token positions to pool blocks:

    virtual position t of slot s  ->  pool block table[s, t // block_size],
                                      offset t % block_size

Block id 0 is RESERVED as the trash block: unallocated table entries point
at it, so jit'd scatters can route pad/dead-row writes somewhere harmless
without data-dependent shapes, and gathers through an unallocated entry read
garbage that position masking already hides. Real allocations hand out ids
from [1, n_blocks).

Allocation is DEMAND-PAGED through a reservation ledger. Admission books a
request's worst-case token need (``ceil(total_tokens / block_size)`` blocks)
as a *reservation* — so admission control stays sound — but only allocates
blocks covering the tokens it will write now (the prefill context);
``grow`` allocates the next block when decode crosses a block boundary.
The ledger may overcommit the pool (``overcommit`` > 1 books more reserved
blocks than physically exist), betting that EOS-early requests release
capacity before everyone reaches worst case; when the bet loses and a grow
finds the free list dry, the engine preempts a victim slot (its KV blocks
round-trip through the shared tensor store — see serving/engine.py).
A single request's worst case must always fit the pool physically, so a
slot that is alone can never wedge on its own reservation.

``reserve(slot, n, live_tokens=None)`` with the default ``live_tokens``
allocates everything up front — the pre-ledger behavior, kept as the
``kv_alloc="upfront"`` A/B baseline (``alloc`` is its alias).

``note_live`` records tokens actually written so ``frag_tokens`` reports
TRUE internal fragmentation (allocated capacity minus live occupancy), not
the smaller waste-vs-lifetime-reservation number.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

TRASH_BLOCK = 0


class BlockManager:
    def __init__(self, n_blocks: int, block_size: int, max_slots: int,
                 max_blocks_per_slot: int, overcommit: float = 1.0):
        assert n_blocks >= 2, "need at least the trash block plus one"
        assert block_size >= 1
        assert overcommit >= 1.0, "overcommit < 1 would idle physical blocks"
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks_per_slot = max_blocks_per_slot
        self.overcommit = float(overcommit)
        # LIFO free list keeps recently-freed (cache-warm) blocks hot
        self._free: List[int] = list(range(n_blocks - 1, TRASH_BLOCK, -1))
        # per-slot block table; row width = blocks needed for max_len
        self.table = np.full((max_slots, max_blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self._owned: Dict[int, List[int]] = {}
        self._reserved: Dict[int, int] = {}   # ledger: worst-case blocks
        self._tokens: Dict[int, int] = {}     # requested lifetime tokens
        self._live: Dict[int, int] = {}       # tokens actually written
        self.peak_blocks = 0
        self.grows = 0                        # decode-time block allocations

    # -- sizing -----------------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.block_size)

    def reservation_cap(self) -> int:
        """Ledger capacity: physical blocks scaled by the overcommit bet."""
        return int(self.overcommit * (self.n_blocks - 1))

    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    def can_reserve(self, n_tokens: int, live_tokens: int = None) -> bool:
        live = n_tokens if live_tokens is None else min(live_tokens, n_tokens)
        need_res = self.blocks_for(n_tokens)
        return (need_res <= self.max_blocks_per_slot
                # worst case must fit the pool physically: a slot running
                # alone must be able to grow to its reservation, or
                # preemption could thrash without ever making room
                and need_res <= self.n_blocks - 1
                and self.reserved_blocks() + need_res
                <= self.reservation_cap()
                and self.blocks_for(live) <= len(self._free))

    def can_alloc(self, n_tokens: int) -> bool:
        return self.can_reserve(n_tokens)

    # -- reserve / grow / free --------------------------------------------------
    def reserve(self, slot: int, n_tokens: int,
                live_tokens: int = None) -> bool:
        """Book ``slot``'s worst-case ``n_tokens`` in the ledger and
        allocate only the blocks covering ``live_tokens`` (demand paging;
        default = everything up front). All-or-nothing: returns False
        leaving ledger and free list untouched when the reservation or the
        immediate allocation can't be covered."""
        assert slot not in self._owned, f"slot {slot} already allocated"
        live = n_tokens if live_tokens is None else min(live_tokens, n_tokens)
        if not self.can_reserve(n_tokens, live):
            return False
        need = self.blocks_for(live)
        ids = [self._free.pop() for _ in range(need)]
        self._owned[slot] = ids
        self._reserved[slot] = self.blocks_for(n_tokens)
        self._tokens[slot] = n_tokens
        self._live[slot] = live
        self.table[slot, :need] = ids
        self.table[slot, need:] = TRASH_BLOCK
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use())
        return True

    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Whole-request upfront allocation (the pre-ledger behavior, kept
        as the ``kv_alloc='upfront'`` baseline)."""
        return self.reserve(slot, n_tokens)

    def grow(self, slot: int, n_tokens: int) -> bool:
        """Ensure ``slot``'s allocation covers ``n_tokens``, allocating the
        missing blocks (decode crossed a block boundary). True when the
        capacity already suffices; False when the free list can't cover it
        (the caller preempts a victim and retries)."""
        ids = self._owned.get(slot)
        assert ids is not None, f"grow on unallocated slot {slot}"
        need = self.blocks_for(n_tokens)
        assert need <= self._reserved[slot], \
            f"slot {slot} growing past its reservation"
        extra = need - len(ids)
        if extra <= 0:
            return True
        if extra > len(self._free):
            return False
        base = len(ids)
        new = [self._free.pop() for _ in range(extra)]
        ids.extend(new)
        self.table[slot, base:base + extra] = new
        self.grows += extra
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use())
        return True

    def note_live(self, slot: int, n_tokens: int) -> None:
        """Record tokens actually written to ``slot`` (frag accounting)."""
        if slot in self._owned:
            self._live[slot] = n_tokens

    def free(self, slot: int) -> int:
        """Return ``slot``'s blocks to the pool, release its reservation,
        zero its table row."""
        ids = self._owned.pop(slot, [])
        self._reserved.pop(slot, None)
        self._tokens.pop(slot, None)
        self._live.pop(slot, None)
        self._free.extend(reversed(ids))
        self.table[slot, :] = TRASH_BLOCK
        return len(ids)

    def free_all(self) -> None:
        for slot in list(self._owned):
            self.free(slot)

    # -- introspection ----------------------------------------------------------
    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self._owned.values())

    def blocks_free(self) -> int:
        return len(self._free)

    def live_tokens(self, slot: int) -> int:
        return self._live.get(slot, 0)

    def frag_tokens(self) -> int:
        """TRUE internal fragmentation: allocated token capacity beyond
        what the owning requests have actually written (live occupancy,
        not the lifetime reservation — mid-flight waste counts)."""
        return sum(len(ids) * self.block_size - self._live[s]
                   for s, ids in self._owned.items())

    def check_no_leak(self) -> bool:
        """Every non-trash block is either free or owned exactly once, and
        the ledger brackets every slot's allocation:
        live <= allocated capacity, allocated <= reserved."""
        owned = [b for ids in self._owned.values() for b in ids]
        seen = owned + self._free
        if not (len(seen) == len(set(seen)) == self.n_blocks - 1
                and TRASH_BLOCK not in seen):
            return False
        if not (set(self._owned) == set(self._reserved)
                == set(self._live)):
            return False
        return all(self._live[s] <= len(ids) * self.block_size
                   and len(ids) <= self._reserved[s]
                   for s, ids in self._owned.items())
