"""Block-granular KV allocation for the paged cache layout.

The contiguous layout pins a full ``max_len`` KV row per slot, so memory
utilization collapses at high slot counts with mixed context lengths — the
ROADMAP's paged-KV lift. Here the engine's KV pool is ``n_blocks`` fixed-size
token blocks shared by every slot; the ``BlockManager`` owns the free list
and a per-slot block table mapping virtual token positions to pool blocks:

    virtual position t of slot s  ->  pool block table[s, t // block_size],
                                      offset t % block_size

Block id 0 is RESERVED as the trash block: unallocated table entries point
at it, so jit'd scatters can route pad/dead-row writes somewhere harmless
without data-dependent shapes, and gathers through an unallocated entry read
garbage that position masking already hides. Real allocations hand out ids
from [1, n_blocks).

Allocation is whole-request up front (``ceil(total_tokens / block_size)``
blocks at admission, freed on finish/eviction): a request admitted can never
hit an out-of-blocks condition mid-decode, so backpressure lives entirely at
admission (``Engine`` counts the rejections in ``EngineStats.alloc_failures``
and leaves the request queued instead of OOM-ing the pool).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

TRASH_BLOCK = 0


class BlockManager:
    def __init__(self, n_blocks: int, block_size: int, max_slots: int,
                 max_blocks_per_slot: int):
        assert n_blocks >= 2, "need at least the trash block plus one"
        assert block_size >= 1
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks_per_slot = max_blocks_per_slot
        # LIFO free list keeps recently-freed (cache-warm) blocks hot
        self._free: List[int] = list(range(n_blocks - 1, TRASH_BLOCK, -1))
        # per-slot block table; row width = blocks needed for max_len
        self.table = np.full((max_slots, max_blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self._owned: Dict[int, List[int]] = {}
        self._tokens: Dict[int, int] = {}     # requested tokens per slot
        self.peak_blocks = 0

    # -- sizing -----------------------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.block_size)

    def can_alloc(self, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens)
        return need <= len(self._free) and need <= self.max_blocks_per_slot

    # -- alloc / free -----------------------------------------------------------
    def alloc(self, slot: int, n_tokens: int) -> bool:
        """Reserve blocks covering ``n_tokens`` for ``slot``. All-or-nothing:
        returns False when the pool can't cover the request, leaving the
        free list untouched (the engine counts rejections in
        ``EngineStats.alloc_failures``)."""
        assert slot not in self._owned, f"slot {slot} already allocated"
        if not self.can_alloc(n_tokens):
            return False
        need = self.blocks_for(n_tokens)
        ids = [self._free.pop() for _ in range(need)]
        self._owned[slot] = ids
        self._tokens[slot] = n_tokens
        self.table[slot, :need] = ids
        self.table[slot, need:] = TRASH_BLOCK
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use())
        return True

    def free(self, slot: int) -> int:
        """Return ``slot``'s blocks to the pool; zero its table row."""
        ids = self._owned.pop(slot, [])
        self._tokens.pop(slot, None)
        self._free.extend(reversed(ids))
        self.table[slot, :] = TRASH_BLOCK
        return len(ids)

    def free_all(self) -> None:
        for slot in list(self._owned):
            self.free(slot)

    # -- introspection ----------------------------------------------------------
    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    def blocks_in_use(self) -> int:
        return sum(len(v) for v in self._owned.values())

    def blocks_free(self) -> int:
        return len(self._free)

    def frag_tokens(self) -> int:
        """Internal fragmentation: allocated token capacity beyond what the
        owning requests asked for (the tail of each slot's last block)."""
        return sum(len(ids) * self.block_size - self._tokens[s]
                   for s, ids in self._owned.items())

    def check_no_leak(self) -> bool:
        """Every non-trash block is either free or owned exactly once."""
        owned = [b for ids in self._owned.values() for b in ids]
        seen = owned + self._free
        return (len(seen) == len(set(seen)) == self.n_blocks - 1
                and TRASH_BLOCK not in seen)
