"""Shape-stable batched continuous-batching engine (execution plane v2).

Slot-based continuous batching over a fixed KV/state cache, rebuilt for
admission throughput and trace stability:

* **Paged block-KV cache** (``kv_layout="paged"``, the default for
  attention families) — the KV lives in a pool of fixed-size token blocks
  shared by every slot, with a per-slot block table mapping virtual
  positions to pool blocks (``serving/kv_blocks.py``), so memory scales
  with *actual* context lengths instead of ``max_batch * max_len`` — the
  lever that lets mixed-length workloads run the large batches the
  roofline estimator assumes. When the pool can't cover a request the
  engine refuses admission (``EngineStats.alloc_failures`` — backpressure,
  not OOM), skipping ahead a bounded window so one oversized request
  can't starve fit-able smaller ones behind it. ``kv_layout="contig"``
  keeps the dense slot-row layout (required for SSM/MoE/enc-dec, and the
  A/B baseline for benchmarks/bench_kv_paging.py).
* **Demand-paged block allocation** (``kv_alloc="lazy"``, the default) —
  admission books a request's worst-case ``ceil(total_ctx / block_size)``
  blocks as a *reservation* in the block manager's ledger (admission
  control stays sound) but allocates only the blocks covering the prefill
  context; ``step()`` grows a slot by one block when decode crosses a
  block boundary (``EngineStats.block_grows``). With ``kv_overcommit > 1``
  the ledger books more reserved blocks than physically exist, betting
  that EOS-early requests free capacity before everyone reaches worst
  case; when a grow then finds the free list dry, the engine PREEMPTS a
  victim slot (fewest generated tokens): its live KV blocks are exported
  (position-exact, the §5.1 invariant), its blocks freed, and the request
  parked on ``take_preempted()`` for KV-attach re-admission — the global
  server publishes the payload to the shared tensor store and requeues;
  a standalone engine re-attaches it itself once capacity frees. Greedy
  outputs stay byte-identical across grow and preempt/re-admit paths.
  ``kv_alloc="upfront"`` keeps whole-request allocation at admission (a
  lazily-admitted pool can never preempt under ``kv_overcommit=1.0``
  either: reservations never exceed physical blocks, so every grow is
  covered).
* **Prefix-sharing KV cache** (``prefix_share=True``, paged layout) — a
  block-aligned prefix index (``serving/prefix_index.py``) is consulted at
  admission: a request extending a cached prefix maps the shared blocks
  into its slot table (refcounted, read-only), COPY-ON-WRITES the first
  partially-shared boundary block, and prefills ONLY the divergent suffix
  (``EngineStats.prefix_hits`` / ``prefix_shared_tokens`` /
  ``cow_copies``). Freed blocks keep content until reallocated, so a hot
  prefix survives its requests; ``hot_prefixes``/``warm_prefix`` round
  shared-prefix payloads through the tensor store so re-placed pipelines
  warm up instead of recomputing (``prefix_warmups``). Greedy outputs stay
  byte-identical to the no-sharing engine (prefix activations are causally
  independent of the suffix).
* **Block-granular KV migration** — ``export_kv``/``import_kv`` round-trip
  a live request's blocks through the shared tensor store, so a migrated
  request re-attaches its KV instead of recomputing it (§5.1 upgraded via
  §5.2's store; see serving/server.py).
* **Batched, bucketed prefill** — waiting requests are admitted in groups
  of ``prefill_group``, right-padded to a power-of-2 length bucket, so the
  jit'd prefill traces O(log max_len) shapes instead of one per prompt
  length (``EngineStats.prefill_retraces`` proves the bound). Causal
  masking makes right-padding exact for dense-attention families;
  SSM/hybrid trunks carry recurrent state through pad tokens and MoE
  expert capacity is shared across the flattened token stream, so those
  admit at exact length (and MoE at batch 1) to stay output-exact.
* **Batched chunked prefill** — contexts longer than ``prefill_chunk``
  (the migration-recompute case) prefill chunk-by-chunk between decode
  steps, bounding head-of-line blocking for live slots during interruption
  storms. Pendings admitted together advance as ONE dispatch per scheduling
  step (a ``_PendingGroup``), not a batch-1 loop per request. Under the
  paged layout each chunk's K/V is written STRAIGHT into the owning slots'
  pool blocks through a snapshot of their block tables — no transient
  group cache, no terminal scatter dispatch (``EngineStats.chunk_direct``
  vs ``chunk_scatters``); contig keeps the transient path as the A/B
  baseline. Enc-dec requests chunk too: the cross-attention cache is
  warmed by one encoder pass when the group cache is created.
* **Fused jit'd slot scatter** — one jit'd gather/scatter installs a whole
  prefill group into its slots (through the block tables under the paged
  layout), replacing the per-cache-key Python ``at[].set`` loop.
* **Masked, donated decode** — dead slots are masked (their cache position
  is frozen) instead of decoding token 0 forever; the cache buffer is
  donated across steps.

Migration semantics: re-admission prefills ``prompt + generated[:-1]`` and
lets the first decode step feed ``generated[-1]``, reproducing the
uninterrupted run's cache layout byte-for-byte. With greedy sampling an
interrupted run emits identical tokens to an uninterrupted one whether it
recomputes or KV-attaches (paper §5.1, tested end-to-end in
tests/test_engine_v2.py and tests/test_kv_paging.py).

``admission="legacy"`` keeps the seed's per-request batch-1 eager path
(contiguous layout only) as the baseline for
benchmarks/bench_engine_throughput.py.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.serving.kv_blocks import KV_POISON, BlockManager
from repro.serving.request import ServeRequest

_donation_filter_installed = False


def _silence_cpu_donation_warnings() -> None:
    """CPU has no buffer donation EVER, so the per-compile warning carries
    no signal there — silence it once so driver/example logs stay readable.
    On TPU/GPU the warning stays live: a missed donation is a real
    regression on accelerators."""
    global _donation_filter_installed
    if _donation_filter_installed or jax.default_backend() != "cpu":
        return
    _donation_filter_installed = True
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not")


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0           # requests prefilled (admissions)
    prefill_batches: int = 0    # batched prefill dispatches
    prefill_chunks: int = 0     # chunked-prefill chunk dispatches
    chunk_direct: int = 0       # paged chunks written in-place (no scatter)
    chunk_scatters: int = 0     # contig finisher scatters (transient path)
    decode_steps: int = 0
    tokens_out: int = 0
    retraces: int = 0           # total jit traces (prefill+decode+scatter)
    prefill_retraces: int = 0   # prefill traces — bounded by bucket count
    alloc_failures: int = 0     # paged admissions refused (backpressure)
    block_grows: int = 0        # blocks allocated on demand mid-decode
    preemptions: int = 0        # slots evicted when a grow found a dry pool
    kv_exports: int = 0         # KV block sets published for migration
    kv_imports: int = 0         # re-admissions that attached KV (no prefill)
    prefix_hits: int = 0        # admissions that mapped shared-prefix blocks
    prefix_shared_tokens: int = 0   # prefill tokens NOT recomputed
    cow_copies: int = 0         # boundary blocks copied before first write
    prefix_warmups: int = 0     # published prefixes attached from the store
    grow_ahead_skips: int = 0   # boundary crossings served by look-ahead
    admit_deferred: int = 0     # admissions deferred for free-block headroom


@dataclasses.dataclass
class _PendingMember:
    req: ServeRequest
    slot: int
    tokens: np.ndarray
    done: bool = False


@dataclasses.dataclass
class _PendingGroup:
    """Long-context admissions prefilled chunk-by-chunk as ONE batched
    dispatch per scheduling step (members share the chunk boundary)."""
    members: List[_PendingMember]
    base: int = 0
    cache: Any = None


class Engine:
    def __init__(self, cfg: ArchConfig, params: Any, max_batch: int = 8,
                 max_len: int = 256, model_kw: Optional[Dict] = None,
                 np_rng: Optional[np.random.RandomState] = None,
                 use_pallas: bool = False, prefill_group: int = 4,
                 prefill_bucket: int = 16, prefill_chunk: int = 0,
                 admission: str = "bucketed", kv_layout: str = "auto",
                 block_size: int = 16, n_blocks: int = 0,
                 kv_alloc: str = "lazy", kv_overcommit: float = 1.0,
                 admit_window: int = 4, prefix_share: bool = False,
                 grow_ahead: int = 1, admit_headroom: bool = True,
                 kv_sanitize: Optional[bool] = None,
                 victim_policy: str = "cost", placement: Any = None):
        assert admission in ("bucketed", "legacy"), admission
        assert kv_layout in ("auto", "paged", "contig"), kv_layout
        assert kv_alloc in ("lazy", "upfront"), kv_alloc
        assert victim_policy in ("cost", "fewest"), victim_policy
        _silence_cpu_donation_warnings()
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.admission = admission
        self.prefill_chunk = int(prefill_chunk)
        # MoE expert capacity is computed over the flattened (batch, seq)
        # token stream, so pad tokens/rows would compete with real tokens
        # for expert slots and change which tokens get dropped — batched or
        # padded prefill is not output-exact for MoE. Admit batch-1 at
        # exact length until the router masks pads (ROADMAP follow-up).
        self._moe = cfg.n_experts > 0
        self._group = 1 if self._moe else max(1, min(prefill_group,
                                                     max_batch))
        self._min_bucket = max(1, min(prefill_bucket, max_len))
        # paged layout: dense-attention families only (SSM/hybrid carry
        # recurrent state, not KV rows; enc-dec has a second cache; MoE
        # rides the contig path with its batch-1 admission). The legacy
        # baseline predates the block table and stays contiguous.
        paged_ok = not (cfg.is_encdec or cfg.family in ("ssm", "hybrid")
                        or self._moe or admission == "legacy")
        if kv_layout == "auto":
            kv_layout = "paged" if paged_ok else "contig"
        elif kv_layout == "paged" and not paged_ok:
            raise ValueError(
                f"kv_layout='paged' unsupported for {cfg.name} "
                f"(family={cfg.family}, admission={admission})")
        self.kv_layout = kv_layout
        self.kv_alloc = kv_alloc
        self._lazy = kv_alloc == "lazy" and kv_layout == "paged"
        self._admit_window = max(0, int(admit_window))
        self._grow_ahead = max(1, int(grow_ahead))
        self._admit_headroom = bool(admit_headroom)
        # preemption-victim choice: "cost" picks the slot with the lowest
        # estimated re-admission cost (restore vs recompute, priced by
        # cluster/recovery); "fewest" is the legacy fewest-generated rule,
        # which remains the tie-break within a cost bucket. ``placement``
        # (core.estimator.Placement) prices the recompute branch; without
        # it only the restore (store round-trip) branch is priced.
        self._victim_policy = victim_policy
        self._placement = placement
        self._victim_costs: Dict[int, float] = {}
        self._victim_spec = None
        self.bm: Optional[BlockManager] = None
        self._prefix = None
        self._tbl_dirty = False
        self.enc_frames = 8           # stubbed frontend frame count
        if kv_layout == "paged":
            mb = -(-max_len // block_size)
            if n_blocks <= 0:
                n_blocks = max_batch * mb + 1     # capacity-parity + trash
            self.bm = BlockManager(n_blocks, block_size, max_batch, mb,
                                   overcommit=kv_overcommit,
                                   sanitize=kv_sanitize)
        elif prefix_share:
            raise ValueError("prefix_share requires kv_layout='paged'")
        # model AFTER the block manager: sanitize mode arms the device-side
        # poison probe — paged gathers emit a max readable |K|/|V| that is
        # checkify'd against KV_POISON, so a stale block-table read fires
        # at the offending dispatch instead of only via output divergence
        model_kw = dict(model_kw or {})
        model_kw.setdefault("use_pallas", use_pallas)
        self.use_pallas = model_kw["use_pallas"]
        if self.bm is not None:
            model_kw.setdefault("kv_probe", self.bm.sanitize)
        self._kv_probe = bool(model_kw.get("kv_probe", False))
        self.model = build_model(cfg, **model_kw)
        if kv_layout == "paged":
            self.cache = self.model.init_cache(
                max_batch, max_len, vector_pos=True, kv_layout="paged",
                n_blocks=n_blocks, block_size=block_size)
            if prefix_share:
                if admission == "legacy":
                    raise ValueError(
                        "prefix_share requires the bucketed paged engine")
                from repro.serving.prefix_index import PrefixIndex
                self._prefix = PrefixIndex(block_size, self.bm)
                self.bm.on_reuse = self._prefix.invalidate_block
        elif cfg.is_encdec:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               s_enc=self.enc_frames,
                                               vector_pos=True)
        else:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               ring=False, vector_pos=True)
        self.slots: List[Optional[ServeRequest]] = [None] * max_batch
        self.stats = EngineStats()
        self._pending: List[_PendingGroup] = []
        self._admit_finished: List[ServeRequest] = []
        # requests evicted by a dry-pool grow, with their exported KV
        # payloads; drained by the global server (publish + requeue) or
        # re-attached internally once capacity frees (standalone use)
        self._preempted: List[Tuple[ServeRequest, Dict]] = []
        self._legacy_shapes: set = set()

        def prefill_fn(params, tokens, last_pos):
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            if cfg.is_encdec:
                frames = jnp.zeros(
                    (tokens.shape[0], self.enc_frames, cfg.d_model),
                    jnp.float32)
                return self.model.prefill(
                    params, {"embeds": frames, "tokens": tokens},
                    max_len=self.max_len, last_pos=last_pos)
            return self.model.prefill(params, {"tokens": tokens},
                                      max_len=self.max_len, ring=False,
                                      last_pos=last_pos)

        def chunk_fn(params, cache, tokens, base, last_pos):
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            return self.model.prefill_chunk(params, cache, tokens, base,
                                            last_pos=last_pos)

        def chunk_paged_fn(params, cache, tokens, base, last_idx, rem,
                           tbls):
            # direct paged chunking: the chunk's K/V land in the owning
            # slots' pool blocks as they are computed, each row routed
            # through a snapshot of its slot's block table — no transient
            # group cache, no terminal scatter. ``rem`` masks columns past
            # a row's remaining tokens (and whole finished rows, rem=0)
            # into the trash block.
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            return self.model.prefill_chunk(params, cache, tokens, base,
                                            last_pos=last_idx,
                                            block_tbl=tbls, lens=rem)

        def enc_warm_fn(params, frames):
            # chunked enc-dec prefill: the transient group cache needs the
            # cross-attention K/V resident before the first decoder chunk
            self.stats.retraces += 1
            cache = self.model.init_cache(frames.shape[0], self.max_len,
                                          s_enc=self.enc_frames)
            enc_out = self.model.encode(params, frames)
            cache["ck"], cache["cv"] = self.model.cross_kv(params, enc_out)
            return cache

        def scatter_contig_fn(cache, group, slots, rows, lens):
            # Install ``group`` (batch G, possibly with pad rows remapped to
            # row 0 / slot[0] so duplicate writes agree) into slot rows.
            self.stats.retraces += 1
            out = dict(cache)
            for key, small in group.items():
                if key == "pos":
                    out["pos"] = cache["pos"].at[slots].set(lens)
                elif key == "slot_pos":
                    continue              # engine caches are linear
                else:
                    sel = jnp.take(small, rows, axis=1)
                    out[key] = cache[key].at[:, slots].set(
                        sel.astype(cache[key].dtype))
            return out

        def scatter_paged_fn(cache, group, slots, rows, lens, tbls):
            # Same contract, but K/V route through the destination slots'
            # block tables (``tbls``: (G, max_blocks)). Positions past a
            # row's real length land in the reserved trash block 0.
            self.stats.retraces += 1
            bs = cache["k"].shape[2]
            out = dict(cache)
            for key, small in group.items():
                if key == "pos":
                    out["pos"] = cache["pos"].at[slots].set(lens)
                elif key in ("slot_pos", "block_tbl"):
                    continue
                else:
                    sel = jnp.take(small, rows, axis=1)   # (L,G,S,nkv,d)
                    t = jnp.arange(sel.shape[2])
                    dest = jnp.take(tbls, t // bs, axis=1)       # (G, S)
                    dest = jnp.where(t[None, :] < lens[:, None], dest, 0)
                    out[key] = cache[key].at[:, dest, t % bs].set(
                        sel.astype(cache[key].dtype))
            out["block_tbl"] = cache["block_tbl"].at[slots].set(tbls)
            return out

        def decode_fn(params, cache, tokens, live):
            self.stats.retraces += 1
            pos0 = cache["pos"]
            if "block_tbl" in cache:
                # dead/pending rows must not write their (masked, garbage)
                # token through their tables: mid-chunk pending slots hold
                # LIVE in-place chunk KV now, so route those writes to the
                # trash block instead
                tbl = cache["block_tbl"]
                cache = dict(cache,
                             block_tbl=jnp.where(live[:, None], tbl, 0))
                logits, new_cache = self.model.decode_step(params, cache,
                                                           tokens)
                new_cache["block_tbl"] = tbl
            else:
                logits, new_cache = self.model.decode_step(params, cache,
                                                           tokens)
            # dead slots: freeze the cache position instead of advancing on
            # a dummy token (their rows are fully overwritten on reuse)
            new_cache["pos"] = jnp.where(live, new_cache["pos"], pos0)
            return logits, new_cache

        def suffix_fn(params, cache, tokens, bases, lens, slots, tbls):
            # prefix-sharing admission: prefill only the divergent suffix;
            # the shared prefix is read through the (updated) block tables
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            logits, out = self.model.prefill_suffix(params, cache, tokens,
                                                    bases, tbls, lens)
            out["pos"] = out["pos"].at[slots].set(bases + lens)
            out["block_tbl"] = out["block_tbl"].at[slots].set(tbls)
            return logits, out

        def cow_fn(cache, src, dst):
            # copy-on-write a partially-shared boundary block BEFORE any
            # divergent suffix write lands in it
            self.stats.retraces += 1
            out = dict(cache)
            out["k"] = cache["k"].at[:, dst].set(cache["k"][:, src])
            out["v"] = cache["v"].at[:, dst].set(cache["v"][:, src])
            return out

        def warm_fn(cache, k, v, ids):
            # install a published shared-prefix payload into free blocks
            self.stats.retraces += 1
            out = dict(cache)
            out["k"] = cache["k"].at[:, ids].set(k.astype(cache["k"].dtype))
            out["v"] = cache["v"].at[:, ids].set(v.astype(cache["v"].dtype))
            return out

        self._prefill_b = jax.jit(prefill_fn)
        self._chunk = jax.jit(chunk_fn, donate_argnums=(1,))
        self._enc_warm = jax.jit(enc_warm_fn)
        # the group cache is NOT donated: a pending group's cache outlives
        # the scatter of its early finishers
        scatter = (scatter_paged_fn if kv_layout == "paged"
                   else scatter_contig_fn)
        self._scatter = jax.jit(scatter, donate_argnums=(0,))
        # with the poison probe armed these dispatches read through block
        # tables and carry checkify.checks; _run discharges the error
        if self._kv_probe:
            from jax.experimental import checkify

            def probed(f):
                return checkify.checkify(f, errors=checkify.user_checks)
        else:
            def probed(f):
                return f
        self._decode = jax.jit(probed(decode_fn), donate_argnums=(1,))
        self._suffix = jax.jit(probed(suffix_fn), donate_argnums=(1,))
        self._chunk_paged = jax.jit(probed(chunk_paged_fn),
                                    donate_argnums=(1,))
        self._cow = jax.jit(cow_fn, donate_argnums=(0,))
        self._warm = jax.jit(warm_fn, donate_argnums=(0,))

    def _run(self, fn, *args):
        """Dispatch a (possibly checkify'd) jit: with the poison probe
        armed the device-side checks are discharged here — sanitize/debug
        mode only, the probe-off hot path pays no extra sync."""
        if not self._kv_probe:
            return fn(*args)
        err, out = fn(*args)
        err.throw()
        return out

    # -- buckets ----------------------------------------------------------------
    def bucket_lens(self) -> List[int]:
        """Prefill length buckets: powers of two up to max_len."""
        out, b = [], self._min_bucket
        while b < self.max_len:
            out.append(b)
            b *= 2
        out.append(self.max_len)
        return out

    def _bucket(self, n: int) -> int:
        if self.cfg.family in ("ssm", "hybrid") or self._moe:
            return n      # recurrent state / expert capacity: no padding
        b = self._min_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _use_chunked(self, n: int) -> bool:
        # MoE excluded: per-chunk expert capacity differs from full-prefill
        # capacity, changing token drops (same exactness issue as padding).
        # Enc-dec chunks fine: the cross-attention cache is warmed once at
        # group creation and the decoder chunks like any attention family.
        if (self.prefill_chunk <= 0
                or self.cfg.family in ("ssm", "hybrid") or self._moe):
            return False
        n_chunks = -(-n // self.prefill_chunk)
        return n > self.prefill_chunk and \
            n_chunks * self.prefill_chunk <= self.max_len

    @staticmethod
    def _prefill_tokens(req: ServeRequest) -> List[int]:
        """Context to prefill: the full context *minus* the last generated
        token, which the first decode step feeds — so a recomputed cache is
        laid out identically to an uninterrupted run's."""
        ctx = req.full_context()
        return ctx[:-1] if req.generated else ctx

    @staticmethod
    def _total_tokens(req: ServeRequest) -> int:
        """Token capacity a request needs for its whole lifetime: current
        context plus every token it may still generate."""
        return req.ctx_len + req.max_new_tokens - len(req.generated)

    # -- slot management --------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active(self) -> List[ServeRequest]:
        return [s for s in self.slots if s is not None]

    def _pending_slots(self) -> set:
        return {m.slot for g in self._pending for m in g.members
                if not m.done}

    def _free_blocks(self, slot: int) -> None:
        if self.bm is not None and self.bm.slot_blocks(slot):
            self.bm.free(slot)
            self._poison_released()
            self._tbl_dirty = True

    def _poison_released(self) -> None:
        """Sanitize mode: overwrite the device content of blocks whose
        last mapping just died with the KV_POISON sentinel — a stale
        gather through a dangling table entry then produces unmissable
        garbage instead of silently-plausible old KV. Blocks a prefix
        index still references are exempt (their content is the warm
        prefix feature, kept valid until reallocation)."""
        if self.bm is None or not self.bm.sanitize \
                or not self.bm.last_released:
            return
        ids = jnp.asarray(self.bm.last_released)
        self.cache["k"] = self.cache["k"].at[:, ids].set(KV_POISON)
        self.cache["v"] = self.cache["v"].at[:, ids].set(KV_POISON)
        self.bm.last_released = []

    def _sync_block_tbl(self) -> None:
        """Push the host-side block table to the device cache when
        allocations changed since the last dispatch."""
        if self.bm is not None and self._tbl_dirty:
            self.cache["block_tbl"] = jnp.asarray(self.bm.table)
            self._tbl_dirty = False

    def block_stats(self) -> Dict[str, int]:
        """Paged-pool occupancy/fragmentation counters (empty for contig)."""
        if self.bm is None:
            return {}
        return {"blocks_in_use": self.bm.blocks_in_use(),
                "blocks_free": self.bm.blocks_free(),
                "reserved_blocks": self.bm.reserved_blocks(),
                "outstanding_blocks": self.bm.outstanding_blocks(),
                "frag_tokens": self.bm.frag_tokens(),
                "peak_blocks": self.bm.peak_blocks,
                "block_size": self.bm.block_size,
                "n_blocks": self.bm.n_blocks,
                "block_grows": self.stats.block_grows,
                "preemptions": self.stats.preemptions,
                "alloc_failures": self.stats.alloc_failures,
                "prefix_hits": self.stats.prefix_hits,
                "cow_copies": self.stats.cow_copies}

    # -- admission --------------------------------------------------------------
    def admit(self, req: ServeRequest) -> bool:
        return bool(self.admit_many([req]))

    def admit_many(self, reqs: Sequence[ServeRequest]
                   ) -> List[ServeRequest]:
        """Admit from ``reqs`` in order, bounded by free slots and (paged)
        the block manager's reservation ledger.

        Lazy mode books each request's worst-case blocks in the ledger but
        allocates only the prefill-context blocks (``step()`` grows on
        demand). A request the pool can't cover is SKIPPED rather than
        blocking the whole queue — admission keeps scanning up to
        ``admit_window`` failures so fit-able smaller requests behind an
        oversized one still drain (approximate FIFO). The returned list is
        therefore NOT necessarily a prefix of ``reqs``; callers must
        remove admitted requests from their queues by identity.

        Requests are grouped by length bucket and prefilled in batches of
        ``prefill_group``; long contexts go to the chunked path (grouped
        into one dispatch per step). Finished ones surface via ``step()``."""
        free = self.free_slots()
        admitted: List[ServeRequest] = []
        skipped = 0
        # free blocks live slots will claim at their NEXT boundary crossing;
        # admissions that would eat into it are deferred, so a fresh
        # admission can't guarantee an immediate preemption storm
        imminent = self._imminent_blocks() if (
            self._admit_headroom and self._lazy) else 0
        groups: Dict[int, List[Tuple[ServeRequest, List[int], int]]] = {}
        sgroups: Dict[int, List] = {}
        chunked: List[Tuple[ServeRequest, List[int], int]] = []
        # blocks pre-indexed THIS call whose content only materializes when
        # the full-prefill groups dispatch (before any suffix dispatch)
        fresh_this_call: set = set()
        for r in reqs:               # done reqs need no slot: pass through
            if r.done:
                self._admit_finished.append(r)
                admitted.append(r)
                continue
            if not free:
                break                # no slot for anyone: skipping can't help
            assert self._total_tokens(r) <= self.max_len, \
                "context exceeds engine max_len"
            slot = free[0]
            toks: Optional[List[int]] = None
            match = None
            if self.bm is not None:
                # prefill length without materializing the token list (it
                # is only built once the reservation succeeds) — unless the
                # prefix index needs it for matching
                ctx = r.ctx_len - (1 if r.generated else 0)
                live = ctx if self._lazy else None
                if self._prefix is not None:
                    toks = self._prefill_tokens(r)
                    match = self._prefix.match(toks)
                shared = match.full if match is not None else None
                n_sh = len(shared) if shared else 0
                if imminent > 0:
                    fresh = max(0, self.bm.blocks_for(ctx) - n_sh)
                    if self.bm.blocks_free() - fresh < imminent:
                        self.stats.admit_deferred += 1
                        skipped += 1
                        if skipped >= self._admit_window:
                            break
                        continue
                boundary = match.boundary if match is not None else None
                if not self.bm.reserve(slot, self._total_tokens(r), live,
                                       shared=shared, boundary=boundary):
                    self.stats.alloc_failures += 1
                    skipped += 1
                    if skipped >= self._admit_window:
                        break        # backpressure: leave the rest queued
                    continue         # skip ahead: smaller reqs may still fit
                self.bm.note_live(slot, ctx)         # true-frag accounting
                self._tbl_dirty = True
            free.pop(0)
            if toks is None:
                toks = self._prefill_tokens(r)
            if self.admission == "legacy":
                self._admit_one_legacy(r, toks, slot)
            elif match is not None and match.n_tokens > 0:
                cow = None
                if match.boundary is not None:
                    # COW the partially-shared boundary block before any
                    # suffix write lands in it. A donor admitted THIS call
                    # hasn't prefilled yet — its copy is deferred to the
                    # suffix dispatch (full-prefill groups run first, and
                    # the donor's mapping keeps the source block pinned).
                    dst = int(self.bm.table[slot, len(match.full)])
                    if match.boundary in fresh_this_call:
                        cow = (match.boundary, dst)
                    else:
                        self.bm.note_cow(match.boundary, dst)
                        self.cache = self._cow(self.cache, jnp.asarray(
                            match.boundary), jnp.asarray(dst))
                        self.stats.cow_copies += 1
                self.stats.prefix_hits += 1
                self.stats.prefix_shared_tokens += match.n_tokens
                sgroups.setdefault(
                    self._bucket(len(toks) - match.n_tokens), []).append(
                    (r, toks, slot, match.n_tokens, cow))
            elif self._use_chunked(len(toks)):
                self.slots[slot] = r
                chunked.append((r, toks, slot))
            else:
                groups.setdefault(self._bucket(len(toks)), []).append(
                    (r, toks, slot))
                if self._prefix is not None:
                    # pre-index so later requests in this SAME call share;
                    # safe because every full-prefill group dispatches
                    # before the first suffix dispatch
                    self._index_insert(toks, slot)
                    fresh_this_call.update(self.bm.slot_blocks(slot))
            admitted.append(r)
        for blen, items in sorted(groups.items()):
            for i in range(0, len(items), self._group):
                self._admit_group(items[i:i + self._group], blen)
        for blen, items in sorted(sgroups.items()):
            for i in range(0, len(items), self._group):
                self._admit_group_suffix(items[i:i + self._group], blen)
        # pendings admitted together share a group: one chunk dispatch per
        # step for the whole group instead of a batch-1 loop
        for i in range(0, len(chunked), self._group):
            members = [_PendingMember(r, slot, np.asarray(toks, np.int32))
                       for r, toks, slot in chunked[i:i + self._group]]
            self._pending.append(_PendingGroup(members))
        return admitted

    def _admit_group(self, items, blen: int) -> None:
        """One batched prefill + fused scatter for <= prefill_group
        requests sharing a length bucket."""
        g, n = self._group, len(items)
        tokens = np.zeros((g, blen), np.int32)
        lens = np.zeros((g,), np.int32)
        slots = np.zeros((g,), np.int32)
        rows = np.zeros((g,), np.int32)
        for j, (r, toks, slot) in enumerate(items):
            tokens[j, :len(toks)] = toks
            lens[j] = len(toks)
            slots[j] = slot
            rows[j] = j
        # pad rows replicate row 0: duplicate slot writes carry identical
        # data, keeping the scatter deterministic
        lens[n:] = lens[0]
        slots[n:] = slots[0]
        logits, group_cache = self._prefill_b(
            self.params, jnp.asarray(tokens), jnp.asarray(lens - 1))
        self._scatter_group(group_cache, slots, rows, lens)
        # jaxlint: disable=host-sync -- intended: sampled first tokens
        # must land on the host to fill req.generated
        first = np.asarray(self.model.sample_greedy(logits))
        self.stats.prefill_batches += 1
        for j, (r, toks, slot) in enumerate(items):
            self._install(r, slot, first[j])

    def _admit_group_suffix(self, items, blen: int) -> None:
        """Prefix-sharing admission: one batched SUFFIX prefill for <=
        prefill_group requests sharing a suffix-length bucket. Each row's
        shared prefix is already resident (mapped via its block table); the
        dispatch computes/writes only the divergent suffix and samples the
        first token from each row's last real suffix position."""
        g, n = self._group, len(items)
        tokens = np.zeros((g, blen), np.int32)
        bases = np.zeros((g,), np.int32)
        lens = np.zeros((g,), np.int32)
        slots = np.zeros((g,), np.int32)
        for j, (r, toks, slot, n_sh, cow) in enumerate(items):
            if cow is not None:       # deferred COW: donor prefilled by now
                self.bm.note_cow(cow[0], cow[1])
                self.cache = self._cow(self.cache, jnp.asarray(cow[0]),
                                       jnp.asarray(cow[1]))
                self.stats.cow_copies += 1
            suf = toks[n_sh:]
            tokens[j, :len(suf)] = suf
            bases[j] = n_sh
            lens[j] = len(suf)
            slots[j] = slot
        # pad rows replicate row 0: duplicate slot writes carry identical
        # data, keeping the scatter deterministic
        tokens[n:] = tokens[0]
        bases[n:] = bases[0]
        lens[n:] = lens[0]
        slots[n:] = slots[0]
        tbls = self.bm.table[slots]
        logits, self.cache = self._run(
            self._suffix, self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(bases), jnp.asarray(lens), jnp.asarray(slots),
            jnp.asarray(tbls))
        # jaxlint: disable=host-sync -- intended: sampled first tokens
        # must land on the host to fill req.generated
        first = np.asarray(self.model.sample_greedy(logits))
        self.stats.prefill_batches += 1
        for j, (r, toks, slot, n_sh, cow) in enumerate(items):
            self._index_insert(toks, slot)
            self._install(r, slot, first[j])

    def _index_insert(self, toks, slot: int) -> None:
        """Register a freshly-prefilled context's blocks with the prefix
        index (BEFORE ``_install`` may free an immediately-done slot — a
        freed block's content stays valid, which is exactly how a hot
        prefix survives its first request's completion)."""
        if self._prefix is not None:
            self._prefix.insert(toks, self.bm.slot_blocks(slot))

    def _scatter_group(self, group_cache, slots, rows, lens) -> None:
        """Fused install of a (remapped) group cache into slot rows, routed
        through the block tables under the paged layout."""
        args = [jnp.asarray(slots), jnp.asarray(rows), jnp.asarray(lens)]
        if self.bm is not None:
            args.append(jnp.asarray(self.bm.table[slots]))
        self.cache = self._scatter(self.cache, group_cache, *args)

    def _install(self, req: ServeRequest, slot: int, first_tok) -> None:
        """Post-prefill bookkeeping shared by all admission paths."""
        self.slots[slot] = req
        self.stats.prefills += 1
        if not req.generated:        # fresh request: prefill emits 1st token
            req.generated.append(int(first_tok))
            self.stats.tokens_out += 1
        if req.done:
            self.slots[slot] = None
            self._free_blocks(slot)
            self._admit_finished.append(req)

    def _admit_one_legacy(self, req: ServeRequest, toks: List[int],
                          slot: int) -> None:
        """Seed admission path: eager batch-1 exact-length prefill plus a
        per-key Python scatter loop (one trace per distinct length)."""
        if len(toks) not in self._legacy_shapes:
            self._legacy_shapes.add(len(toks))
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
        tokens = jnp.asarray([toks], jnp.int32)
        if self.cfg.is_encdec:
            frames = jnp.zeros((1, self.enc_frames, self.cfg.d_model),
                               jnp.float32)
            logits, one = self.model.prefill(
                self.params, {"embeds": frames, "tokens": tokens},
                max_len=self.max_len)
        else:
            logits, one = self.model.prefill(self.params,
                                             {"tokens": tokens},
                                             max_len=self.max_len,
                                             ring=False)
        self._scatter_cache_legacy(slot, one, len(toks))
        self.stats.prefill_batches += 1
        self._install(req, slot, self.model.sample_greedy(logits)[0])

    def _scatter_cache_legacy(self, slot: int, one: Dict,
                              ctx_len: int) -> None:
        """Write a single-request cache (batch dim 1) into ``slot``."""
        def scatter(big, small, batch_axis):
            idx = [slice(None)] * big.ndim
            idx[batch_axis] = slice(slot, slot + 1)
            pad = [(0, b - s) for b, s in
                   zip(big[tuple(idx)].shape, small.shape)]
            if any(p != (0, 0) for p in pad):
                small = jnp.pad(small, pad)
            return big.at[tuple(idx)].set(small.astype(big.dtype))

        for key, small in one.items():
            if key == "pos":
                self.cache["pos"] = self.cache["pos"].at[slot].set(ctx_len)
            elif key == "slot_pos":
                continue                      # engine caches are linear
            else:
                self.cache[key] = scatter(self.cache[key], small, 1)

    # -- chunked prefill --------------------------------------------------------
    def _chunk_init(self, g: int):
        """Transient group cache for the contig chunked path (enc-dec
        groups additionally warm the cross-attention cache with one
        encoder pass over the stubbed frames)."""
        if self.cfg.is_encdec:
            frames = jnp.zeros((g, self.enc_frames, self.cfg.d_model),
                               jnp.float32)
            return self._enc_warm(self.params, frames)
        return self.model.init_cache(g, self.max_len, ring=False)

    def _advance_pending(self) -> None:
        """One chunk of prefill work per pending GROUP, interleaved between
        decode steps (bounds head-of-line blocking; one dispatch covers
        every member at the shared chunk boundary).

        Paged engines write each chunk's K/V STRAIGHT into the owning
        slots' pool blocks, routed through a snapshot of their block
        tables — no transient group cache is ever allocated and finishing
        needs no scatter (``stats.chunk_direct``). Contig engines keep the
        transient-cache + terminal-scatter path (the A/B baseline, and the
        only option without block routing)."""
        c = self.prefill_chunk
        still: List[_PendingGroup] = []
        for grp in self._pending:
            g = len(grp.members)
            chunk = np.zeros((g, c), np.int32)
            last_idx = np.zeros((g,), np.int32)
            rem = np.zeros((g,), np.int32)
            for j, m in enumerate(grp.members):
                if m.done:
                    continue        # finished early: row computes pad zeros
                end = min(grp.base + c, len(m.tokens))
                chunk[j, :end - grp.base] = m.tokens[grp.base:end]
                last_idx[j] = min(c - 1, len(m.tokens) - 1 - grp.base)
                rem[j] = end - grp.base
            if self.bm is not None:
                # snapshot the members' table rows; finished members (whose
                # slots now decode, or may even have been reused) are routed
                # wholesale to the trash block — their rows compute don't-care
                tbls = self.bm.table[
                    [m.slot for m in grp.members]].copy()
                tbls[rem == 0] = 0
                if self.bm.sanitize:
                    for j, m in enumerate(grp.members):
                        if rem[j]:
                            # jaxlint: disable=host-sync -- host numpy rem
                            # (sanitizer-armed debug path only)
                            hi = grp.base + int(rem[j])
                            self.bm.check_write(m.slot, grp.base, hi)
                logits, self.cache = self._run(
                    self._chunk_paged, self.params, self.cache,
                    jnp.asarray(chunk), jnp.asarray(grp.base, jnp.int32),
                    jnp.asarray(last_idx), jnp.asarray(rem),
                    jnp.asarray(tbls))
                self.stats.chunk_direct += 1
            else:
                if grp.cache is None:
                    grp.cache = self._chunk_init(g)
                logits, grp.cache = self._chunk(
                    self.params, grp.cache, jnp.asarray(chunk),
                    jnp.asarray(grp.base, jnp.int32), jnp.asarray(last_idx))
            self.stats.prefill_chunks += 1
            grp.base += c
            finishers = [(j, m) for j, m in enumerate(grp.members)
                         if not m.done and grp.base >= len(m.tokens)]
            if finishers:
                # jaxlint: disable=host-sync -- intended: finishers' first
                # tokens must land on the host to fill req.generated
                first = np.asarray(self.model.sample_greedy(logits))
                self._finish_pending(grp, finishers, first)
            if not all(m.done for m in grp.members):
                still.append(grp)
        self._pending = still

    def _finish_pending(self, grp: _PendingGroup, finishers, first
                        ) -> None:
        """Finish fully-prefilled members. Paged groups already wrote every
        chunk in place through the block tables — only the per-slot cache
        positions need setting; contig groups scatter out of the transient
        group cache (one fused dispatch for this step's finishers)."""
        slots = np.array([m.slot for _, m in finishers], np.int32)
        lens = np.array([len(m.tokens) for _, m in finishers], np.int32)
        if self.bm is not None:
            self.cache["pos"] = self.cache["pos"].at[
                jnp.asarray(slots)].set(jnp.asarray(lens))
        else:
            rows = np.array([j for j, _ in finishers], np.int32)
            self._scatter_group(grp.cache, slots, rows, lens)
            self.stats.chunk_scatters += 1
        for j, m in finishers:
            m.done = True
            self.slots[m.slot] = None     # _install re-marks the slot
            self._index_insert(list(m.tokens), m.slot)
            self._install(m.req, m.slot, first[j])

    # -- decode-time grow / preemption ------------------------------------------
    def _victim_cost(self, slot: int) -> float:
        """Estimated re-admission cost of preempting this slot: the
        cheaper of the store restore round trip
        (``recovery.preemption_seconds``) and a context recompute
        (``recovery.recompute_seconds``, when a placement prices it) —
        the same estimates the cluster simulator charges. Context is
        bucketed to the block grid before pricing: two slots whose KV
        occupies the same number of blocks cost the same to re-admit, so
        the fewest-generated rule stays the live tie-break instead of
        being drowned by sub-block context noise."""
        r = self.slots[slot]
        bs = self.bm.block_size if self.bm is not None else 16
        ctx_b = max(bs, -(-r.ctx_len // bs) * bs)
        c = self._victim_costs.get(ctx_b)
        if c is None:
            from repro.cluster.recovery import (preemption_seconds,
                                                recompute_seconds)
            if self._victim_spec is None:
                self._victim_spec = self.cfg.to_modelspec()
            c = preemption_seconds(self._victim_spec, ctx_b)
            if self._placement is not None:
                c = min(c, recompute_seconds(
                    self._victim_spec, self._placement, ctx_b,
                    chunk=self.prefill_chunk, max_len=self.max_len))
            self._victim_costs[ctx_b] = c
        return c

    def _pick_victim(self, candidates: List[int]) -> Optional[int]:
        """Preemption victim. Policy "cost": the slot whose re-admission
        is estimated cheapest (``_victim_cost``); fewest generated tokens
        breaks cost ties (least progress to park), slot index breaks the
        rest. Policy "fewest": the legacy fewest-generated-only rule."""
        owned = [i for i in candidates if self.slots[i] is not None]
        if not owned:
            return None
        if self._victim_policy == "fewest":
            return min(owned, key=lambda i: (len(self.slots[i].generated),
                                             i))
        return min(owned, key=lambda i: (self._victim_cost(i),
                                         len(self.slots[i].generated), i))

    def _preempt(self, slot: int) -> None:
        """Evict a live slot to make room: export its KV (position-exact,
        so re-admission can attach byte-identically), free its blocks, and
        park (request, payload) for the server to publish + requeue."""
        req = self.slots[slot]
        payload = self.export_kv(slot)
        self.slots[slot] = None
        self.bm.free(slot)
        self._poison_released()
        self._tbl_dirty = True
        self.stats.preemptions += 1
        self._preempted.append((req, payload))

    def _ensure_grow(self, live: List[int]) -> List[int]:
        """Demand paging's decode-side half: every slot decoding this step
        writes token ``pos``, so its block table must cover ``pos + 1``
        tokens — which is the request's ``ctx_len`` (§5.1 invariant:
        everything but the last generated token is in the cache), so no
        device sync is needed. Grow crossing slots by a block; when the
        free list is dry, preempt victims until the grow fits (preempting
        the grower itself ends its grow — it re-attaches later). Returns
        the slots that still decode this step."""
        grows0 = self.bm.grows
        alive = list(live)
        k = self._grow_ahead
        for slot in list(live):
            if self.slots[slot] is None:        # preempted by an earlier grow
                continue
            need = self.slots[slot].ctx_len
            if k > 1:
                crossing = (self.bm.blocks_for(need)
                            > self.bm.blocks_for(need - 1))
                if crossing and (self.bm.covered_blocks(slot)
                                 >= self.bm.blocks_for(need)):
                    # hysteresis win: an earlier look-ahead grow already
                    # covers this boundary crossing — no dispatch, no
                    # preempt/re-admit thrash near pool-full
                    self.stats.grow_ahead_skips += 1
                    continue
            # look ahead only with free-list headroom; exactly one block
            # when the pool is tight (look-ahead must never force preempts)
            ahead = (k - 1 if k > 1
                     and self.bm.blocks_free() >= len(alive) + k else 0)
            while not self.bm.grow(slot, need, ahead=ahead):
                ahead = 0
                victim = self._pick_victim(alive)
                assert victim is not None, "grow failed with no live victim"
                self._preempt(victim)
                alive.remove(victim)
                if victim == slot:
                    break
        if self.bm.grows > grows0:
            self.stats.block_grows += self.bm.grows - grows0
            self._tbl_dirty = True
        return [i for i in alive if self.slots[i] is not None]

    def _imminent_blocks(self) -> int:
        """Free blocks live slots will need at their NEXT decode step's
        boundary crossing — the headroom admission must not consume."""
        if self.bm is None:
            return 0
        pend = self._pending_slots()
        n = 0
        for i, r in enumerate(self.slots):
            if r is None or r.done or i in pend:
                continue
            n += max(0, self.bm.blocks_for(r.ctx_len + 1)
                     - self.bm.covered_blocks(i))
        return n

    # -- decode -----------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        """One scheduling iteration: re-attach preempted requests capacity
        now allows, advance chunked prefills, grow block tables crossing a
        block boundary (preempting victims when the pool is dry), then
        decode one token for every live slot; returns finished requests."""
        if self._preempted:
            self._readmit_preempted()
        if self._pending:
            self._advance_pending()
        finished = list(self._admit_finished)
        self._admit_finished.clear()
        pending = self._pending_slots()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and i not in pending]
        if not live:
            return finished
        if self._lazy:           # upfront allocations can never need a grow
            live = self._ensure_grow(live)
            if not live:
                return finished
        tokens = np.zeros((self.max_batch, 1), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        for i in live:
            tokens[i, 0] = self.slots[i].generated[-1]
            mask[i] = True
        if self.bm is not None and self.bm.sanitize:
            for i in live:
                # this dispatch reads each live slot's KV history and
                # writes the incoming token at position ctx_len - 1
                self.bm.check_read(i, self.slots[i].ctx_len - 1)
                self.bm.check_write(i, self.slots[i].ctx_len - 1,
                                    self.slots[i].ctx_len)
        self._sync_block_tbl()
        logits, self.cache = self._run(self._decode, self.params,
                                       self.cache, jnp.asarray(tokens),
                                       jnp.asarray(mask))
        # jaxlint: disable=host-sync -- intended: THE per-step sync point.
        # Sampled tokens feed the next step's host-side scheduling; every
        # other sync in step() has been eliminated, so the pipeline stalls
        # exactly once per decode step.
        nxt = np.asarray(self.model.sample_greedy(logits))[:, 0]
        for i in live:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            self.stats.tokens_out += 1
            if self.bm is not None:
                # tokens in the cache == ctx_len - 1 (§5.1 invariant)
                self.bm.note_live(i, req.ctx_len - 1)
            if req.done:
                finished.append(req)
                self.slots[i] = None
                self._free_blocks(i)
        self.stats.decode_steps += 1
        return finished

    def _readmit_preempted(self) -> None:
        """Re-attach parked preempted requests whose blocks now fit
        (standalone operation; the global server normally drains
        ``take_preempted`` every round before this can fire)."""
        still: List[Tuple[ServeRequest, Dict]] = []
        for req, payload in self._preempted:
            if not self.import_kv(req, payload):
                still.append((req, payload))
        self._preempted = still

    def take_preempted(self) -> List[Tuple[ServeRequest, Dict]]:
        """Drain (request, KV payload) pairs evicted by dry-pool grows —
        the global server publishes the payloads to the tensor store and
        requeues the requests for KV-attach re-admission."""
        out, self._preempted = self._preempted, []
        return out

    def drain(self) -> List[ServeRequest]:
        """Run until every admitted request finishes."""
        out = []
        while (self.active() or self._pending or self._admit_finished
               or self._preempted):
            out.extend(self.step())
        return out

    def evict_all(self) -> List[ServeRequest]:
        """Simulated engine death: return in-flight requests (their
        ``generated`` lists are the preserved output — paper §5.1),
        including preempted ones still parked for re-admission."""
        reqs = [s for s in self.slots if s is not None]
        reqs += [r for r, _ in self._preempted]
        reqs += [r for r in self._admit_finished if r not in reqs]
        self.slots = [None] * self.max_batch
        self._pending = []
        self._admit_finished = []
        self._preempted = []
        if self.bm is not None:
            self.bm.free_all()
            self._tbl_dirty = True
        return reqs

    # -- block-granular KV migration (paper §5.1 x §5.2) ------------------------
    def export_kv(self, slot: int, pos: Optional[int] = None) -> Dict:
        """Snapshot a live slot's KV blocks for publication to the tensor
        store. The payload is position-exact: importing it reproduces the
        donor engine's cache state for that request byte-for-byte."""
        assert self.bm is not None, "KV export requires the paged layout"
        if pos is None:
            # §5.1 invariant: a live, fully-prefilled slot's cache holds
            # everything but the last generated token, so its position is
            # ctx_len - 1. Reading it from the request avoids syncing the
            # device pos array on the dry-pool preemption hot path (the
            # same identity note_live/import_kv already rely on).
            pos = self.slots[slot].ctx_len - 1
        self.bm.check_read(slot, pos)      # no-op unless sanitize mode
        nb = -(-pos // self.bm.block_size) if pos > 0 else 0
        ids = jnp.asarray(self.bm.table[slot, :nb].copy())
        self.stats.kv_exports += 1
        return {"k": self.cache["k"][:, ids], "v": self.cache["v"][:, ids],
                "pos": int(pos), "block_size": self.bm.block_size,
                "arch": self.cfg.name}

    def export_live_kv(self) -> Dict[int, Dict]:
        """Payloads for every live, fully-prefilled slot, keyed by request
        id (mid-chunked-prefill slots have incomplete KV and are skipped —
        those requests fall back to recompute)."""
        if self.bm is None:
            return {}
        pend = self._pending_slots()
        # §5.1 invariant (see export_kv): pos == ctx_len - 1 for every
        # live, fully-prefilled slot — no device sync needed here either
        return {r.rid: self.export_kv(slot, r.ctx_len - 1)
                for slot, r in enumerate(self.slots)
                if r is not None and slot not in pend}

    def import_kv(self, req: ServeRequest, payload: Dict) -> bool:
        """Admit ``req`` by attaching a published KV payload instead of
        recomputing its context. Returns False (caller falls back to the
        recompute path) on any incompatibility: contig layout, different
        arch or block size, no slot, no blocks, or a payload whose position
        doesn't match the request's migration state."""
        if self.bm is None or payload.get("arch") != self.cfg.name \
                or payload.get("block_size") != self.bm.block_size:
            return False
        if req.done or not req.generated:
            return False
        # invariant of the §5.1 layout: everything but the last generated
        # token is in the cache; the first decode step feeds that token
        if payload["pos"] != req.ctx_len - 1:
            return False
        free = self.free_slots()
        if not free or self._total_tokens(req) > self.max_len:
            return False
        slot = free[0]
        # lazy: allocate only the blocks the payload fills (the ledger
        # books the worst case); the rest arrive via decode-time grow
        live = payload["pos"] if self._lazy else None
        if not self.bm.reserve(slot, self._total_tokens(req), live):
            return False             # no capacity yet: caller retries later
        self.bm.note_live(slot, payload["pos"])
        self._tbl_dirty = True
        nb = payload["k"].shape[1]
        ids = jnp.asarray(self.bm.table[slot, :nb].copy())
        self.cache["k"] = self.cache["k"].at[:, ids].set(
            payload["k"].astype(self.cache["k"].dtype))
        self.cache["v"] = self.cache["v"].at[:, ids].set(
            payload["v"].astype(self.cache["v"].dtype))
        self.cache["pos"] = self.cache["pos"].at[slot].set(payload["pos"])
        self.slots[slot] = req
        self.stats.kv_imports += 1
        return True

    # -- shared-prefix publication / warm-up (tentpole, cluster half) -----------
    def export_prefix(self, tokens) -> Optional[Dict]:
        """Snapshot the KV blocks of a fully-indexed token run for
        publication to the tensor store (content-addressed by the run
        itself). Full blocks only: partial boundary blocks keep mutating
        under decode and are never published."""
        if self._prefix is None:
            return None
        ids = self._prefix.full_run(tokens)
        if not ids:
            return None
        idsj = jnp.asarray(ids)
        toks = [int(t) for t in tokens[:len(ids) * self.bm.block_size]]
        return {"k": self.cache["k"][:, idsj], "v": self.cache["v"][:, idsj],
                "tokens": toks, "block_size": self.bm.block_size,
                "arch": self.cfg.name}

    def hot_runs(self, min_hits: int = 2) -> List[Tuple[int, ...]]:
        """The hottest fully-indexed token runs (matched at least
        ``min_hits`` times). Cheap — no KV gather — so the server can
        content-address them against the store BEFORE exporting."""
        return [] if self._prefix is None else self._prefix.hot(min_hits)

    def hot_prefixes(self, min_hits: int = 2) -> List[Dict]:
        """Payloads for the hottest shared-prefix runs — the server
        publishes them to the store."""
        out = []
        for run in self.hot_runs(min_hits):
            p = self.export_prefix(run)
            if p is not None:
                out.append(p)
        return out

    def warm_prefix(self, payload: Dict) -> bool:
        """Attach a published shared-prefix payload: write its KV into
        free blocks, index them, and hand the blocks straight back to the
        free list (refcount 0) — warm, fully reclaimable, and mapped
        read-only by the next admission matching the prefix. Returns False
        (recompute fallback) on any incompatibility or when the prefix is
        already resident."""
        if self._prefix is None or self.bm is None:
            return False
        if payload.get("arch") != self.cfg.name \
                or payload.get("block_size") != self.bm.block_size:
            return False
        toks = [int(t) for t in payload["tokens"]]
        nb = len(toks) // self.bm.block_size
        if nb <= 0 or payload["k"].shape[1] < nb:
            return False
        if len(self._prefix.full_run(toks)) >= nb:
            return False             # already warm (or computed locally)
        ids = self.bm.warm_blocks(nb)
        if ids is None:
            return False             # pool too tight right now
        idsj = jnp.asarray(ids)
        self.cache = self._warm(self.cache, jnp.asarray(payload["k"][:, :nb]),
                                jnp.asarray(payload["v"][:, :nb]), idsj)
        self._prefix.insert(toks[:nb * self.bm.block_size], ids)
        self.bm.warm_release(ids)
        self.stats.prefix_warmups += 1
        return True
