"""Shape-stable batched continuous-batching engine (execution plane v2).

Slot-based continuous batching over a fixed (max_batch, max_len) KV/state
cache, rebuilt for admission throughput and trace stability:

* **Batched, bucketed prefill** — waiting requests are admitted in groups
  of ``prefill_group``, right-padded to a power-of-2 length bucket, so the
  jit'd prefill traces O(log max_len) shapes instead of one per prompt
  length (``EngineStats.prefill_retraces`` proves the bound). Causal
  masking makes right-padding exact for dense-attention families;
  SSM/hybrid trunks carry recurrent state through pad tokens and MoE
  expert capacity is shared across the flattened token stream, so those
  admit at exact length (and MoE at batch 1) to stay output-exact.
* **Chunked prefill** — contexts longer than ``prefill_chunk`` (the
  migration-recompute case: context = prompt + preserved output) prefill
  chunk-by-chunk between decode steps, bounding head-of-line blocking for
  live slots during interruption storms.
* **Fused jit'd slot scatter** — one jit'd gather/scatter installs a whole
  prefill group into its slots (cache donated via ``donate_argnums``),
  replacing the per-cache-key Python ``at[].set`` loop.
* **Masked, donated decode** — dead slots are masked (their cache position
  is frozen) instead of decoding token 0 forever; the cache buffer is
  donated across steps.

Migration semantics fix over the seed engine: re-admission prefills
``prompt + generated[:-1]`` and lets the first decode step feed
``generated[-1]``, reproducing the uninterrupted run's cache layout
byte-for-byte (the seed prefilled the full context and then fed the last
token again, duplicating it at two positions). With greedy sampling an
interrupted run now emits identical tokens to an uninterrupted one
(paper §5.1, tested end-to-end in tests/test_engine_v2.py).

``admission="legacy"`` keeps the seed's per-request batch-1 eager path
(with the semantics fix) as the baseline for
benchmarks/bench_engine_throughput.py.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.serving.request import ServeRequest

_donation_filter_installed = False


def _silence_cpu_donation_warnings() -> None:
    """CPU has no buffer donation EVER, so the per-compile warning carries
    no signal there — silence it once so driver/example logs stay readable.
    On TPU/GPU the warning stays live: a missed donation is a real
    regression on accelerators."""
    global _donation_filter_installed
    if _donation_filter_installed or jax.default_backend() != "cpu":
        return
    _donation_filter_installed = True
    warnings.filterwarnings(
        "ignore", message="Some donated buffers were not")


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0           # requests prefilled (admissions)
    prefill_batches: int = 0    # batched prefill dispatches
    prefill_chunks: int = 0     # chunked-prefill chunk dispatches
    decode_steps: int = 0
    tokens_out: int = 0
    retraces: int = 0           # total jit traces (prefill+decode+scatter)
    prefill_retraces: int = 0   # prefill traces — bounded by bucket count


@dataclasses.dataclass
class _Pending:
    """A long-context admission being prefilled chunk-by-chunk."""
    req: ServeRequest
    slot: int
    tokens: np.ndarray
    base: int = 0
    cache: Any = None


class Engine:
    def __init__(self, cfg: ArchConfig, params: Any, max_batch: int = 8,
                 max_len: int = 256, model_kw: Optional[Dict] = None,
                 np_rng: Optional[np.random.RandomState] = None,
                 use_pallas: bool = False, prefill_group: int = 4,
                 prefill_bucket: int = 16, prefill_chunk: int = 0,
                 admission: str = "bucketed"):
        assert admission in ("bucketed", "legacy"), admission
        _silence_cpu_donation_warnings()
        self.cfg = cfg
        model_kw = dict(model_kw or {})
        model_kw.setdefault("use_pallas", use_pallas)
        self.use_pallas = model_kw["use_pallas"]
        self.model = build_model(cfg, **model_kw)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.admission = admission
        self.prefill_chunk = int(prefill_chunk)
        # MoE expert capacity is computed over the flattened (batch, seq)
        # token stream, so pad tokens/rows would compete with real tokens
        # for expert slots and change which tokens get dropped — batched or
        # padded prefill is not output-exact for MoE. Admit batch-1 at
        # exact length until the router masks pads (ROADMAP follow-up).
        self._moe = cfg.n_experts > 0
        self._group = 1 if self._moe else max(1, min(prefill_group,
                                                     max_batch))
        self._min_bucket = max(1, min(prefill_bucket, max_len))
        self.enc_frames = 8           # stubbed frontend frame count
        if cfg.is_encdec:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               s_enc=self.enc_frames,
                                               vector_pos=True)
        else:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               ring=False, vector_pos=True)
        self.slots: List[Optional[ServeRequest]] = [None] * max_batch
        self.stats = EngineStats()
        self._pending: List[_Pending] = []
        self._admit_finished: List[ServeRequest] = []
        self._legacy_shapes: set = set()

        def prefill_fn(params, tokens, last_pos):
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            if cfg.is_encdec:
                frames = jnp.zeros(
                    (tokens.shape[0], self.enc_frames, cfg.d_model),
                    jnp.float32)
                return self.model.prefill(
                    params, {"embeds": frames, "tokens": tokens},
                    max_len=self.max_len, last_pos=last_pos)
            return self.model.prefill(params, {"tokens": tokens},
                                      max_len=self.max_len, ring=False,
                                      last_pos=last_pos)

        def chunk_fn(params, cache, tokens, base, last_pos):
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
            return self.model.prefill_chunk(params, cache, tokens, base,
                                            last_pos=last_pos)

        def scatter_fn(cache, group, slots, rows, lens):
            # Install ``group`` (batch G, possibly with pad rows remapped to
            # row 0 / slot[0] so duplicate writes agree) into slot rows.
            self.stats.retraces += 1
            out = dict(cache)
            for key, small in group.items():
                if key == "pos":
                    out["pos"] = cache["pos"].at[slots].set(lens)
                elif key == "slot_pos":
                    continue              # engine caches are linear
                else:
                    sel = jnp.take(small, rows, axis=1)
                    out[key] = cache[key].at[:, slots].set(
                        sel.astype(cache[key].dtype))
            return out

        def decode_fn(params, cache, tokens, live):
            self.stats.retraces += 1
            logits, new_cache = self.model.decode_step(params, cache, tokens)
            # dead slots: freeze the cache position instead of advancing on
            # a dummy token (their rows are fully overwritten on reuse)
            new_cache["pos"] = jnp.where(live, new_cache["pos"],
                                         cache["pos"])
            return logits, new_cache

        self._prefill_b = jax.jit(prefill_fn)
        self._chunk = jax.jit(chunk_fn, donate_argnums=(1,))
        self._scatter = jax.jit(scatter_fn, donate_argnums=(0, 1))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

    # -- buckets ----------------------------------------------------------------
    def bucket_lens(self) -> List[int]:
        """Prefill length buckets: powers of two up to max_len."""
        out, b = [], self._min_bucket
        while b < self.max_len:
            out.append(b)
            b *= 2
        out.append(self.max_len)
        return out

    def _bucket(self, n: int) -> int:
        if self.cfg.family in ("ssm", "hybrid") or self._moe:
            return n      # recurrent state / expert capacity: no padding
        b = self._min_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _use_chunked(self, n: int) -> bool:
        # MoE excluded: per-chunk expert capacity differs from full-prefill
        # capacity, changing token drops (same exactness issue as padding)
        if (self.prefill_chunk <= 0 or self.cfg.is_encdec
                or self.cfg.family in ("ssm", "hybrid") or self._moe):
            return False
        n_chunks = -(-n // self.prefill_chunk)
        return n > self.prefill_chunk and \
            n_chunks * self.prefill_chunk <= self.max_len

    @staticmethod
    def _prefill_tokens(req: ServeRequest) -> List[int]:
        """Context to prefill: the full context *minus* the last generated
        token, which the first decode step feeds — so a recomputed cache is
        laid out identically to an uninterrupted run's."""
        ctx = req.full_context()
        return ctx[:-1] if req.generated else ctx

    # -- slot management --------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active(self) -> List[ServeRequest]:
        return [s for s in self.slots if s is not None]

    def _pending_slots(self) -> set:
        return {p.slot for p in self._pending}

    # -- admission --------------------------------------------------------------
    def admit(self, req: ServeRequest) -> bool:
        return bool(self.admit_many([req]))

    def admit_many(self, reqs: Sequence[ServeRequest]
                   ) -> List[ServeRequest]:
        """Admit a prefix of ``reqs`` bounded by free slots.

        Requests are grouped by length bucket and prefilled in batches of
        ``prefill_group``; long contexts go to the chunked path. Returns
        the admitted requests (finished ones surface via ``step()``)."""
        free = self.free_slots()
        take: List[ServeRequest] = []
        slots_needed = 0
        for r in reqs:               # strict prefix; done reqs need no slot
            if not r.done:
                if slots_needed >= len(free):
                    break
                slots_needed += 1
            take.append(r)
        if not take:
            return []
        free_iter = iter(free)
        admitted: List[ServeRequest] = []
        groups: Dict[int, List[Tuple[ServeRequest, List[int], int]]] = {}
        for r in take:
            if r.done:                # nothing to generate: pass through
                self._admit_finished.append(r)
                admitted.append(r)
                continue
            assert r.ctx_len + r.max_new_tokens - len(r.generated) \
                <= self.max_len, "context exceeds engine max_len"
            toks = self._prefill_tokens(r)
            slot = next(free_iter)
            if self.admission == "legacy":
                self._admit_one_legacy(r, toks, slot)
            elif self._use_chunked(len(toks)):
                self.slots[slot] = r
                self._pending.append(
                    _Pending(r, slot, np.asarray(toks, np.int32)))
            else:
                groups.setdefault(self._bucket(len(toks)), []).append(
                    (r, toks, slot))
            admitted.append(r)
        for blen, items in sorted(groups.items()):
            for i in range(0, len(items), self._group):
                self._admit_group(items[i:i + self._group], blen)
        return admitted

    def _admit_group(self, items, blen: int) -> None:
        """One batched prefill + fused scatter for <= prefill_group
        requests sharing a length bucket."""
        g, n = self._group, len(items)
        tokens = np.zeros((g, blen), np.int32)
        lens = np.zeros((g,), np.int32)
        slots = np.zeros((g,), np.int32)
        rows = np.zeros((g,), np.int32)
        for j, (r, toks, slot) in enumerate(items):
            tokens[j, :len(toks)] = toks
            lens[j] = len(toks)
            slots[j] = slot
            rows[j] = j
        # pad rows replicate row 0: duplicate slot writes carry identical
        # data, keeping the scatter deterministic
        lens[n:] = lens[0]
        slots[n:] = slots[0]
        logits, group_cache = self._prefill_b(
            self.params, jnp.asarray(tokens), jnp.asarray(lens - 1))
        self.cache = self._scatter(self.cache, group_cache,
                                   jnp.asarray(slots), jnp.asarray(rows),
                                   jnp.asarray(lens))
        first = np.asarray(self.model.sample_greedy(logits))
        self.stats.prefill_batches += 1
        for j, (r, toks, slot) in enumerate(items):
            self._install(r, slot, first[j])

    def _install(self, req: ServeRequest, slot: int, first_tok) -> None:
        """Post-prefill bookkeeping shared by all admission paths."""
        self.slots[slot] = req
        self.stats.prefills += 1
        if not req.generated:        # fresh request: prefill emits 1st token
            req.generated.append(int(first_tok))
            self.stats.tokens_out += 1
        if req.done:
            self.slots[slot] = None
            self._admit_finished.append(req)

    def _admit_one_legacy(self, req: ServeRequest, toks: List[int],
                          slot: int) -> None:
        """Seed admission path: eager batch-1 exact-length prefill plus a
        per-key Python scatter loop (one trace per distinct length)."""
        if len(toks) not in self._legacy_shapes:
            self._legacy_shapes.add(len(toks))
            self.stats.retraces += 1
            self.stats.prefill_retraces += 1
        tokens = jnp.asarray([toks], jnp.int32)
        if self.cfg.is_encdec:
            frames = jnp.zeros((1, self.enc_frames, self.cfg.d_model),
                               jnp.float32)
            logits, one = self.model.prefill(
                self.params, {"embeds": frames, "tokens": tokens},
                max_len=self.max_len)
        else:
            logits, one = self.model.prefill(self.params,
                                             {"tokens": tokens},
                                             max_len=self.max_len,
                                             ring=False)
        self._scatter_cache_legacy(slot, one, len(toks))
        self.stats.prefill_batches += 1
        self._install(req, slot, self.model.sample_greedy(logits)[0])

    def _scatter_cache_legacy(self, slot: int, one: Dict,
                              ctx_len: int) -> None:
        """Write a single-request cache (batch dim 1) into ``slot``."""
        def scatter(big, small, batch_axis):
            idx = [slice(None)] * big.ndim
            idx[batch_axis] = slice(slot, slot + 1)
            pad = [(0, b - s) for b, s in
                   zip(big[tuple(idx)].shape, small.shape)]
            if any(p != (0, 0) for p in pad):
                small = jnp.pad(small, pad)
            return big.at[tuple(idx)].set(small.astype(big.dtype))

        for key, small in one.items():
            if key == "pos":
                self.cache["pos"] = self.cache["pos"].at[slot].set(ctx_len)
            elif key == "slot_pos":
                continue                      # engine caches are linear
            else:
                self.cache[key] = scatter(self.cache[key], small, 1)

    # -- chunked prefill --------------------------------------------------------
    def _advance_pending(self) -> None:
        """One chunk of prefill work per pending admission, interleaved
        between decode steps (bounds head-of-line blocking)."""
        c = self.prefill_chunk
        still: List[_Pending] = []
        for p in self._pending:
            if p.cache is None:
                p.cache = self.model.init_cache(1, self.max_len, ring=False)
            end = min(p.base + c, len(p.tokens))
            chunk = np.zeros((1, c), np.int32)
            chunk[0, :end - p.base] = p.tokens[p.base:end]
            last_idx = min(c - 1, len(p.tokens) - 1 - p.base)
            logits, p.cache = self._chunk(
                self.params, p.cache, jnp.asarray(chunk),
                jnp.asarray(p.base, jnp.int32),
                jnp.asarray([last_idx], jnp.int32))
            self.stats.prefill_chunks += 1
            p.base = end
            if p.base >= len(p.tokens):
                lens = jnp.asarray([len(p.tokens)], jnp.int32)
                self.cache = self._scatter(
                    self.cache, p.cache, jnp.asarray([p.slot], jnp.int32),
                    jnp.zeros((1,), jnp.int32), lens)
                self.slots[p.slot] = None     # _install re-marks the slot
                self._install(p.req, p.slot,
                              self.model.sample_greedy(logits)[0])
            else:
                still.append(p)
        self._pending = still

    # -- decode -----------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        """One scheduling iteration: advance chunked prefills, then decode
        one token for every live slot; returns finished requests."""
        if self._pending:
            self._advance_pending()
        finished = list(self._admit_finished)
        self._admit_finished.clear()
        pending = self._pending_slots()
        live = [i for i, s in enumerate(self.slots)
                if s is not None and i not in pending]
        if not live:
            return finished
        tokens = np.zeros((self.max_batch, 1), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        for i in live:
            tokens[i, 0] = self.slots[i].generated[-1]
            mask[i] = True
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(mask))
        nxt = np.asarray(self.model.sample_greedy(logits))[:, 0]
        for i in live:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            self.stats.tokens_out += 1
            if req.done:
                finished.append(req)
                self.slots[i] = None
        self.stats.decode_steps += 1
        return finished

    def drain(self) -> List[ServeRequest]:
        """Run until every admitted request finishes."""
        out = []
        while self.active() or self._pending or self._admit_finished:
            out.extend(self.step())
        return out

    def evict_all(self) -> List[ServeRequest]:
        """Simulated engine death: return in-flight requests (their
        ``generated`` lists are the preserved output — paper §5.1)."""
        reqs = [s for s in self.slots if s is not None]
        reqs += [r for r in self._admit_finished if r not in reqs]
        self.slots = [None] * self.max_batch
        self._pending = []
        self._admit_finished = []
        return reqs
