"""Real JAX inference engine with continuous batching.

Slot-based continuous batching: a fixed (max_batch, max_len) KV/state cache;
each slot holds one request at its own position (the decode path supports
per-sequence position vectors). Admission prefills a request and scatters
its cache rows into a free slot; every ``step()`` decodes one token for all
live slots; finished slots free immediately.

This is the execution-plane engine — it actually generates tokens (small
models on CPU in tests/examples; the same code path jit-lowers for the
production meshes via launch.steps).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.serving.request import ServeRequest


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params: Any, max_batch: int = 8,
                 max_len: int = 256, model_kw: Optional[Dict] = None,
                 np_rng: Optional[np.random.RandomState] = None):
        self.cfg = cfg
        self.model = build_model(cfg, **(model_kw or {}))
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.enc_frames = 8           # stubbed frontend frame count
        if cfg.is_encdec:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               s_enc=self.enc_frames,
                                               vector_pos=True)
        else:
            self.cache = self.model.init_cache(max_batch, max_len,
                                               ring=False, vector_pos=True)
        self.slots: List[Optional[ServeRequest]] = [None] * max_batch
        self.stats = EngineStats()
        self._decode = jax.jit(self.model.decode_step)

    # -- slot management ------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active(self) -> List[ServeRequest]:
        return [s for s in self.slots if s is not None]

    def _scatter_cache(self, slot: int, one: Dict) -> None:
        """Write a single-request cache (batch dim 1) into slot ``slot``."""
        def scatter(big, small, batch_axis):
            idx = [slice(None)] * big.ndim
            idx[batch_axis] = slice(slot, slot + 1)
            pad = [(0, b - s) for b, s in
                   zip(big[tuple(idx)].shape, small.shape)]
            if any(p != (0, 0) for p in pad):
                small = jnp.pad(small, pad)
            return big.at[tuple(idx)].set(small.astype(big.dtype))

        for key, small in one.items():
            if key == "pos":
                self.cache["pos"] = self.cache["pos"].at[slot].set(small)
            elif key == "slot_pos":
                continue                      # engine caches are linear
            else:
                axis = 1                      # (L, B, ...) stacked caches
                self.cache[key] = scatter(self.cache[key], small, axis)

    # -- admission --------------------------------------------------------------
    def admit(self, req: ServeRequest) -> bool:
        """Prefill ``req``'s full context (prompt + generated — that is what
        makes migration output-preserving) into a free slot."""
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        ctx = req.full_context()
        assert len(ctx) + req.max_new_tokens - len(req.generated) \
            <= self.max_len, "context exceeds engine max_len"
        tokens = jnp.asarray([ctx], jnp.int32)
        if self.cfg.is_encdec:
            # frontend is a stub: deterministic zero frames (the decoder
            # token stream is what migration must preserve)
            frames = jnp.zeros((1, self.enc_frames, self.cfg.d_model),
                               jnp.float32)
            logits, one = self.model.prefill(
                self.params, {"embeds": frames, "tokens": tokens},
                max_len=self.max_len)
        else:
            logits, one = self.model.prefill(self.params, {"tokens": tokens},
                                             max_len=self.max_len,
                                             ring=False)
        self._scatter_cache(slot, one)
        self.slots[slot] = req
        self.stats.prefills += 1
        if not req.generated:        # fresh request: prefill emits 1st token
            tok = int(self.model.sample_greedy(logits)[0])
            req.generated.append(tok)
            self.stats.tokens_out += 1
        return True

    # -- decode -----------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        """One decode iteration for all live slots; returns finished."""
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return []
        tokens = jnp.asarray(
            [[self.slots[i].generated[-1] if (self.slots[i] is not None
                                              and self.slots[i].generated)
              else 0] for i in range(self.max_batch)], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens)
        nxt = np.asarray(self.model.sample_greedy(logits))[:, 0]
        finished = []
        for i in live:
            req = self.slots[i]
            req.generated.append(int(nxt[i]))
            self.stats.tokens_out += 1
            if req.done:
                finished.append(req)
                self.slots[i] = None
        self.stats.decode_steps += 1
        return finished

    def drain(self) -> List[ServeRequest]:
        """Run until every admitted request finishes."""
        out = []
        while self.active():
            out.extend(self.step())
        return out

    def evict_all(self) -> List[ServeRequest]:
        """Simulated engine death: return in-flight requests (their
        ``generated`` lists are the preserved output — paper §5.1)."""
        reqs = [s for s in self.slots if s is not None]
        self.slots = [None] * self.max_batch
        return reqs
