"""Global server: pipelines + instance manager + fault tolerance (paper §3,
§5) over the REAL engine (execution plane).

A ``ServingPipeline`` binds an ``Engine`` to a set of instance ids (from a
placement). The ``GlobalServer``:

  * dispatches requests weighted-round-robin by pipeline throughput (§3),
    with weights derived from ``core.estimator`` stage latencies when the
    pipeline's ``Placement`` is known (instead of a hardcoded 1.0);
  * with ``dispatch="throughput"`` or ``"cost"`` (Mélange-style,
    ``core.buckets``): classifies each request into an
    (input-len, output-len) bucket and shunts it to the pipeline with the
    best estimated output tokens/s (throughput policy) or tokens/s per
    $/hr — i.e. lowest $/token — (cost policy) *for that bucket*, so
    long-context requests land on high-HBM pipelines instead of
    collapsing a low-HBM pipeline's Eq. 6 batch bound. The round-robin
    credit scheme is kept per bucket, so every pipeline with nonzero
    bucket weight still receives its proportional share (no starvation);
    a request's bucket is assigned once and preserved across
    interrupt/requeue (migrated requests carry grown contexts, which must
    not reclassify them). With prefix sharing on, near-ties break toward
    a pipeline already holding the request's published prefix;
  * advances the virtual clock by the estimator's bottleneck decode-step
    latency per scheduling round (``tick``), so reported throughput is
    consistent with the simulator instead of a hardcoded 0.01 s/round;
  * on a spot interruption: collects in-flight requests WITH their generated
    outputs (output-preserving request migration, §5.1) and re-queues them —
    onto surviving pipelines, or back onto the interrupted pipeline's own
    queue when none survive (it revives at ``down_until``; requests must
    never be silently dropped);
  * with ``use_kv_migration`` (and paged-KV engines + a store): additionally
    publishes each interrupted request's live KV blocks to the tensor store
    (``Engine.export_kv``), so re-admission ATTACHES the blocks
    (``Engine.import_kv``) and skips context recomputation entirely —
    SpotServe-style KV migration carried by the §5.2 store instead of a
    point-to-point transfer racing the grace period. Any incompatibility
    (contig engine, different block size, stale payload) falls back to the
    §5.1 recompute path;
  * pool preemptions ride the SAME path: when a demand-paged engine's
    decode-time grow finds the block pool dry (overcommitted ledger), the
    victim's exported KV payload is published to the store — capped first
    by the store's byte budget (``TensorStore(budget_bytes=...)``) — and
    the request requeued at the queue front for KV-attach re-admission;
  * rebuilds the pipeline with a replacement instance: with the shared
    tensor store the new engine ATTACHES to resident weights (concurrent
    initialization, §5.2) — the rebuild overlaps serving on the other
    pipelines and costs zero weight-reload; without the store it must
    re-load weights (slow path, modeled on the virtual clock).

Wall time is virtual (``clock``): control-plane latencies (provision/load/
init/grace) advance the clock; token generation is real JAX compute.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest
from repro.serving.tensor_store import TensorStore

DEFAULT_ROUND_S = 0.01           # fallback when no placement is known


@dataclasses.dataclass
class FTTimes:
    grace_period_s: float = 120.0
    node_provision_s: float = 41.55
    store_load_s: float = 61.85
    engine_init_s: float = 64.51


@dataclasses.dataclass
class ServingPipeline:
    pid: int
    engine: Engine
    instance_ids: List[str]
    weight: float = 1.0
    alive: bool = True
    down_until: float = 0.0
    queue: List[ServeRequest] = dataclasses.field(default_factory=list)
    placement: Optional[Any] = None       # core.estimator.Placement
    round_s: float = DEFAULT_ROUND_S      # est. decode-step wall time
    bucket_tbl: Optional[Any] = None      # core.buckets.BucketTable
    pricing: str = "spot"                 # "spot" | "ondemand" billing rate


class GlobalServer:
    def __init__(self, cfg: ArchConfig, store: Optional[TensorStore],
                 ft: Optional[FTTimes] = None, use_migration: bool = True,
                 use_concurrent_init: bool = True, max_batch: int = 4,
                 max_len: int = 128, use_pallas: bool = False,
                 prefill_chunk: int = 0,
                 est_workload: Tuple[int, int] = (763, 232),
                 engine_kw: Optional[Dict] = None,
                 use_kv_migration: bool = False,
                 use_prefix_share: bool = False,
                 prefix_hot_hits: int = 2,
                 dispatch: str = "weighted",
                 buckets: Optional[Any] = None,
                 prefix_affinity_frac: float = 0.9):
        assert dispatch in ("weighted", "uniform", "throughput", "cost"), \
            dispatch
        self.cfg = cfg
        self.store = store
        self.ft = ft or FTTimes()
        self.use_migration = use_migration
        # KV-block migration is opt-in: it trades store bytes for skipped
        # recompute, and the recompute path must stay the tested default
        # (the paper's §5.1 baseline; recovery.decide weighs the two)
        self.use_kv_migration = use_kv_migration
        # prefix sharing is likewise opt-in: engines index shared prompt
        # prefixes, the server publishes HOT prefix payloads to the store
        # under content-hash keys, and re-placed/new pipelines warm their
        # caches from the store instead of recomputing (recompute fallback
        # when the store lacks the prefix)
        self.use_prefix_share = use_prefix_share
        self.prefix_hot_hits = prefix_hot_hits
        # dispatch policy: "weighted" — scalar weighted RR (legacy);
        # "uniform" — every alive pipeline weighted 1.0 (A/B baseline);
        # "throughput"/"cost" — per-length-bucket weights from the
        # pipeline's BucketTable (tokens/s, or tokens/s per $/hr)
        self.dispatch = dispatch
        if buckets is None:
            from repro.core.buckets import LengthBuckets
            buckets = LengthBuckets()
        self.buckets = buckets
        # a holder within this fraction of the best bucket weight takes
        # the request (prefix-affinity tie-breaking)
        self.prefix_affinity_frac = prefix_affinity_frac
        self.use_concurrent_init = use_concurrent_init
        self.max_batch = max_batch
        self.max_len = max_len
        self.est_workload = est_workload      # (s_in, s_out) for estimates
        self.engine_kw = dict(engine_kw or {})
        self.engine_kw.setdefault("use_pallas", use_pallas)
        self.engine_kw.setdefault("prefill_chunk", prefill_chunk)
        if use_prefix_share:
            self.engine_kw.setdefault("prefix_share", True)
        self.pipelines: List[ServingPipeline] = []
        self.clock = 0.0
        # scalar dispatch keys on pid; bucket dispatch on (pid, bucket)
        self._rr_credit: Dict[Any, float] = {}
        self._bucket_by_rid: Dict[int, Tuple[int, int]] = {}
        self._bucket_est: Dict[Any, Any] = {}     # spec -> BucketEstimator
        self._pipe_engine_kw: Dict[int, Dict] = {}   # pid -> engine_kw
        # published/warmed shared-prefix token runs -> pids holding them
        # (the server knows which pipeline published which content-hash
        # key — prefix-aware dispatch routes a request to a pipeline that
        # already holds its prefix)
        self._prefix_home: Dict[Tuple[int, ...], set] = {}
        self.completed: List[ServeRequest] = []
        self.events: List[Tuple[float, str, str]] = []   # (t, kind, detail)

    # -- pipeline lifecycle ---------------------------------------------------
    def _build_engine(self, params: Any,
                      extra_kw: Optional[Dict] = None) -> Engine:
        kw = dict(self.engine_kw)
        kw.update(extra_kw or {})
        mb = kw.pop("max_batch", self.max_batch)
        ml = kw.pop("max_len", self.max_len)
        return Engine(self.cfg, params, max_batch=mb, max_len=ml, **kw)

    def _estimate_pipeline(self, placement) -> Tuple[float, float]:
        """(dispatch weight, per-round seconds) from the §4.1 estimator's
        stage latencies for this placement at the reference workload."""
        from repro.core import estimator
        s_in, s_out = self.est_workload
        est = estimator.estimate(placement.spec, placement, s_in, s_out)
        if est.batch <= 0 or not est.decode_stage_s:
            return 1.0, DEFAULT_ROUND_S
        # one scheduling round == one decode step on every live slot; the
        # bottleneck stage paces the pipeline (Eq. 5)
        round_s = max(est.decode_stage_s) / s_out
        return max(est.throughput_rps, 1e-9), max(round_s, 1e-6)

    def _bucket_table(self, placement) -> Any:
        """Per-bucket tokens/s / $-per-token table for a placement, with
        the bucket estimators shared across every pipeline of the same
        spec (the prefix-sum tables are the expensive part)."""
        from repro.core.buckets import BucketEstimator, bucket_table
        est = self._bucket_est.get(placement.spec)
        if est is None:
            est = BucketEstimator(placement.spec, self.buckets)
            self._bucket_est[placement.spec] = est
        return bucket_table(placement, est=est)

    def add_pipeline(self, params: Any, instance_ids: Sequence[str],
                     weight: Optional[float] = None, partition: str = "full",
                     placement=None,
                     engine_kw: Optional[Dict] = None,
                     pricing: str = "spot") -> ServingPipeline:
        """pricing: which rate this pipeline is billed at — a cluster
        mixing spot and on-demand capacity prices the SAME placement
        differently, so cost-policy dispatch must re-rank per pipeline
        (``BucketTable.weight(spot=...)``), not per spec."""
        assert pricing in ("spot", "ondemand"), pricing
        if self.store is not None:
            key = f"{partition}/p{len(self.pipelines)}"
            params, cold = self.store.put_or_attach(self.cfg.name, key,
                                                    params)
            if cold:
                self.events.append((self.clock, "store_load",
                                    f"{self.cfg.name}/{key}"))
        round_s = DEFAULT_ROUND_S
        bucket_tbl = None
        if placement is not None:
            est_w, round_s = self._estimate_pipeline(placement)
            if weight is None:
                weight = est_w
            if self.dispatch in ("throughput", "cost"):
                bucket_tbl = self._bucket_table(placement)
        pid = len(self.pipelines)
        self._pipe_engine_kw[pid] = dict(engine_kw or {})
        # the engine's cost-aware preemption-victim policy prices the
        # recompute branch off the pipeline's placement when known
        if placement is not None:
            self._pipe_engine_kw[pid].setdefault("placement", placement)
        p = ServingPipeline(pid,
                            self._build_engine(params,
                                               self._pipe_engine_kw[pid]),
                            list(instance_ids),
                            1.0 if weight is None else weight,
                            placement=placement, round_s=round_s,
                            bucket_tbl=bucket_tbl, pricing=pricing)
        self.pipelines.append(p)
        self._rr_credit[p.pid] = 0.0
        # a newly-placed pipeline warms its cache from published hot
        # prefixes instead of recomputing them on first contact
        self._warm_prefixes(p)
        return p

    # -- dispatch ---------------------------------------------------------------
    def bucket_for(self, req: ServeRequest) -> Tuple[int, int]:
        """The request's length bucket, assigned ONCE on first contact
        from (prompt len, max output) and preserved across interrupt /
        preemption requeues — a migrated request's recompute context has
        grown by its generated tokens, which must not reclassify it."""
        b = self._bucket_by_rid.get(req.rid)
        if b is None:
            b = self.buckets.bucket_of(len(req.prompt), req.max_new_tokens)
            self._bucket_by_rid[req.rid] = b
        return b

    def _dispatch_weight(self, p: ServingPipeline,
                         b: Optional[Tuple[int, int]]) -> float:
        if self.dispatch == "uniform":
            return 1.0
        if b is None or p.bucket_tbl is None:
            return p.weight
        # cost-policy weights divide by the pipeline's OWN billing rate:
        # an on-demand pipeline serving the same bucket at the same
        # tokens/s is strictly more $/token, so spot capacity out-ranks it
        return p.bucket_tbl.weight(b[0], b[1], policy=self.dispatch,
                                   spot=(p.pricing == "spot"))

    def _prefix_holders(self, prompt: Sequence[int]) -> set:
        """Pids of pipelines holding a published/warmed shared-prefix run
        that this prompt extends."""
        if not self._prefix_home:
            return set()
        toks = list(prompt)
        out: set = set()
        for run, pids in self._prefix_home.items():
            if len(run) <= len(toks) and toks[:len(run)] == list(run):
                out |= pids
        return out

    def submit(self, req: ServeRequest) -> Optional[ServingPipeline]:
        alive = [p for p in self.pipelines if p.alive]
        if not alive:
            return None
        b = self.bucket_for(req) \
            if self.dispatch in ("throughput", "cost") else None
        w = {p.pid: self._dispatch_weight(p, b) for p in alive}
        if all(v <= 0 for v in w.values()):
            # the estimator says no alive pipeline can serve this bucket
            # (or every weight degenerated): fall back to scalar weights —
            # the request must still be placed somewhere
            w = {p.pid: max(p.weight, 1e-9) for p in alive}
        key = (lambda pid: (pid, b)) if b is not None else (lambda pid: pid)
        for p in alive:
            self._rr_credit[key(p.pid)] = \
                self._rr_credit.get(key(p.pid), 0.0) + w[p.pid]
        best = max(alive, key=lambda p: self._rr_credit[key(p.pid)])
        if self.use_prefix_share:
            # tie-break toward a pipeline already holding this prompt's
            # prefix: a holder within prefix_affinity_frac of the chosen
            # pipeline's weight skips the prefix recompute entirely, which
            # is worth a marginal estimated-throughput gap. Credits are
            # still settled below, so long-run shares stay proportional.
            holders = self._prefix_holders(req.prompt)
            if holders and best.pid not in holders:
                cand = [p for p in alive if p.pid in holders
                        and w[p.pid] >= self.prefix_affinity_frac
                        * w[best.pid]]
                if cand:
                    best = max(cand,
                               key=lambda p: self._rr_credit[key(p.pid)])
        self._rr_credit[key(best.pid)] -= sum(w.values())
        best.queue.append(req)
        return best

    # -- serving loop -------------------------------------------------------------
    _KV_MODEL = "__kv__"
    _PREFIX_MODEL = "__prefix__"

    def _kv_key(self, req: ServeRequest) -> str:
        return f"r{req.rid}"

    def _prefix_key(self, arch: str, block_size: int, tokens) -> str:
        """Content-hash key for a shared-prefix run: the token run (plus
        arch and block geometry) IS the identity, so every pipeline that
        computes the same hot prefix publishes to the same key exactly
        once."""
        import hashlib
        import numpy as np
        h = hashlib.sha1(
            np.asarray(list(tokens), np.int64).tobytes()).hexdigest()
        return f"{arch}/b{block_size}/{h[:16]}"

    def _publish_hot_prefixes(self, p: ServingPipeline) -> None:
        """Publish this pipeline's hottest shared-prefix block payloads
        (budget-capped via the store's LRU insert path, like KV
        migration payloads; unreferenced, so evictable). Runs are
        content-addressed BEFORE export, so an already-published prefix
        costs no KV gather."""
        if not self.use_prefix_share or self.store is None:
            return
        eng = p.engine
        for run in eng.hot_runs(self.prefix_hot_hits):
            # the run lives in this engine's own index — record the
            # pipeline as a holder for prefix-affinity dispatch
            self._prefix_home.setdefault(tuple(run), set()).add(p.pid)
            key = self._prefix_key(self.cfg.name, eng.bm.block_size, run)
            # peek (not contains): an already-published hot prefix counts
            # as a store HIT, feeding the store's top-k hot-key pinning
            if self.store.peek(self._PREFIX_MODEL, key) is not None:
                continue
            payload = eng.export_prefix(run)
            if payload is not None:
                self.store.put(self._PREFIX_MODEL, key, payload)
                self.events.append((self.clock, "prefix_publish", key))

    def _warm_prefixes(self, p: ServingPipeline) -> None:
        """Warm a (new or rebuilt) pipeline's cache with every published
        shared-prefix payload its engine can attach. ``peek`` is
        non-consuming — warm-up is multi-consumer, unlike migrated-KV
        ``take``. Absent or incompatible payloads simply leave the engine
        on the recompute path (fallback preserved)."""
        if not self.use_prefix_share or self.store is None:
            return
        for model, part in self.store.keys(self._PREFIX_MODEL):
            payload = self.store.peek(model, part)
            if payload is not None and p.engine.warm_prefix(payload):
                self.events.append((self.clock, "prefix_warm", part))
                run = tuple(int(t) for t in payload["tokens"])
                self._prefix_home.setdefault(run, set()).add(p.pid)

    def _publish_kv(self, key: str, payload: Dict) -> None:
        """Publish one request's KV payload. Interruption grace-window and
        pool-preemption publishes share this path; ``put`` LRU-evicts
        unreferenced keys down to the store's ``budget_bytes`` on insert,
        so published-KV residency stays capped (older unpinned payloads
        go first — the fresh payload is most-recently used)."""
        self.store.put(self._KV_MODEL, key, payload)
        self.events.append((self.clock, "kv_publish", key))

    def _admit_kv_attached(self, p: ServingPipeline) -> None:
        """Admit queued requests whose KV blocks are resident in the store
        by attaching them (no recompute). Successful imports consume the
        payload; failures leave the request queued for the normal path."""
        rest: List[ServeRequest] = []
        for r in p.queue:
            key = self._kv_key(r)
            payload = self.store.take(self._KV_MODEL, key)  # single consumer
            if payload is None:
                rest.append(r)
            elif p.engine.import_kv(r, payload):
                self.events.append((self.clock, "kv_attach", key))
            else:
                # incompatible here; republish for a later/other pipeline
                self.store.put(self._KV_MODEL, key, payload)
                rest.append(r)
        p.queue[:] = rest

    def _drain_preempted(self, p: ServingPipeline) -> None:
        """Collect requests the engine preempted when a decode-time grow
        found the pool dry: publish their KV payloads (so re-admission
        attaches instead of recomputing — same store path the grace window
        uses) and requeue them at the FRONT of the pipeline's queue."""
        for req, payload in reversed(p.engine.take_preempted()):
            self.events.append((self.clock, "preempt", f"r{req.rid}"))
            # a victim preempted in its admission round has left the
            # engine's live set before step()'s first-token scan runs:
            # record TTFT here, at the round its token was emitted
            if req.first_token_s < 0 and req.generated:
                req.first_token_s = self.clock
            if self.use_kv_migration and self.store is not None:
                self._publish_kv(self._kv_key(req), payload)
            # without a store the payload is dropped; generated tokens are
            # preserved, so re-admission recomputes (§5.1 semantics)
            p.queue.insert(0, req)

    def step(self) -> int:
        """One scheduling round: batched admission of queued requests (KV
        attach first, prefill for the rest), one decode step per alive
        pipeline, then publish + requeue any pool-preempted requests.
        Returns tokens emitted."""
        emitted = 0
        for p in self.pipelines:
            if not p.alive:
                if self.clock >= p.down_until:
                    p.alive = True
                    self.events.append((self.clock, "revive", f"p{p.pid}"))
                else:
                    continue
            toks_before = p.engine.stats.tokens_out
            if self.use_kv_migration and self.store is not None and p.queue:
                self._admit_kv_attached(p)
            admitted = p.engine.admit_many(p.queue)
            if admitted:
                # skip-ahead admission: admitted is not necessarily a
                # queue prefix — remove by identity
                taken = {id(r) for r in admitted}
                p.queue[:] = [r for r in p.queue if id(r) not in taken]
            fin = p.engine.step()
            self._drain_preempted(p)
            self._publish_hot_prefixes(p)
            for r in list(p.engine.active()) + fin:
                if r.first_token_s < 0 and r.generated:
                    r.first_token_s = self.clock
            emitted += p.engine.stats.tokens_out - toks_before
            for r in fin:
                r.finish_s = self.clock
                self.completed.append(r)
        return emitted

    def round_s(self) -> float:
        """Virtual seconds one scheduling round represents: the slowest
        alive pipeline's estimated decode-step latency."""
        alive = [p.round_s for p in self.pipelines if p.alive]
        return max(alive) if alive else DEFAULT_ROUND_S

    def tick(self) -> None:
        if any(p.alive for p in self.pipelines):
            self.clock += self.round_s()
            return
        # nothing is serving: fast-forward the virtual clock to the next
        # revival so queued work (e.g. requests requeued on a sole
        # interrupted pipeline) is never starved by a round budget that
        # cannot span the grace period
        waking = [p.down_until for p in self.pipelines
                  if p.down_until > self.clock]
        if waking:
            self.clock = min(waking)
        else:
            self.clock += DEFAULT_ROUND_S

    def pending(self) -> bool:
        return any(p.queue or p.engine.active() for p in self.pipelines)

    def run_until_drained(self, max_rounds: int = 10_000) -> None:
        rounds = 0
        while rounds < max_rounds:
            if not self.pending():
                break
            self.step()
            self.tick()
            rounds += 1

    # -- fault tolerance ------------------------------------------------------------
    def interrupt_instance(self, instance_id: str) -> List[ServeRequest]:
        """Spot interruption notice for one instance: the owning pipeline is
        torn down after the grace period; in-flight requests migrate
        (output-preserving) or restart. Returns the affected requests."""
        ft = self.ft
        affected: List[Tuple[ServeRequest, ServingPipeline]] = []
        for p in self.pipelines:
            if not p.alive or instance_id not in p.instance_ids:
                continue
            self.events.append((self.clock, "interrupt",
                                f"p{p.pid}:{instance_id}"))
            # pool-preempted requests parked on the engine carry their own
            # payloads; the dying pipeline must not drop them
            parked = p.engine.take_preempted()
            # publish live KV blocks DURING the grace period (the engine is
            # still up): replacement/surviving pipelines attach instead of
            # recomputing (§5.1 x §5.2)
            if (self.use_kv_migration and self.use_migration
                    and self.store is not None):
                for req, payload in parked:
                    self._publish_kv(self._kv_key(req), payload)
                for rid, payload in p.engine.export_live_kv().items():
                    self._publish_kv(f"r{rid}", payload)
            # old pipeline serves through the grace period
            grace_end = self.clock + ft.grace_period_s
            if self.use_concurrent_init and self.store is not None:
                # replacement prepared in background; store makes the engine
                # init on unaffected nodes free of weight reloads
                ready = (self.clock + ft.node_provision_s
                         + max(ft.store_load_s, ft.engine_init_s))
                p.down_until = max(grace_end, ready)
            else:
                # must terminate old engine first; fresh engine reloads
                ready = (max(grace_end, self.clock + ft.node_provision_s)
                         + ft.store_load_s + ft.engine_init_s)
                p.down_until = ready
            reqs = (p.engine.evict_all() + [r for r, _ in parked]
                    + p.queue)
            p.queue = []
            for r in reqs:
                if not self.use_migration:
                    r.generated = []          # progress lost
                r.migrations += 1
                affected.append((r, p))
            p.alive = False
            p.instance_ids = [i for i in p.instance_ids if i != instance_id]
            p.instance_ids.append(f"{instance_id}/replacement")
            # rebuild engine NOW (attach-only when store present) so tokens
            # keep flowing the moment down_until passes
            p.engine = self._build_engine(
                p.engine.params, self._pipe_engine_kw.get(p.pid))
            # the rebuilt engine's cache is cold: it no longer holds any
            # published prefix (affinity map), and re-warming republishes
            # what the store still has
            for pids in self._prefix_home.values():
                pids.discard(p.pid)
            self._warm_prefixes(p)
        # re-dispatch affected requests to surviving pipelines; if none is
        # alive, requeue on the owner — it revives at down_until, and a
        # request must never be dropped because submit() had no target
        for r, owner in affected:
            if self.submit(r) is None:
                owner.queue.append(r)
        return [r for r, _ in affected]

    def downtime_of(self, pid: int) -> float:
        p = self.pipelines[pid]
        return max(0.0, p.down_until - self.clock)
