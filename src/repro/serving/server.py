"""Global server: pipelines + instance manager + fault tolerance (paper §3,
§5) over the REAL engine (execution plane).

A ``ServingPipeline`` binds an ``Engine`` to a set of instance ids (from a
placement). The ``GlobalServer``:

  * dispatches requests weighted-round-robin by pipeline throughput (§3);
  * on a spot interruption: collects in-flight requests WITH their generated
    outputs (output-preserving request migration, §5.1) and re-queues them;
  * rebuilds the pipeline with a replacement instance: with the shared
    tensor store the new engine ATTACHES to resident weights (concurrent
    initialization, §5.2) — the rebuild overlaps serving on the other
    pipelines and costs zero weight-reload; without the store it must
    re-load weights (slow path, modeled on the virtual clock).

Wall time is virtual (``clock``): control-plane latencies (provision/load/
init/grace) advance the clock; token generation is real JAX compute.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest
from repro.serving.tensor_store import TensorStore


@dataclasses.dataclass
class FTTimes:
    grace_period_s: float = 120.0
    node_provision_s: float = 41.55
    store_load_s: float = 61.85
    engine_init_s: float = 64.51


@dataclasses.dataclass
class ServingPipeline:
    pid: int
    engine: Engine
    instance_ids: List[str]
    weight: float = 1.0
    alive: bool = True
    down_until: float = 0.0
    queue: List[ServeRequest] = dataclasses.field(default_factory=list)


class GlobalServer:
    def __init__(self, cfg: ArchConfig, store: Optional[TensorStore],
                 ft: Optional[FTTimes] = None, use_migration: bool = True,
                 use_concurrent_init: bool = True, max_batch: int = 4,
                 max_len: int = 128):
        self.cfg = cfg
        self.store = store
        self.ft = ft or FTTimes()
        self.use_migration = use_migration
        self.use_concurrent_init = use_concurrent_init
        self.max_batch = max_batch
        self.max_len = max_len
        self.pipelines: List[ServingPipeline] = []
        self.clock = 0.0
        self._rr_credit: Dict[int, float] = {}
        self.completed: List[ServeRequest] = []
        self.events: List[Tuple[float, str, str]] = []   # (t, kind, detail)

    # -- pipeline lifecycle ---------------------------------------------------
    def add_pipeline(self, params: Any, instance_ids: Sequence[str],
                     weight: float = 1.0, partition: str = "full"
                     ) -> ServingPipeline:
        if self.store is not None:
            self.store.put(self.cfg.name, f"{partition}/p{len(self.pipelines)}",
                           params)
            params = self.store.attach(
                self.cfg.name, f"{partition}/p{len(self.pipelines)}")
        eng = Engine(self.cfg, params, max_batch=self.max_batch,
                     max_len=self.max_len)
        p = ServingPipeline(len(self.pipelines), eng, list(instance_ids),
                            weight)
        self.pipelines.append(p)
        self._rr_credit[p.pid] = 0.0
        return p

    # -- dispatch ---------------------------------------------------------------
    def submit(self, req: ServeRequest) -> Optional[ServingPipeline]:
        alive = [p for p in self.pipelines if p.alive]
        if not alive:
            return None
        for p in alive:
            self._rr_credit[p.pid] += p.weight
        best = max(alive, key=lambda p: self._rr_credit[p.pid])
        self._rr_credit[best.pid] -= sum(p.weight for p in alive)
        best.queue.append(req)
        return best

    # -- serving loop -------------------------------------------------------------
    def step(self) -> int:
        """One scheduling round: admit queued requests, one decode step per
        alive pipeline. Returns tokens emitted."""
        emitted = 0
        for p in self.pipelines:
            if not p.alive:
                if self.clock >= p.down_until:
                    p.alive = True
                    self.events.append((self.clock, "revive", f"p{p.pid}"))
                else:
                    continue
            while p.queue and p.engine.free_slots():
                req = p.queue.pop(0)
                p.engine.admit(req)
                if req.first_token_s < 0 and req.generated:
                    req.first_token_s = self.clock
            fin = p.engine.step()
            emitted += len([s for s in p.engine.slots if s]) + len(fin)
            for r in fin:
                r.finish_s = self.clock
                self.completed.append(r)
        return emitted

    def run_until_drained(self, max_rounds: int = 10_000) -> None:
        rounds = 0
        while rounds < max_rounds:
            pending = any(p.queue or p.engine.active()
                          for p in self.pipelines)
            if not pending:
                break
            self.step()
            self.clock += 0.01
            rounds += 1

    # -- fault tolerance ------------------------------------------------------------
    def interrupt_instance(self, instance_id: str) -> List[ServeRequest]:
        """Spot interruption notice for one instance: the owning pipeline is
        torn down after the grace period; in-flight requests migrate
        (output-preserving) or restart. Returns the affected requests."""
        ft = self.ft
        affected: List[ServeRequest] = []
        for p in self.pipelines:
            if not p.alive or instance_id not in p.instance_ids:
                continue
            self.events.append((self.clock, "interrupt",
                                f"p{p.pid}:{instance_id}"))
            # old pipeline serves through the grace period
            grace_end = self.clock + ft.grace_period_s
            if self.use_concurrent_init and self.store is not None:
                # replacement prepared in background; store makes the engine
                # init on unaffected nodes free of weight reloads
                ready = (self.clock + ft.node_provision_s
                         + max(ft.store_load_s, ft.engine_init_s))
                p.down_until = max(grace_end, ready)
            else:
                # must terminate old engine first; fresh engine reloads
                ready = (max(grace_end, self.clock + ft.node_provision_s)
                         + ft.store_load_s + ft.engine_init_s)
                p.down_until = ready
            reqs = p.engine.evict_all() + p.queue
            p.queue = []
            for r in reqs:
                if not self.use_migration:
                    r.generated = []          # progress lost
                r.migrations += 1
                affected.append(r)
            p.alive = False
            p.instance_ids = [i for i in p.instance_ids if i != instance_id]
            p.instance_ids.append(f"{instance_id}/replacement")
            # rebuild engine NOW (attach-only when store present) so tokens
            # keep flowing the moment down_until passes
            params = p.engine.params
            p.engine = Engine(self.cfg, params, max_batch=self.max_batch,
                              max_len=self.max_len)
        # re-dispatch affected requests to surviving pipelines
        for r in affected:
            self.submit(r)
        return affected

    def downtime_of(self, pid: int) -> float:
        p = self.pipelines[pid]
        return max(0.0, p.down_until - self.clock)
