"""Baseline file handling for jaxlint's incremental CI gate.

A baseline is a committed list of accepted finding fingerprints — the
gate fails only on findings whose fingerprint is absent. Format (one
entry per line)::

    <fingerprint>  <check> <path>:<line> <qualname>  # reason

Everything after the first whitespace run is commentary for humans:
``load_baseline`` keys on the leading fingerprint token alone, so the
descriptive tail (and the recorded line number) may drift without
invalidating the entry. Blank lines and ``#``-prefixed lines are
ignored. Fingerprints are line-number-free (see ``jaxlint``), so
baselines survive edits elsewhere in the file; editing the flagged line
itself changes the fingerprint and forces re-triage — intended.
"""

from __future__ import annotations

from typing import Iterable, Set

from repro.analysis.jaxlint import Finding

_HEADER = """\
# jaxlint baseline — accepted findings (see src/repro/analysis/).
# One fingerprint per line; trailing text is human commentary only.
# Regenerate with:  PYTHONPATH=src python -m repro.analysis src/ \\
#     --write-baseline .jaxlint-baseline
# then re-add reason comments for entries you keep.
"""


def load_baseline(path: str) -> Set[str]:
    """Accepted fingerprints from ``path``; empty set if absent."""
    fps: Set[str] = set()
    try:
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                fps.add(line.split()[0])
    except FileNotFoundError:
        pass
    return fps


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    """Write all ``findings`` as a fresh baseline; returns the count."""
    rows = sorted(findings, key=lambda f: (f.path, f.line, f.check))
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(_HEADER)
        for f in rows:
            fh.write(f"{f.fingerprint}  {f.check} {f.path}:{f.line} "
                     f"{f.qualname}\n")
    return len(rows)
