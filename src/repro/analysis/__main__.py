"""CLI: ``python -m repro.analysis [paths...]``.

Exit 0 when every finding is baselined or suppressed; exit 1 when new
findings exist (printed) or ``--fail-on-stale`` is set and the baseline
carries entries that no longer fire.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.jaxlint import CHECKS, LintConfig, analyze_paths


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-tuned JAX/Pallas discipline analyzer")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--baseline", default=".jaxlint-baseline",
                    help="accepted-findings file (default: "
                         ".jaxlint-baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="accept all current findings into PATH and exit")
    ap.add_argument("--tests-dir", default="tests",
                    help="tests directory for the pallas-test "
                         "cross-reference (default: tests)")
    ap.add_argument("--select", metavar="CHECKS",
                    help="comma-separated subset of checks to run")
    ap.add_argument("--list-checks", action="store_true",
                    help="print the check catalogue and exit")
    ap.add_argument("--fail-on-stale", action="store_true",
                    help="also fail when baseline entries no longer fire")
    args = ap.parse_args(argv)

    if args.list_checks:
        for name, doc in CHECKS.items():
            print(f"{name}: {doc}")
        return 0

    enabled = tuple(CHECKS)
    if args.select:
        enabled = tuple(c.strip() for c in args.select.split(","))
        unknown = [c for c in enabled if c not in CHECKS]
        if unknown:
            print(f"unknown check(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    tests_dir = args.tests_dir if os.path.isdir(args.tests_dir) else None
    config = LintConfig(tests_dir=tests_dir, enabled=enabled)
    paths = args.paths or ["src"]
    findings = analyze_paths(paths, config)

    if args.write_baseline:
        n = write_baseline(args.write_baseline, findings)
        print(f"wrote {n} finding(s) to {args.write_baseline}")
        return 0

    accepted = (set() if args.no_baseline
                else load_baseline(args.baseline))
    new = [f for f in findings if f.fingerprint not in accepted]
    fired = {f.fingerprint for f in findings}
    stale = sorted(accepted - fired)

    for f in new:
        print(f.render())
    if new:
        print(f"\n{len(new)} new finding(s) "
              f"({len(findings) - len(new)} baselined). Fix, suppress "
              f"with `# jaxlint: disable=<check> -- reason`, or accept "
              f"via --write-baseline.")
        return 1
    if stale:
        print(f"{len(stale)} stale baseline entr{'y' if len(stale) == 1 else 'ies'} "
              f"(no longer fire): {', '.join(stale)}")
        if args.fail_on_stale:
            return 1
    print(f"jaxlint clean: {len(findings)} finding(s), all baselined "
          f"or none.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
