"""repro.analysis — repo-specific JAX/Pallas discipline tooling.

Two halves (ISSUE 8):

* ``jaxlint`` — an AST-based static analyzer (stdlib ``ast`` only, no new
  dependencies) with checks tuned to THIS codebase's invariants: donated
  jit buffers must never be read after dispatch, hot scheduling loops must
  not silently sync device values to the host, jit'd callees must not be
  fed Python-varying shapes outside the blessed bucketing helpers, Pallas
  call sites must tie their grid/BlockSpec dims to named constants and
  carry an interpret-mode equivalence test, and jit-traced function bodies
  must not branch on traced values. Findings are suppressed inline with
  ``# jaxlint: disable=<check>`` or accepted into a committed baseline
  file so the CI gate is incremental (only NEW findings fail the build).

  Run it locally::

      PYTHONPATH=src python -m repro.analysis src/

* KV-block sanitizer — a runtime mode of ``serving.kv_blocks.BlockManager``
  (``BlockManager(sanitize=True)`` or ``REPRO_KV_SANITIZE=1``) that keeps
  a shadow ledger cross-checked on every reserve/grow/free/COW op, poisons
  freed blocks with a sentinel, and raises ``KVSanitizerError`` on
  use-after-free, double-free, refcount underflow, and writes to a shared
  block — per-op detection instead of end-of-test ``check_no_leak()``.
"""

from repro.analysis.baseline import load_baseline, write_baseline
from repro.analysis.jaxlint import (
    CHECKS,
    Finding,
    LintConfig,
    analyze_file,
    analyze_paths,
)

__all__ = [
    "CHECKS",
    "Finding",
    "LintConfig",
    "analyze_file",
    "analyze_paths",
    "load_baseline",
    "write_baseline",
]
