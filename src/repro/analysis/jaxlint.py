"""jaxlint — AST checks for this repo's JAX/Pallas discipline.

Every check exists because a PR in this repo's history fixed (or nearly
shipped) the corresponding bug class by hand; see ``CHECKS`` for the
catalogue. The analyzer is intentionally repo-tuned, not general: the hot
path set, the blessed bucketing helpers, and the Pallas test
cross-reference all name structures of THIS codebase (``LintConfig``).

Design notes:

* Analysis is per-module and flow-approximate: statements are ordered by
  source position, so a read *lexically after* a donating dispatch counts
  as after it even across branches. That over-approximation is the right
  polarity for a linter (false positives are suppressible; misses are not
  visible), with one deliberate blind spot — a donation at the bottom of a
  loop body followed by a read at the top of the next iteration is not
  seen. Rebinding the donated name in the dispatch statement itself (the
  idiom ``logits, self.cache = self._decode(self.params, self.cache, …)``)
  is recognized and never flagged.
* Suppression: a finding is dropped when its line (or an immediately
  preceding comment-only line run) carries
  ``# jaxlint: disable=<check>[,<check>…]`` (or ``disable=all``), with an
  optional ``-- reason`` tail. Prefer inline suppression for
  intentional-by-design sites (self-documenting); use the baseline file
  (``repro.analysis.baseline``) for bulk-accepted legacy findings.
* Fingerprints are line-number-free: ``md5(check|path|qualname|stripped
  source line|occurrence)``. Baselines survive unrelated edits but go
  stale when the flagged line itself changes — by design, an edited line
  must re-justify its baseline entry.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# check name -> one-line contract (shown by --list-checks)
CHECKS: Dict[str, str] = {
    "donated-use": (
        "a buffer passed at a donate_argnums position of a jit'd call is "
        "read again before being rebound (use-after-dispatch)"),
    "host-sync": (
        "host-device synchronization (.item(), np.asarray/np.array on "
        "device values, block_until_ready, int()/float() on indexed "
        "values) inside a configured hot-path function"),
    "retrace": (
        "a jit'd callee is fed an array sliced to a Python-varying extent "
        "outside the blessed bucketing helpers — every distinct extent "
        "retraces"),
    "pallas-grid": (
        "a pl.pallas_call grid / BlockSpec dimension is a bare magic "
        "number instead of a named constant (0 and 1 are allowed)"),
    "pallas-test": (
        "a public Pallas kernel wrapper lacks an interpret= parameter or "
        "is never referenced by any file under tests/ (no interpret-mode "
        "equivalence coverage)"),
    "traced-flow": (
        "a jit-traced function body branches on (or concretizes with "
        "int/float/bool) a traced parameter — TracerBoolConversionError "
        "or silent host fallback at trace time"),
}

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get"}
_SYNC_ATTRS = {"item", "block_until_ready", "copy_to_host_async"}
_HOST_LITERALS = (ast.List, ast.ListComp, ast.Tuple, ast.GeneratorExp,
                  ast.Dict, ast.DictComp, ast.Constant)

_SUPPRESS_RE = re.compile(r"#\s*jaxlint:\s*disable=([\w\-,]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    check: str
    path: str              # as reported (display)
    line: int
    col: int
    qualname: str
    message: str
    fingerprint: str

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.check} "
                f"[{self.fingerprint}] {self.qualname}: {self.message}")


@dataclasses.dataclass
class LintConfig:
    """Repo-tuned knobs. Defaults encode THIS codebase's conventions."""
    # qualname regexes whose bodies are hot scheduling/dispatch paths —
    # host syncs here stall the engine's per-step pipeline
    hot_functions: Tuple[str, ...] = (
        r"^Engine\.step$",
        r"^Engine\._ensure_grow$",
        r"^Engine\._advance_pending$",
        r"^Engine\._finish_pending$",
        r"^Engine\._admit_group$",
        r"^Engine\._admit_group_suffix$",
        r"^Engine\._scatter_group$",
        r"^Engine\._preempt$",
        r"^Engine\.export_kv$",
        r"^Engine\.export_live_kv$",
    )
    # qualname regexes blessed to feed jit'd callees shape-varying data —
    # the power-of-2 bucketing helpers pad before dispatch
    blessed_retrace: Tuple[str, ...] = (
        r"^Engine\._admit_group$",
        r"^Engine\._admit_group_suffix$",
        r"^Engine\._bucket$",
        r"^Engine\.bucket_lens$",
    )
    # directory whose files provide the pallas-test cross-reference
    tests_dir: Optional[str] = None
    enabled: Tuple[str, ...] = tuple(CHECKS)
    grid_allowed_ints: Tuple[int, ...] = (0, 1)


# -- small AST helpers ----------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' / 'self._decode' / 'np.asarray' for Name/Attribute
    chains; None for anything else (calls, subscripts…)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const_ints(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


def _const_strs(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str))
    return ()


def _walk_local(fn: ast.AST) -> Iterable[ast.AST]:
    """Nodes of ``fn``'s own body, NOT descending into nested function or
    class definitions — those are visited separately under their own
    qualname (so per-function policy like hot/blessed applies to the
    innermost enclosing function, and nothing is analyzed twice)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def _stmts_in_order(fn: ast.AST) -> List[ast.stmt]:
    """``fn``'s own statement nodes in source order (flow-approximate
    linearization; see module docstring)."""
    out = [n for n in _walk_local(fn) if isinstance(n, ast.stmt)]
    out.sort(key=lambda n: (n.lineno, n.col_offset))
    return out


def _parents(root: ast.AST) -> Dict[ast.AST, ast.AST]:
    par: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


@dataclasses.dataclass
class _JitInfo:
    name: str                       # call-site dotted name
    donate: Tuple[int, ...] = ()
    static_nums: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()
    func_name: Optional[str] = None  # wrapped python function, if a Name


def _jit_kwargs(call: ast.Call) -> Dict[str, Tuple]:
    out: Dict[str, Tuple] = {}
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            out["donate"] = _const_ints(kw.value)
        elif kw.arg == "static_argnums":
            out["static_nums"] = _const_ints(kw.value)
        elif kw.arg == "static_argnames":
            out["static_names"] = _const_strs(kw.value)
    return out


def _collect_jit_registry(tree: ast.Module) -> Dict[str, _JitInfo]:
    """Map call-site dotted names -> jit metadata.

    Recognizes ``X = jax.jit(f, …)`` (X a Name or self-attribute),
    ``@jax.jit`` and ``@functools.partial(jax.jit, …)`` decorations.
    """
    reg: Dict[str, _JitInfo] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if _dotted(call.func) not in _JIT_NAMES:
                continue
            kw = _jit_kwargs(call)
            fn = None
            if call.args and isinstance(call.args[0], ast.Name):
                fn = call.args[0].id
            for tgt in node.targets:
                name = _dotted(tgt)
                if name is not None:
                    reg[name] = _JitInfo(name=name, func_name=fn, **kw)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _dotted(dec) in _JIT_NAMES:
                    reg[node.name] = _JitInfo(name=node.name,
                                              func_name=node.name)
                elif isinstance(dec, ast.Call):
                    head = _dotted(dec.func)
                    if head in _JIT_NAMES:
                        reg[node.name] = _JitInfo(
                            name=node.name, func_name=node.name,
                            **_jit_kwargs(dec))
                    elif (head in _PARTIAL_NAMES and dec.args
                          and _dotted(dec.args[0]) in _JIT_NAMES):
                        reg[node.name] = _JitInfo(
                            name=node.name, func_name=node.name,
                            **_jit_kwargs(dec))
    return reg


def _suppressions(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = set(m.group(1).split(","))
    return out


def _suppressed(check: str, line: int, sup: Dict[int, Set[str]],
                lines: Sequence[str]) -> bool:
    """Suppressed if the finding's line, or the run of comment-only lines
    immediately above it, carries a matching disable."""
    def hit(ln: int) -> bool:
        s = sup.get(ln)
        return s is not None and (check in s or "all" in s)

    if hit(line):
        return True
    ln = line - 1
    while ln >= 1 and ln <= len(lines) and lines[ln - 1].lstrip().startswith("#"):
        if hit(ln):
            return True
        ln -= 1
    return False


class _Scoped(ast.NodeVisitor):
    """Base visitor that tracks class/function qualnames."""

    def __init__(self) -> None:
        self._stack: List[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self._stack)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    def _visit_fn(self, node) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn


# -- per-module analysis --------------------------------------------------------
class _ModuleLinter:
    def __init__(self, path: str, rel: str, source: str,
                 config: LintConfig, tests_blob: str) -> None:
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.config = config
        self.tests_blob = tests_blob
        self.tree = ast.parse(source, filename=path)
        self.registry = _collect_jit_registry(self.tree)
        self.sup = _suppressions(source)
        self.raw: List[Tuple[str, int, int, str, str]] = []
        self._hot = [re.compile(p) for p in config.hot_functions]
        self._blessed = [re.compile(p) for p in config.blessed_retrace]

    # -- emit helpers ------------------------------------------------------
    def _emit(self, check: str, node: ast.AST, qualname: str,
              message: str) -> None:
        if check not in self.config.enabled:
            return
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        if _suppressed(check, line, self.sup, self.lines):
            return
        self.raw.append((check, line, col, qualname, message))

    def findings(self) -> List[Finding]:
        seen: Dict[Tuple[str, str, str], int] = {}
        out: List[Finding] = []
        for check, line, col, qualname, message in sorted(self.raw):
            src = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
            key = (check, qualname, src)
            occ = seen.get(key, 0)
            seen[key] = occ + 1
            fp = hashlib.md5(
                f"{check}|{self.rel}|{qualname}|{src}|{occ}"
                .encode()).hexdigest()[:16]
            out.append(Finding(check, self.rel, line, col, qualname,
                               message, fp))
        return out

    def run(self) -> List[Finding]:
        self._walk_functions()
        self._check_pallas_grid()
        self._check_pallas_test()
        self._check_traced_flow()
        return self.findings()

    # -- function-scoped checks -------------------------------------------
    def _walk_functions(self) -> None:
        linter = self

        class V(_Scoped):
            def _visit_fn(self, node) -> None:
                self._stack.append(node.name)
                qn = self.qualname
                linter._check_donated_use(node, qn)
                if any(r.search(qn) for r in linter._hot):
                    linter._check_host_sync(node, qn)
                if not any(r.search(qn) for r in linter._blessed):
                    linter._check_retrace(node, qn)
                self.generic_visit(node)
                self._stack.pop()

            visit_FunctionDef = _visit_fn
            visit_AsyncFunctionDef = _visit_fn

        V().visit(self.tree)

    # donated-use ----------------------------------------------------------
    def _check_donated_use(self, fn, qualname: str) -> None:
        stmts = [s for s in fn.body]
        # direct statements only at top; nested bodies handled by the
        # source-order linearization below
        all_stmts = _stmts_in_order(fn)
        par = _parents(fn)

        def stmt_of(node: ast.AST) -> Optional[ast.stmt]:
            while node in par and not isinstance(node, ast.stmt):
                node = par[node]
            return node if isinstance(node, ast.stmt) else None

        def assign_targets(stmt: ast.stmt) -> Set[str]:
            tgts: Set[str] = set()
            if isinstance(stmt, ast.Assign):
                nodes = stmt.targets
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                nodes = [stmt.target]
            else:
                return tgts
            for t in nodes:
                for sub in ast.walk(t):
                    d = _dotted(sub)
                    if d is not None and isinstance(
                            getattr(sub, "ctx", None), ast.Store):
                        tgts.add(d)
            return tgts

        def loads_of(node: ast.AST, dotted: str) -> List[ast.AST]:
            hits = []
            for sub in ast.walk(node):
                if (_dotted(sub) == dotted
                        and isinstance(getattr(sub, "ctx", None), ast.Load)
                        # the value side of a dotted chain repeats; only
                        # count the full chain's outermost node
                        and not (sub in par
                                 and isinstance(par[sub], ast.Attribute))):
                    hits.append(sub)
            return hits

        del stmts
        for node in _walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            info = self.registry.get(callee) if callee else None
            if info is None or not info.donate:
                continue
            stmt = stmt_of(node)
            if stmt is None:
                continue
            for pos in info.donate:
                if pos >= len(node.args):
                    continue
                if any(isinstance(a, ast.Starred)
                       for a in node.args[:pos + 1]):
                    continue
                donated = _dotted(node.args[pos])
                if donated is None:
                    continue
                rebound = donated in assign_targets(stmt)
                # reads of the donated name in the SAME statement beyond
                # the donated argument itself (e.g. ``y = f(x) + x``)
                arg_reads = len(loads_of(node.args[pos], donated))
                call_reads = sum(len(loads_of(a, donated))
                                 for a in node.args)
                call_reads += sum(len(loads_of(kw.value, donated))
                                  for kw in node.keywords)
                stmt_reads = len(loads_of(stmt, donated))
                if stmt_reads > call_reads or call_reads > arg_reads:
                    self._emit(
                        "donated-use", node, qualname,
                        f"`{donated}` is donated to `{callee}` (arg {pos}) "
                        f"but read again in the same statement")
                    continue
                if rebound:
                    continue
                end = getattr(stmt, "end_lineno", stmt.lineno)
                for later in all_stmts:
                    if later.lineno <= end:
                        continue
                    reads = loads_of(later, donated)
                    tgts = assign_targets(later)
                    if reads:
                        self._emit(
                            "donated-use", reads[0], qualname,
                            f"`{donated}` was donated to `{callee}` "
                            f"(arg {pos}) at line {stmt.lineno} and is "
                            f"read here before being rebound")
                        break
                    if donated in tgts:
                        break        # rebound: later reads are fine
                else:
                    # fell through without rebind: donated name escapes
                    # the function unread — fine
                    pass

    # host-sync ------------------------------------------------------------
    def _check_host_sync(self, fn, qualname: str) -> None:
        for node in _walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee in _SYNC_CALLS:
                if node.args and isinstance(node.args[0], _HOST_LITERALS):
                    continue      # pure host construction, no device sync
                self._emit("host-sync", node, qualname,
                           f"`{callee}` pulls a device value to the host "
                           f"inside hot path `{qualname}`")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _SYNC_ATTRS and not node.args):
                self._emit("host-sync", node, qualname,
                           f"`.{node.func.attr}()` blocks on the device "
                           f"inside hot path `{qualname}`")
            elif (callee in ("int", "float") and len(node.args) == 1
                  and isinstance(node.args[0], ast.Subscript)):
                self._emit("host-sync", node, qualname,
                           f"`{callee}()` on an indexed value syncs if it "
                           f"is a device array (hot path `{qualname}`)")

    # retrace --------------------------------------------------------------
    def _check_retrace(self, fn, qualname: str) -> None:
        for node in _walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            info = self.registry.get(callee) if callee else None
            if info is None:
                continue
            for i, arg in enumerate(node.args):
                if i in info.static_nums:
                    continue
                slc = self._varying_slice(arg)
                if slc is not None:
                    self._emit(
                        "retrace", arg, qualname,
                        f"arg {i} of jit'd `{callee}` is sliced to the "
                        f"Python-varying extent `{slc}` — every distinct "
                        f"extent retraces (pad to a bucket, or bless "
                        f"this helper in LintConfig)")

    @staticmethod
    def _varying_slice(arg: ast.AST) -> Optional[str]:
        for sub in ast.walk(arg):
            if not isinstance(sub, ast.Subscript):
                continue
            parts = (sub.slice.elts
                     if isinstance(sub.slice, ast.Tuple) else [sub.slice])
            for p in parts:
                if isinstance(p, ast.Slice):
                    for bound in (p.lower, p.upper):
                        if bound is not None and not isinstance(
                                bound, ast.Constant):
                            try:
                                return ast.unparse(bound)
                            except Exception:
                                return "<expr>"
        return None

    # pallas-grid ----------------------------------------------------------
    def _check_pallas_grid(self) -> None:
        if "pallas_call" not in self.source:
            return
        allowed = set(self.config.grid_allowed_ints)
        linter = self

        class V(_Scoped):
            def visit_Call(self, node: ast.Call) -> None:
                callee = _dotted(node.func) or ""
                if callee.endswith("pallas_call") or \
                        callee.endswith("GridSpec"):
                    for kw in node.keywords:
                        if kw.arg == "grid":
                            linter._flag_magic(kw.value, self.qualname,
                                               "grid", allowed)
                elif callee.endswith("BlockSpec") and node.args:
                    linter._flag_magic(node.args[0], self.qualname,
                                       "BlockSpec block shape", allowed)
                self.generic_visit(node)

        V().visit(self.tree)

    def _flag_magic(self, node: ast.AST, qualname: str, what: str,
                    allowed: Set[int]) -> None:
        if not isinstance(node, (ast.Tuple, ast.List)):
            return
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int) \
                    and e.value not in allowed:
                self._emit(
                    "pallas-grid", e, qualname,
                    f"magic number {e.value} in {what} — tie kernel "
                    f"dims to named constants so grid math stays "
                    f"auditable")

    # pallas-test ----------------------------------------------------------
    def _check_pallas_test(self) -> None:
        if "pallas_call" not in self.source:
            return
        for node in self.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            if node.name.startswith("_"):
                continue
            has_call = any(
                isinstance(n, ast.Call)
                and (_dotted(n.func) or "").endswith("pallas_call")
                for n in ast.walk(node))
            if not has_call:
                continue
            params = {a.arg for a in node.args.args}
            params |= {a.arg for a in node.args.kwonlyargs}
            if "interpret" not in params:
                self._emit(
                    "pallas-test", node, node.name,
                    f"Pallas wrapper `{node.name}` has no interpret= "
                    f"parameter — interpret-mode equivalence tests "
                    f"cannot exercise it")
            if self.tests_blob and not re.search(
                    rf"\b{re.escape(node.name)}\b", self.tests_blob):
                self._emit(
                    "pallas-test", node, node.name,
                    f"Pallas wrapper `{node.name}` is not referenced by "
                    f"any file under the tests directory — add an "
                    f"interpret-mode equivalence test")

    # traced-flow ----------------------------------------------------------
    def _check_traced_flow(self) -> None:
        defs: Dict[str, ast.AST] = {}
        qn: Dict[str, str] = {}
        linter = self

        class Collect(_Scoped):
            def _visit_fn(self, node) -> None:
                self._stack.append(node.name)
                defs.setdefault(node.name, node)
                qn.setdefault(node.name, self.qualname)
                self.generic_visit(node)
                self._stack.pop()

            visit_FunctionDef = _visit_fn
            visit_AsyncFunctionDef = _visit_fn

        Collect().visit(self.tree)
        for info in self.registry.values():
            fn = defs.get(info.func_name or "")
            if fn is None:
                continue
            args = fn.args
            names = [a.arg for a in args.args + args.kwonlyargs]
            traced = {n for i, n in enumerate(names)
                      if i not in info.static_nums
                      and n not in info.static_names and n != "self"}
            linter._traced_flow_body(fn, qn[fn.name], traced)

    def _traced_flow_body(self, fn, qualname: str,
                          traced: Set[str]) -> None:
        def uses_traced(node: ast.AST) -> Optional[str]:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Name) and sub.id in traced:
                    return sub.id
            return None

        for node in _walk_local(fn):     # nested defs trace separately
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
                if (isinstance(test, ast.Compare) and all(
                        isinstance(op, (ast.Is, ast.IsNot))
                        for op in test.ops)):
                    continue   # `is (not) None` on optionals is static
                name = uses_traced(test)
                if name is not None:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    self._emit(
                        "traced-flow", node, qualname,
                        f"`{kind}` on traced `{name}` inside jit-traced "
                        f"`{qualname}` — use jnp.where/lax.cond or mark "
                        f"it static")
            elif isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee in ("int", "float", "bool") and node.args:
                    name = uses_traced(node.args[0])
                    if name is not None:
                        self._emit(
                            "traced-flow", node, qualname,
                            f"`{callee}()` concretizes traced `{name}` "
                            f"inside jit-traced `{qualname}`")


# -- entry points ---------------------------------------------------------------
def _read_tests_blob(tests_dir: Optional[str]) -> str:
    if not tests_dir or not os.path.isdir(tests_dir):
        return ""
    chunks = []
    for base, _dirs, files in os.walk(tests_dir):
        for f in sorted(files):
            if f.endswith(".py"):
                p = os.path.join(base, f)
                try:
                    with open(p, encoding="utf-8") as fh:
                        chunks.append(fh.read())
                except OSError:
                    pass
    return "\n".join(chunks)


def analyze_file(path: str, config: Optional[LintConfig] = None,
                 rel: Optional[str] = None,
                 tests_blob: Optional[str] = None) -> List[Finding]:
    config = config or LintConfig()
    if tests_blob is None:
        tests_blob = _read_tests_blob(config.tests_dir)
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    return _ModuleLinter(path, rel or path, source, config,
                         tests_blob).run()


def _iter_py(root: str) -> Iterable[Tuple[str, str]]:
    """(abspath, relpath-for-fingerprints). Fingerprint paths are rooted
    at the scan root's basename so they are stable across machines and
    working directories (``src/repro/…`` whether scanned as ``src/`` or
    ``/abs/path/src``)."""
    root = root.rstrip(os.sep)
    if os.path.isfile(root):
        yield root, os.path.basename(root)
        return
    base = os.path.basename(os.path.abspath(root))
    for dirpath, dirnames, files in os.walk(root):
        dirnames[:] = [d for d in sorted(dirnames)
                       if d not in ("__pycache__", ".git")]
        for f in sorted(files):
            if f.endswith(".py"):
                p = os.path.join(dirpath, f)
                yield p, os.path.join(
                    base, os.path.relpath(p, root)).replace(os.sep, "/")


def analyze_paths(paths: Sequence[str],
                  config: Optional[LintConfig] = None) -> List[Finding]:
    config = config or LintConfig()
    tests_blob = _read_tests_blob(config.tests_dir)
    out: List[Finding] = []
    for root in paths:
        for path, rel in _iter_py(root):
            out.extend(analyze_file(path, config, rel=rel,
                                    tests_blob=tests_blob))
    return out
