"""Divisibility-aware logical-axis sharding (GSPMD).

Model code annotates tensors with *logical* axis names; a rule table maps
logical names to mesh axes per execution mode. ``resolve`` drops a mesh axis
whenever the dimension is not divisible by the mesh-axis size — heterogenous
head counts (14 q-heads on a 16-way model axis, 8 kv-heads, 40 experts, odd
vocabs) then fall back to replication instead of failing to lower, and vocab
dims are padded by the models to stay shardable (Megatron-style).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# Rule tables. Keys are logical axis names used throughout repro.models.
INFER_RULES: Dict[str, Axes] = {
    "batch": ("data",),
    "seq": None,
    "cache_seq": None,         # launch code may set ("model",) when KV heads
    #                            do not divide the model axis (seq-parallel KV)
    "embed": None,             # replicated over data in inference
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "qkv_flat": ("model",),    # fused q/kv projection output dim
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": None,
    "moe_cap": None,           # expert capacity dim (hillclimb lever)
    "expert_ffn": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "stack": None,
}

# Training: FSDP — shard the replicated-embed dims over data too.
TRAIN_RULES: Dict[str, Axes] = dict(
    INFER_RULES,
    embed=("data",),
    experts=None,
)

# Multi-pod training: gradients all-reduce over ("pod","data"); batch spans
# both. (Serving multi-pod uses the pod axis for PP instead — launch/pipeline.)
TRAIN_RULES_MULTIPOD: Dict[str, Axes] = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
)

INFER_RULES_MULTIPOD: Dict[str, Axes] = dict(
    INFER_RULES,
    batch=("pod", "data"),
)


def _axis_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve(names: Sequence[Optional[str]], shape: Sequence[int],
            rules: Dict[str, Axes], mesh: Mesh) -> P:
    """Logical names -> PartitionSpec, dropping non-divisible axes."""
    assert len(names) == len(shape), (names, shape)
    out = []
    used: set = set()
    for name, dim in zip(names, shape):
        axes = rules.get(name) if name else None
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        kept = []
        denom = 1
        for a in axes:
            if a in used:
                continue
            sz = mesh.shape[a]
            if dim % (denom * sz) == 0:
                kept.append(a)
                denom *= sz
        for a in kept:
            used.add(a)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


@dataclasses.dataclass
class Sharder:
    """Annotation helper threaded through model code.

    ``mesh=None`` (unit tests, single CPU) makes every call the identity.
    """

    mesh: Optional[Mesh] = None
    rules: Dict[str, Axes] = dataclasses.field(
        default_factory=lambda: dict(INFER_RULES))

    def spec(self, names: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        if self.mesh is None:
            return P()
        return resolve(names, shape, self.rules, self.mesh)

    def constrain(self, x: jax.Array, *names: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = self.spec(names, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def named_sharding(self, names: Sequence[Optional[str]],
                       shape: Sequence[int]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(names, shape))


def tree_shardings(specs_tree, shapes_tree, mesh: Mesh,
                   rules: Dict[str, Axes]):
    """Map a pytree of logical-name tuples + ShapeDtypeStructs to
    NamedShardings (for jit in_shardings)."""
    return jax.tree.map(
        lambda names, sds: NamedSharding(
            mesh, resolve(names, sds.shape, rules, mesh)),
        specs_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
