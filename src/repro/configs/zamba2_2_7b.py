"""zamba2-2.7b [hybrid] — Mamba2 trunk + shared attention blocks.

54L d_model=2560 32H (MHA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]. One shared transformer block (attention + MLP over
the concat of hidden and trunk input, as in Zamba) fires every 6 trunk
layers; each application keeps its own KV cache. Simplifications vs the HF
checkpoint (per-application LoRA adapters, dual alternating shared blocks)
are recorded in DESIGN.md §5.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,                 # mamba2 trunk layers
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,                  # shared block MLP
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_period=6,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    rope_theta=10_000.0,
    supports_decode=True,
    subquadratic=True,           # SSM trunk dominates; runs long_500k
    source="arXiv:2411.15242; hf",
)
