"""Architecture registry: ``--arch <id>`` resolution.

The 10 assigned architectures plus the paper's two evaluation models.
"""

from __future__ import annotations

from typing import Dict, List

from repro.configs import shapes as shapes  # re-export module
from repro.configs.base import ArchConfig
from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.llama31_70b import CONFIG as _llama70b
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.phi35_moe_42b_a6_6b import CONFIG as _phi35
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.zamba2_2_7b import CONFIG as _zamba2

ASSIGNED: Dict[str, ArchConfig] = {
    "whisper-tiny": _whisper,
    "command-r-plus-104b": _command_r,
    "internlm2-1.8b": _internlm2,
    "qwen2-0.5b": _qwen2,
    "h2o-danube-3-4b": _danube,
    "granite-moe-3b-a800m": _granite,
    "phi3.5-moe-42b-a6.6b": _phi35,
    "qwen2-vl-2b": _qwen2vl,
    "zamba2-2.7b": _zamba2,
    "mamba2-1.3b": _mamba2,
}

PAPER_MODELS: Dict[str, ArchConfig] = {
    "llama-3.1-70b": _llama70b,
    "qwen3-32b": _qwen3,
}

REGISTRY: Dict[str, ArchConfig] = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def assigned_archs() -> List[str]:
    return list(ASSIGNED)


__all__ = ["ArchConfig", "ASSIGNED", "PAPER_MODELS", "REGISTRY",
           "get_config", "assigned_archs", "shapes"]
