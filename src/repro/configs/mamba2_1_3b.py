"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]. d_inner = 2*d_model = 4096, head_dim 64 =>
64 SSD heads.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    norm="rmsnorm",
    gated_ffn=False,
    act="silu",
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=True,
    source="arXiv:2405.21060; unverified",
)
