"""Qwen3-32B — the paper's second evaluation model (§7).

64L d_model=5120 64H (GQA kv=8) head_dim=128 d_ff=25600 vocab=151936
[arXiv:2505.09388].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    rope_theta=1_000_000.0,
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2505.09388 (paper eval model)",
)
