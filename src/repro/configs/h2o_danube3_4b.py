"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
[arXiv:2401.16818; unverified]. SWA window 4096 (mistral-style) makes decode
memory O(window) — the one dense arch that runs long_500k (ring-buffer KV).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    swa_window=4096,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    rope_theta=10_000.0,
    supports_decode=True,
    subquadratic=True,          # SWA => O(window) per step
    source="arXiv:2401.16818; unverified",
)
