"""granite-moe-3b-a800m [moe] — 40 experts, top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per-expert) vocab=49155
[hf:ibm-granite; hf]. NOTE: the assignment line says both "MoE 40e top-8" and
"32 experts top-8"; the HF granite-3.0-3b-a800m card says 40 experts top-8,
so we use 40 (recorded in DESIGN.md §5).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                    # per-expert intermediate
    vocab=49155,
    n_experts=40,
    moe_top_k=8,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    supports_decode=True,
    subquadratic=False,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base; hf",
)
