"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf].
The vision tower is a stub per the brief: ``input_specs`` provides precomputed
patch embeddings (B, S, d_model) and 3-axis M-RoPE position ids (3, B, S).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    m_rope=True,
    mrope_sections=(16, 24, 24),
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    frontend="vision_embeds",
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2409.12191; hf",
)
