"""Llama-3.1-70B — the paper's primary evaluation model (§7).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2407.21783].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.1-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    rope_theta=500_000.0,
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2407.21783 (paper eval model)",
)
