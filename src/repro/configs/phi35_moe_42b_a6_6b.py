"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 (per-expert) vocab=32064
[hf:microsoft/Phi-3.5-MoE-instruct; hf].
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,                   # per-expert intermediate
    vocab=32064,
    n_experts=16,
    moe_top_k=2,
    norm="layernorm",
    gated_ffn=True,
    act="silu",
    rope_theta=10_000.0,
    supports_decode=True,
    subquadratic=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
