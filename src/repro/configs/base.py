"""Architecture configuration schema.

One ``ArchConfig`` per assigned architecture (``repro/configs/<id>.py``),
shared by BOTH planes: ``to_modelspec()`` feeds the analytical estimator
(paper Table 2) and ``repro.models.build_model`` builds the executable JAX
model, so the two can never drift.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.modelspec import LayerSpec, ModelSpec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 => d_model // n_heads
    # attention details
    qkv_bias: bool = False
    o_bias: bool = False
    mlp_bias: bool = False
    swa_window: Optional[int] = None
    rope_theta: float = 10000.0
    m_rope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # hybrid (zamba2): one shared transformer block applied every N trunk
    # layers (with its own KV cache per application)
    hybrid_period: int = 0
    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    # misc
    frontend: str = "none"          # none | audio_frames | vision_embeds
    tie_embeddings: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    gated_ffn: bool = True
    act: str = "silu"               # silu | gelu
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 128
    # shape applicability (see DESIGN.md §5)
    supports_decode: bool = True
    subquadratic: bool = False      # may run long_500k
    max_position: int = 1 << 20
    source: str = ""

    # ----- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def dtype_bytes(self) -> int:
        return {"bfloat16": 2, "float16": 2, "float32": 4}[self.dtype]

    # ----- estimator view ---------------------------------------------------
    def _attn_layer(self, kind: str = "attn+ffn") -> LayerSpec:
        return LayerSpec(
            kind, self.d_model, self.n_heads, self.n_kv_heads, self.hd,
            self.d_ff, gated_ffn=self.gated_ffn, window=self.swa_window,
            n_experts=self.n_experts, top_k=self.moe_top_k)

    def _mamba_layer(self) -> LayerSpec:
        return LayerSpec(
            "mamba2", self.d_model, 0, 0, 0, 0, gated_ffn=False,
            ssm_state=self.ssm_state, ssm_heads=self.ssm_heads,
            ssm_head_dim=self.ssm_head_dim, conv_dim=self.conv_width)

    def shared_attn_positions(self) -> Tuple[int, ...]:
        """Trunk indices after which the shared block fires (zamba2)."""
        if self.hybrid_period <= 0:
            return ()
        return tuple(range(self.hybrid_period - 1, self.n_layers,
                           self.hybrid_period))

    def to_modelspec(self) -> ModelSpec:
        if self.family == "ssm":
            layers = (self._mamba_layer(),) * self.n_layers
        elif self.family == "hybrid":
            # interleave: mamba trunk + shared attn applications as extra
            # per-layer entries so the DP splits see their true cost.
            layers = []
            shared = LayerSpec(
                "shared_attn", self.d_model, self.n_heads, self.n_kv_heads,
                self.hd, self.d_ff, gated_ffn=self.gated_ffn)
            pos = set(self.shared_attn_positions())
            for i in range(self.n_layers):
                layers.append(self._mamba_layer())
                if i in pos:
                    layers.append(shared)
            layers = tuple(layers)
        elif self.family == "moe":
            layers = (self._attn_layer("attn+moe"),) * self.n_layers
        else:
            layers = (self._attn_layer(),) * self.n_layers
        enc = ()
        if self.is_encdec:
            enc = (LayerSpec("enc", self.d_model, self.n_heads,
                             self.n_kv_heads, self.hd, self.d_ff,
                             gated_ffn=self.gated_ffn),) * self.n_encoder_layers
        return ModelSpec(self.name, layers, self.d_model, self.vocab,
                         dtype_bytes=self.dtype_bytes,
                         tie_embeddings=self.tie_embeddings,
                         encoder_layers=enc)

    # ----- reduced config for CPU smoke tests -------------------------------
    def reduced(self) -> "ArchConfig":
        """Same family/features, toy size, float32 (CPU smoke tests)."""
        n_layers = min(self.n_layers, 4 if self.hybrid_period == 0
                       else 2 * max(2, self.hybrid_period // 2))
        hybrid_period = 0 if self.hybrid_period == 0 else 2
        if hybrid_period:
            n_layers = 4
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, 2)) if self.n_kv_heads else 0
        if self.n_kv_heads == self.n_heads:        # MHA stays MHA
            n_kv = n_heads
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=16,
            d_ff=96 if self.n_experts == 0 else 32,
            vocab=503,                      # deliberately odd: exercises pad
            n_experts=min(self.n_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            hybrid_period=hybrid_period,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            swa_window=(8 if self.swa_window else None),
            dtype="float32",
            vocab_pad_multiple=8,
            mrope_sections=(4, 2, 2),
        )
