"""whisper-tiny [audio] — enc-dec, conv frontend stubbed.

4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865 [arXiv:2212.04356].
The audio frontend (log-mel + conv) is a stub per the brief: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d_model).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    qkv_bias=True,              # whisper uses biases (no bias on k_proj in
    o_bias=True,                # HF impl; we keep the fused-bias form)
    mlp_bias=True,
    norm="layernorm",
    gated_ffn=False,
    act="gelu",
    tie_embeddings=True,
    frontend="audio_frames",
    rope_theta=0.0,             # whisper uses absolute positions, not RoPE
    supports_decode=True,
    subquadratic=False,         # full attention -> skip long_500k
    source="arXiv:2212.04356; unverified",
)
