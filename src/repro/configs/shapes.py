"""Assigned input shapes and (arch x shape) cell enumeration.

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 new token,
                                                     KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> serve_step; requires
                                                     sub-quadratic attention

Skips (recorded in DESIGN.md §5): ``long_500k`` only for subquadratic archs
(mamba2 / zamba2 / h2o-danube SWA); decode shapes only for archs with a
decoder (all assigned archs have one).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str            # train_step | prefill_step | serve_step


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train_step")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill_step")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "serve_step")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "serve_step")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ArchConfig) -> List[ShapeSpec]:
    out = [TRAIN_4K, PREFILL_32K]
    if cfg.supports_decode:
        out.append(DECODE_32K)
        if cfg.subquadratic:
            out.append(LONG_500K)
    return out


def skipped_shapes_for(cfg: ArchConfig) -> List[Tuple[ShapeSpec, str]]:
    out = []
    if not cfg.supports_decode:
        out.append((DECODE_32K, "encoder-only: no decode step"))
        out.append((LONG_500K, "encoder-only: no decode step"))
    elif not cfg.subquadratic:
        out.append((LONG_500K,
                    "pure full attention: O(S^2) at 524288 not servable"))
    return out


def all_cells(configs) -> List[Tuple[ArchConfig, ShapeSpec]]:
    return [(cfg, sh) for cfg in configs for sh in shapes_for(cfg)]
