"""command-r-plus-104b [dense] — GQA, no-bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified].
Cohere models tie input/output embeddings and use LayerNorm.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    norm="layernorm",
    gated_ffn=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=75_000_000.0,
    supports_decode=True,
    subquadratic=False,
    source="hf:CohereForAI/c4ai-command-r-plus; unverified",
)
