"""qwen2-0.5b [dense] — GQA with QKV bias, tied embeddings.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 [arXiv:2407.10671; hf].
14 q-heads deliberately do not divide the 16-way model axis — exercises the
divisibility-aware sharding fallback.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    norm="rmsnorm",
    gated_ffn=True,
    act="silu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    supports_decode=True,
    subquadratic=False,
    source="arXiv:2407.10671; hf",
)
