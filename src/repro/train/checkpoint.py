"""Checkpointing: sharded save/restore with manifests + elastic re-shard.

Fault tolerance for training on spot/preemptible capacity (DESIGN.md §7):

  * every save writes per-leaf ``.npy`` files + a JSON manifest with step,
    tree structure, shapes/dtypes, and a content digest per leaf;
  * saves are atomic (tmp dir + rename) so an interruption mid-save never
    corrupts the latest checkpoint;
  * restore targets ANY mesh: arrays are loaded full and re-sharded by the
    caller's in_shardings (elastic scale-up/down after membership change);
  * ``keep`` rotation bounds disk.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step{step}_")
    manifest = {"step": step, "leaves": []}
    for name, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        fname = hashlib.md5(name.encode()).hexdigest()[:16] + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "digest": hashlib.md5(arr.tobytes()).hexdigest()[:16],
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Optional[Any] = None,
                       verify_digest: bool = True) -> Any:
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (a matching pytree of NamedSharding) — this is the elastic
    path: the saved mesh and the restore mesh may differ."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    flat_like = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        name = jax.tree_util.keystr(p)
        entry = by_path[name]
        arr = np.load(os.path.join(d, entry["file"]))
        if verify_digest:
            got = hashlib.md5(arr.tobytes()).hexdigest()[:16]
            if got != entry["digest"]:
                raise IOError(f"digest mismatch for {name}")
        leaves.append(arr.astype(entry["dtype"]))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
