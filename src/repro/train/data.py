"""Training data pipeline.

Deterministic, shardable synthetic token stream (seeded per (step, host)) +
a file-backed binary token reader for real corpora. Both yield the batch
dict the models consume: tokens / targets / mask (+ embeds for stubbed
frontends, positions for M-RoPE).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


class SyntheticLM:
    """Zipf-distributed token stream; next-step targets; full mask."""

    def __init__(self, cfg: ArchConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.dc.seed * 1_000_003 + step)
                                    % (2 ** 31 - 1))
        c, dc = self.cfg, self.dc
        toks = rng.zipf(1.3, size=(dc.batch, dc.seq_len + 1))
        toks = np.minimum(toks, c.vocab - 1).astype(np.int32)
        out: Dict[str, np.ndarray] = {
            "targets": toks[:, 1:],
            "mask": np.ones((dc.batch, dc.seq_len), np.float32),
        }
        if c.is_encdec:
            out["tokens"] = toks[:, :-1]
            out["embeds"] = rng.randn(dc.batch, dc.seq_len,
                                      c.d_model).astype(np.float32)
        elif c.frontend == "vision_embeds":
            out["embeds"] = rng.randn(dc.batch, dc.seq_len,
                                      c.d_model).astype(np.float32)
        else:
            out["tokens"] = toks[:, :-1]
        if c.m_rope:
            pos = np.broadcast_to(np.arange(dc.seq_len)[None],
                                  (dc.batch, dc.seq_len))
            out["positions"] = np.broadcast_to(
                pos[None], (3, dc.batch, dc.seq_len)).astype(np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class BinaryTokenFile:
    """Flat uint16/uint32 token file reader with epoch shuffling of
    sequence offsets (the custom raw-binary layout mirrors the paper's §6
    observation: store only the needed partition, stream it directly)."""

    def __init__(self, path: str, cfg: ArchConfig, dc: DataConfig,
                 dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.cfg = cfg
        self.dc = dc
        n_seq = (len(self.tokens) - 1) // dc.seq_len
        rng = np.random.RandomState(dc.seed)
        self.order = rng.permutation(n_seq)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        n = len(self.order)
        idx = [self.order[(step * dc.batch + i) % n]
               for i in range(dc.batch)]
        rows = np.stack([
            self.tokens[j * dc.seq_len: j * dc.seq_len + dc.seq_len + 1]
            for j in idx]).astype(np.int32)
        rows = np.minimum(rows, self.cfg.vocab - 1)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:],
                "mask": np.ones((dc.batch, dc.seq_len), np.float32)}
