"""Elastic training: react to cluster membership changes.

When spot capacity changes mid-run the trainer (1) checkpoints, (2) rebuilds
the mesh for the surviving device count, (3) restores with the new mesh's
shardings, (4) rescales the data-parallel batch (keeping per-device batch
constant — linear-scaling rule with LR adjustment hook).

On this CPU container meshes are host-device meshes; on real TPU the same
code re-initializes the runtime across the surviving hosts.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.sharding import rules as R
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class ElasticState:
    mesh: Any
    n_devices: int
    global_batch: int


def plan_resize(old: ElasticState, new_n_devices: int,
                model_axis: int) -> Tuple[Tuple[int, int], int]:
    """New (data, model) mesh shape + global batch. The model axis is fixed
    by the sharding degree (weights layout); data axis absorbs the change."""
    model = min(model_axis, new_n_devices)
    while new_n_devices % model:
        model //= 2
    data = new_n_devices // model
    per_dev = max(1, old.global_batch // max(1, old.n_devices))
    return (data, model), per_dev * new_n_devices


def resize_mesh(old: ElasticState, new_n_devices: int, model_axis: int,
                devices=None) -> ElasticState:
    import numpy as np
    shape, new_batch = plan_resize(old, new_n_devices, model_axis)
    devices = (devices or jax.devices())[:shape[0] * shape[1]]
    mesh = jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), ("data", "model"))
    return ElasticState(mesh, new_n_devices, new_batch)


def reshard_state(state: Any, specs: Any, new: ElasticState,
                  rules: Optional[Dict] = None) -> Any:
    """Re-shard a (restored or live) train state onto the new mesh."""
    rules = rules or dict(R.TRAIN_RULES)

    def put(leaf, names):
        sh = jax.sharding.NamedSharding(
            new.mesh, R.resolve(names, leaf.shape, rules, new.mesh))
        return jax.device_put(leaf, sh)

    return jax.tree.map(
        put, state, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))
