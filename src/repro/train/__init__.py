from repro.train import checkpoint, elastic
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                   init_adamw)
from repro.train.train_step import (TrainState, choose_microbatches,
                                    init_train_state, make_train_step,
                                    train_state_specs)

__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "init_adamw",
           "TrainState", "choose_microbatches", "init_train_state",
           "make_train_step", "train_state_specs", "DataConfig",
           "SyntheticLM", "checkpoint", "elastic"]
