"""AdamW optimizer (self-contained — no optax dependency) with gradient
clipping and optional gradient compression hooks for DCN-bound pods.

Moments are fp32 regardless of param dtype (bf16-safe training).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # fp32 pytree
    v: Any                   # fp32 pytree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    # gradient compression across the DP axes (see DESIGN.md §7): grads are
    # reduced in bf16 instead of fp32 — halves DCN bytes for multi-pod DP.
    compress_grads_bf16: bool = True


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def _global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v)
