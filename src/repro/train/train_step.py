"""Training step: microbatched gradient accumulation + AdamW.

Gradient accumulation is mandatory at the assigned train_4k shape: a single
forward over (256 x 4096) tokens would materialize (tokens x vocab) logits —
petabytes for the 256k-vocab archs. The batch is split into microbatches and
scanned; grads accumulate in fp32; each microbatch's layers are rematerialized
(``remat=True`` in the model).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                   init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(params) -> TrainState:
    return TrainState(params, init_adamw(params))


def train_state_specs(param_specs) -> TrainState:
    """Logical-name tree matching TrainState (for in_shardings)."""
    return TrainState(
        params=param_specs,
        opt=AdamWState(step=(), m=param_specs, v=param_specs))


def choose_microbatches(global_batch: int, seq_len: int, vocab: int,
                        n_chips: int, logit_budget_bytes: float = 2.68e8
                        ) -> int:
    """Pick grad-accum steps so per-chip microbatch logits stay under budget.

    logits bytes/chip ~= mb*seq*vocab*4 / n_chips (batch+vocab sharded).
    """
    n_micro = 1
    while n_micro < global_batch:
        mb = global_batch // n_micro
        if mb * seq_len * vocab * 4.0 / n_chips <= logit_budget_bytes:
            break
        n_micro *= 2
    return min(n_micro, global_batch)


def make_train_step(model, opt_cfg: Optional[AdamWConfig] = None,
                    n_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` leaves have leading dim == global_batch (except "positions"
    with its (3, B, S) layout); they are reshaped to
    (n_micro, mb, ...) and scanned.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = model.loss

    def split_micro(x):
        if x.ndim >= 3 and x.shape[0] == 3:     # (3, B, S) m-rope positions
            b = x.shape[1]
            mb = b // n_microbatches
            x = x.reshape((3, n_microbatches, mb) + x.shape[2:])
            return jnp.moveaxis(x, 1, 0)
        b = x.shape[0]
        mb = b // n_microbatches
        return x.reshape((n_microbatches, mb) + x.shape[1:])

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        micro = jax.tree.map(split_micro, batch)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

        def accum(carry, mb):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
            if opt_cfg.compress_grads_bf16:
                # compression hook: accumulate via bf16 round-trip, which is
                # what the DP all-reduce would carry on the wire.
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.bfloat16).astype(jnp.float32),
                    grads)
            else:
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        if n_microbatches == 1:
            one = jax.tree.map(lambda x: x[0], micro)
            (gsum, lsum), _ = accum((zero_grads, 0.0), one)
        else:
            (gsum, lsum), _ = jax.lax.scan(
                accum, (zero_grads, 0.0), micro)
        grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
        params, opt = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = {"loss": lsum / n_microbatches,
                   "grad_norm": jnp.sqrt(sum(
                       jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))}
        return TrainState(params, opt), metrics

    return train_step
