"""The CI bench gate itself: baseline trend tracking must pass on the
committed baseline and demonstrably fail on a synthetic regression, and
the routing floor must bite."""

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.check_smoke import (TRACKED, check_baseline,  # noqa: E402
                                    check_kernels, check_routing,
                                    derived_floats, parse_rows)

BASELINE_CSV = ROOT / "benchmarks" / "baselines.csv"

SYNTH = """name,us_per_call,derived
kv_paging/capacity,0.0,contig=4 paged=8 ratio=2.00x
kv_paging/lazy_capacity,0.0,upfront=8 lazy=12 ratio=1.50x identical=1
prefix_share/capacity,0.0,noshare=14 share=24 ratio=1.71x
prefix_share/identity,0.0,identical=1 reduction=0.450
routing/cost,0.0,ratio=0.400 identical=1
kernels/chunk_dispatch,0.0,direct=9 scatter=2 reduction=1.22x identical=1
cluster_sim/contention,0.0,ratio=1.429x base_s=140.0 des_s=200.0 wait_s=60.0
cluster_sim/frontier,0.0,points=12 front=8 saving=1.238x
"""


def _perturb(text: str, row: str, key: str, factor: float) -> str:
    """Scale one derived value of one row by ``factor``."""
    out = []
    for line in text.splitlines():
        if line.startswith(row + ","):
            m = re.search(rf"{key}=([-+0-9.eE]+)", line)
            val = float(m.group(1)) * factor
            line = (line[:m.start()] + f"{key}={val:.4f}"
                    + line[m.end():])
        out.append(line)
    return "\n".join(out)


def test_baseline_self_comparison_passes():
    rows = parse_rows(SYNTH)
    assert check_baseline(rows, rows) == []


def test_synthetic_25pct_regression_fails_each_tracked_row():
    base = parse_rows(SYNTH)
    for name, key, direction in TRACKED:
        factor = 0.75 if direction == "higher" else 1.25
        bad = parse_rows(_perturb(SYNTH, name, key, factor))
        fails = check_baseline(bad, base)
        assert fails and name in fails[0], (name, fails)


def test_15pct_drift_within_tolerance():
    base = parse_rows(SYNTH)
    for name, key, direction in TRACKED:
        factor = 0.85 if direction == "higher" else 1.15
        drift = parse_rows(_perturb(SYNTH, name, key, factor))
        assert check_baseline(drift, base) == [], name


def test_improvement_never_fails():
    base = parse_rows(SYNTH)
    for name, key, direction in TRACKED:
        factor = 2.0 if direction == "higher" else 0.5
        better = parse_rows(_perturb(SYNTH, name, key, factor))
        assert check_baseline(better, base) == [], name


def test_tracked_row_vanishing_fails():
    base = parse_rows(SYNTH)
    gone = [r for r in base if r[0] != "routing/cost"]
    fails = check_baseline(gone, base)
    assert any("routing/cost" in f and "missing" in f for f in fails)


def test_row_absent_from_baseline_is_skipped():
    """A newly-tracked metric must not fail until a baseline commits it."""
    base = [r for r in parse_rows(SYNTH) if r[0] != "routing/cost"]
    assert check_baseline(parse_rows(SYNTH), base) == []


def test_committed_baseline_is_complete_and_self_consistent():
    """The file CI compares against carries every TRACKED metric and
    passes against itself (a re-baseline can never break the gate)."""
    rows = parse_rows(BASELINE_CSV.read_text())
    by_name = {n: d for n, _, d in rows}
    for name, key, _ in TRACKED:
        assert name in by_name, f"baseline missing tracked row {name}"
        assert key in derived_floats(by_name[name]), (name, key)
    assert check_baseline(rows, rows) == []


def test_kernels_floor_bites():
    ok_rows = (
        "kernels/chunk/jnp,1300.0,tok_s=95000\n"
        "kernels/chunk/pallas,8400.0,tok_s=15000 speedup=0.16x interp=1\n"
        "kernels/decode/jnp,260.0,tok_s=7600\n"
        "kernels/decode/pallas,4500.0,tok_s=440 speedup=0.06x interp=1\n"
        "kernels/chunk_dispatch,0.0,direct=9 scatter=2 contig_ops=11 "
        "paged_ops=9 reduction=1.22x identical=1\n")
    assert check_kernels(parse_rows(ok_rows)) == []
    # interpret mode exempts the speedup floor; a real accelerator doesn't
    on_dev = ok_rows.replace("speedup=0.16x interp=1",
                             "speedup=0.16x interp=0")
    assert any("speedup" in f for f in check_kernels(parse_rows(on_dev)))
    fast_dev = ok_rows.replace("speedup=0.16x interp=1",
                               "speedup=2.40x interp=0")
    assert check_kernels(parse_rows(fast_dev)) == []
    slow = ok_rows.replace("tok_s=95000", "tok_s=4000")
    assert any("floor" in f for f in check_kernels(parse_rows(slow)))
    diverged = ok_rows.replace("identical=1", "identical=0")
    assert any("diverged" in f for f in check_kernels(parse_rows(diverged)))
    no_gain = ok_rows.replace("reduction=1.22x", "reduction=1.00x")
    assert any("reduction" in f for f in check_kernels(parse_rows(no_gain)))
    assert any("chunk_dispatch" in f
               for f in check_kernels(parse_rows(ok_rows.rsplit(
                   "kernels/chunk_dispatch", 1)[0])))


def test_routing_floor_bites():
    ok = parse_rows(
        "routing/cost,0.0,ratio=0.500 identical=1\n"
        "routing/placement_mix,0.0,short_picks_low=1 mixed_picks_high=1\n")
    assert check_routing(ok) == []
    slow = parse_rows(
        "routing/cost,0.0,ratio=0.900 identical=1\n"
        "routing/placement_mix,0.0,short_picks_low=1 mixed_picks_high=1\n")
    assert any("0.85" in f for f in check_routing(slow))
    diverged = parse_rows(
        "routing/cost,0.0,ratio=0.500 identical=0\n"
        "routing/placement_mix,0.0,short_picks_low=1 mixed_picks_high=1\n")
    assert any("diverged" in f for f in check_routing(diverged))
    wrong_mix = parse_rows(
        "routing/cost,0.0,ratio=0.500 identical=1\n"
        "routing/placement_mix,0.0,short_picks_low=0 mixed_picks_high=1\n")
    assert any("mix" in f for f in check_routing(wrong_mix))
    assert check_routing([]) == ["no routing/cost row found"]
