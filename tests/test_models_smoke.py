"""Per-architecture smoke tests (deliverable f): every assigned arch in a
REDUCED config runs one forward/train step on CPU with shape checks and no
NaNs, plus the prefill/decode consistency invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, REGISTRY, get_config
from repro.configs.shapes import ShapeSpec, shapes_for, skipped_shapes_for
from repro.models import build_model, make_batch

TINY_TRAIN = ShapeSpec("tiny_train", 32, 2, "train_step")
TINY_PREFILL = ShapeSpec("tiny_prefill", 16, 2, "prefill_step")

ALL = sorted(REGISTRY)


def _model_for(name):
    cfg = REGISTRY[name].reduced()
    capf = (cfg.n_experts / max(1, cfg.moe_top_k)) if cfg.n_experts else 1.25
    return cfg, build_model(cfg, remat=False, attn_chunk=0, ssd_chunk=4,
                            moe_capacity_factor=capf)


@pytest.mark.parametrize("name", ALL)
def test_train_step_shapes_and_finite(name):
    cfg, m = _model_for(name)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, TINY_TRAIN)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree.leaves(finite)), name


@pytest.mark.parametrize("name", ALL)
def test_prefill_decode_shapes(name):
    cfg, m = _model_for(name)
    params = m.init(jax.random.PRNGKey(0))
    pre = make_batch(cfg, TINY_PREFILL)
    logits, cache = m.prefill(params, pre, max_len=24)
    assert logits.shape == (2, cfg.padded_vocab)
    tok = m.sample_greedy(logits)
    assert int(jnp.max(tok)) < cfg.vocab
    lg, cache = m.decode_step(params, cache, tok[:, None].astype(jnp.int32))
    assert lg.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg))), name
    assert int(cache["pos"]) == TINY_PREFILL.seq_len + 1


@pytest.mark.parametrize("name", ALL)
def test_prefill_decode_consistency(name):
    """prefill(x[:T]) last logits == prefill(x[:T-1]) + decode(x[T-1]) —
    the invariant output-preserving migration relies on."""
    cfg, m = _model_for(name)
    params = m.init(jax.random.PRNGKey(1))
    B, T = 2, 12
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (B, T)), jnp.int32)
    if cfg.is_encdec:
        frames = jnp.asarray(rng.randn(B, 8, cfg.d_model), jnp.float32)
        full, _ = m.prefill(params, {"embeds": frames, "tokens": toks},
                            max_len=T + 4)
        part, cache = m.prefill(
            params, {"embeds": frames, "tokens": toks[:, :T - 1]},
            max_len=T + 4)
    elif cfg.frontend == "vision_embeds":
        emb = jnp.asarray(rng.randn(B, T, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        pos3 = jnp.broadcast_to(pos[None], (3, B, T)).astype(jnp.int32)
        full, _ = m.prefill(params, {"embeds": emb, "positions": pos3},
                            max_len=T + 4)
        part, cache = m.prefill(
            params, {"embeds": emb[:, :T - 1],
                     "positions": pos3[:, :, :T - 1]}, max_len=T + 4)
        step, _ = m.decode_step(params, cache, emb[:, T - 1:T])
        np.testing.assert_allclose(np.asarray(full), np.asarray(step[:, 0]),
                                   atol=2e-3, rtol=1e-2)
        return
    else:
        full, _ = m.prefill(params, {"tokens": toks}, max_len=T + 4)
        part, cache = m.prefill(params, {"tokens": toks[:, :T - 1]},
                                max_len=T + 4)
    step, _ = m.decode_step(params, cache, toks[:, T - 1:T])
    np.testing.assert_allclose(np.asarray(full), np.asarray(step[:, 0]),
                               atol=2e-3, rtol=1e-2)


def test_all_assigned_archs_present():
    assert len(ASSIGNED) == 10
    assert len(PAPER_MODELS) == 2


def test_shape_skips_documented():
    # long_500k only for subquadratic archs; skips carry a reason
    for name, cfg in ASSIGNED.items():
        shapes = {s.name for s in shapes_for(cfg)}
        skips = dict((s.name, why) for s, why in skipped_shapes_for(cfg))
        if cfg.subquadratic:
            assert "long_500k" in shapes
        else:
            assert "long_500k" in skips and skips["long_500k"]


def test_vocab_padding():
    cfg = get_config("qwen2-0.5b")
    assert cfg.padded_vocab % 128 == 0
    assert cfg.padded_vocab >= cfg.vocab
    r = cfg.reduced()
    assert r.padded_vocab % 8 == 0 and r.padded_vocab != r.vocab


def test_swa_ring_cache_bounded():
    cfg = get_config("h2o-danube-3-4b").reduced()
    m = build_model(cfg, remat=False, attn_chunk=0)
    cache = m.init_cache(2, 64)           # window = 8 in reduced config
    assert cache["k"].shape[2] == cfg.swa_window
    assert "slot_pos" in cache


def test_param_counts_match_modelspec():
    """Executable param count ~= analytical ModelSpec count (<6% diff —
    norms/pad differ)."""
    for name in ["internlm2-1.8b", "mamba2-1.3b", "granite-moe-3b-a800m"]:
        cfg = get_config(name)
        m = build_model(cfg)
        analytical = cfg.to_modelspec().params_total()
        real = m.param_count()
        assert abs(real - analytical) / analytical < 0.06, (
            name, real, analytical)
