"""Demand-paged KV block allocation: reservation ledger, decode-time grow,
dry-pool preemption through the tensor store, skip-ahead admission, true
fragmentation accounting, the pinned-key ``take`` regression, the
KV-publish byte budget, and the simulator's preemption pricing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, GlobalServer, ServeRequest, TensorStore
from repro.serving.kv_blocks import BlockManager


def _params_for(cfg):
    m = build_model(cfg, remat=False, attn_chunk=0)
    return m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    return cfg, _params_for(cfg)


# -- block manager: ledger + grow ----------------------------------------------

def test_ledger_reserve_books_worst_case_allocates_live():
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=4,
                      max_blocks_per_slot=6, overcommit=2.0)
    assert bm.reservation_cap() == 16
    assert bm.reserve(0, 20, 6)                   # 5 reserved, 2 allocated
    assert bm.reserved_blocks() == 5
    assert bm.blocks_in_use() == 2 and bm.blocks_free() == 6
    assert (bm.table[0, :2] > 0).all() and bm.table[0, 2] == 0
    # grow inside allocated capacity is a no-op; crossing allocates one
    assert bm.grow(0, 8) and bm.blocks_in_use() == 2
    assert bm.grow(0, 9) and bm.blocks_in_use() == 3 and bm.grows == 1
    assert bm.table[0, 2] > 0
    assert bm.check_no_leak()
    # free releases ledger and blocks together
    assert bm.free(0) == 3
    assert bm.reserved_blocks() == 0 and bm.blocks_free() == 8
    assert bm.check_no_leak()


def test_ledger_overcommit_and_physical_caps():
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=8,
                      max_blocks_per_slot=8, overcommit=1.5)
    # cap = 1.5 * 8 = 12 reserved blocks
    assert bm.reserve(0, 16, 4)                   # 4 reserved, 1 allocated
    assert bm.reserve(1, 16, 4)                   # 8 reserved
    assert bm.can_reserve(16, 4)                  # 12 == cap: fits
    assert not bm.can_reserve(20, 4)              # 13 > cap: ledger refuses
    assert bm.reserve(2, 16, 4)
    assert not bm.can_reserve(4)                  # cap exhausted
    bm.free(0)
    # a single request's worst case must fit the pool PHYSICALLY no matter
    # the overcommit (otherwise it could thrash preempting forever)
    wide = BlockManager(n_blocks=5, block_size=4, max_slots=2,
                        max_blocks_per_slot=8, overcommit=4.0)
    assert not wide.can_reserve(24)               # 6 blocks > 4 physical
    assert wide.can_reserve(16)
    # and grow past the booked reservation is a programming error
    nb = BlockManager(n_blocks=9, block_size=4, max_slots=2,
                      max_blocks_per_slot=6)
    assert nb.reserve(0, 8, 4)
    with pytest.raises(AssertionError):
        nb.grow(0, 12)


def test_grow_fails_dry_leaving_state_intact():
    bm = BlockManager(n_blocks=4, block_size=4, max_slots=2,
                      max_blocks_per_slot=3, overcommit=2.0)
    assert bm.reserve(0, 12, 4)                   # 1 of 3 allocated
    assert bm.reserve(1, 8, 8)                    # 2 allocated: pool dry
    assert not bm.grow(0, 5)                      # free list empty
    assert bm.blocks_in_use() == 3 and bm.check_no_leak()
    bm.free(1)
    assert bm.grow(0, 5)                          # retry after a free works
    assert bm.check_no_leak()


def test_frag_tokens_measures_live_occupancy(setup):
    """Regression: fragmentation used to be measured against the lifetime
    reservation, hiding the unwritten tail of in-flight requests."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, block_size=8,
                 kv_alloc="upfront")
    r = ServeRequest(prompt=[1, 2, 3, 4], max_new_tokens=28)
    eng.admit(r)
    # upfront allocated ceil(32/8)=4 blocks; only the 4 prompt tokens live
    assert eng.block_stats()["frag_tokens"] == 4 * 8 - 4
    eng.step()
    assert eng.block_stats()["frag_tokens"] == 4 * 8 - 5
    lazy = Engine(cfg, params, max_batch=2, max_len=64, block_size=8)
    r2 = ServeRequest(prompt=[1, 2, 3, 4], max_new_tokens=28)
    lazy.admit(r2)
    # lazy allocated only the prefill block: frag is the block tail
    assert lazy.block_stats()["frag_tokens"] == 8 - 4
    assert lazy.block_stats()["reserved_blocks"] == 4


# -- engine: lazy grow + preemption --------------------------------------------

def test_lazy_matches_upfront_across_grow(setup):
    """Greedy outputs are byte-identical between kv_alloc='lazy' and
    'upfront'; the lazy run must actually grow (and, at overcommit 1.0,
    never preempt — reservations cannot exceed physical blocks)."""
    cfg, params = setup
    outs = {}
    for mode in ("lazy", "upfront"):
        eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                     kv_alloc=mode)
        rs = [ServeRequest(prompt=list(range(1, 4 + 3 * i)),
                           max_new_tokens=12) for i in range(4)]
        eng.admit_many(rs)
        eng.drain()
        outs[mode] = [list(r.generated) for r in rs]
        assert eng.bm.check_no_leak() and eng.bm.blocks_in_use() == 0
        if mode == "lazy":
            assert eng.stats.block_grows >= 1
            assert eng.stats.preemptions == 0
        else:
            assert eng.stats.block_grows == 0
    assert outs["lazy"] == outs["upfront"]


def test_preemption_roundtrip_byte_identical_standalone(setup):
    """An overcommitted pool preempts mid-decode; the standalone engine
    re-attaches the exported KV itself and finishes everything with the
    exact tokens of an unconstrained run."""
    cfg, params = setup

    def gen(**kw):
        eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                     **kw)
        rs = [ServeRequest(prompt=list(range(1, 10 + 2 * i)),
                           max_new_tokens=20) for i in range(3)]
        assert len(eng.admit_many(rs)) == 3
        eng.drain()
        assert all(r.done for r in rs)
        assert eng.bm.check_no_leak() and eng.bm.blocks_in_use() == 0
        return eng, [list(r.generated) for r in rs]

    _, ref = gen()
    eng, out = gen(n_blocks=11, kv_overcommit=2.5)    # 10 physical blocks
    assert out == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.kv_imports >= 1          # re-admitted via attach
    assert eng.stats.block_grows >= 1


def test_preemption_victim_has_fewest_generated(setup):
    """Legacy fewest-generated rule (victim_policy="fewest") pinned: the
    default cost-aware policy would pick the OLD request here (smaller
    context = cheaper restore), which tests/test_cluster_des.py covers."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                 n_blocks=8, kv_overcommit=2.0,      # 7 physical blocks
                 victim_policy="fewest")
    old = ServeRequest(prompt=list(range(1, 9)), max_new_tokens=30)
    eng.admit(old)
    for _ in range(6):
        eng.step()                            # old is well ahead
    young = ServeRequest(prompt=list(range(1, 17)), max_new_tokens=30)
    assert eng.admit(young)
    victims = []
    for _ in range(40):
        eng.step()
        victims += [r.rid for r, _ in eng._preempted]
        if victims:
            break
    assert victims and victims[0] == young.rid


def test_cost_victim_prefers_cheapest_readmission(setup):
    """Default cost-aware policy: the victim is the slot whose estimated
    re-admission (store restore round trip) is cheapest — here the OLD
    request, whose context occupies fewer KV blocks, even though the
    legacy fewest-generated rule would preempt the young one."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                 n_blocks=16, kv_overcommit=2.0)
    assert eng._victim_policy == "cost"
    old = ServeRequest(prompt=list(range(1, 9)), max_new_tokens=30)
    eng.admit(old)
    for _ in range(6):
        eng.step()                            # old: ctx ~15 -> 2 blocks
    young = ServeRequest(prompt=list(range(1, 17)), max_new_tokens=30)
    assert eng.admit(young)                   # young: ctx 17+ -> 3 blocks
    eng.step()
    s_old = next(i for i, r in enumerate(eng.slots) if r is old)
    s_young = next(i for i, r in enumerate(eng.slots) if r is young)
    assert len(young.generated) < len(old.generated)
    assert eng._victim_cost(s_old) < eng._victim_cost(s_young)
    # cost dominates: old is picked even though young has fewer tokens
    assert eng._pick_victim([s_old, s_young]) == s_old


def test_cost_victim_tie_breaks_by_fewest_generated(setup):
    """Context is bucketed to the block grid before pricing, so two slots
    in the same bucket cost the same — and the fewest-generated rule must
    remain the live tie-break (regression gate for the legacy behavior)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                 n_blocks=16, kv_overcommit=2.0)
    r1 = ServeRequest(prompt=list(range(1, 7)), max_new_tokens=30)
    eng.admit(r1)
    for _ in range(4):
        eng.step()
    r2 = ServeRequest(prompt=list(range(1, 10)), max_new_tokens=30)
    assert eng.admit(r2)
    eng.step()
    s1 = next(i for i, r in enumerate(eng.slots) if r is r1)
    s2 = next(i for i, r in enumerate(eng.slots) if r is r2)
    assert len(r2.generated) < len(r1.generated)
    assert eng._victim_cost(s1) == eng._victim_cost(s2)   # same block bucket
    assert eng._pick_victim([s1, s2]) == s2               # fewest generated


def test_ledger_churn_never_leaks(setup):
    """Property-style: random admit/grow/preempt/finish interleavings on
    an overcommitted pool keep the ledger leak-free at every step."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                 n_blocks=13, kv_overcommit=2.0)
    rng = np.random.RandomState(7)
    queue = [ServeRequest(
        prompt=rng.randint(0, cfg.vocab, rng.randint(3, 30)).tolist(),
        max_new_tokens=int(rng.randint(2, 16))) for _ in range(12)]
    done = []
    steps = 0
    while (queue or eng.active() or eng._pending
           or eng._preempted) and steps < 2000:
        if queue and rng.rand() < 0.5:
            n = int(rng.randint(1, 4))
            adm = eng.admit_many(queue[:n])
            taken = {id(r) for r in adm}
            queue = [r for r in queue if id(r) not in taken]
        done += eng.step()
        assert eng.bm.check_no_leak()
        steps += 1
    assert len(done) == 12 and all(r.done for r in done)
    assert eng.bm.blocks_in_use() == 0 and eng.bm.reserved_blocks() == 0


def test_admit_skips_ahead_past_stuck_large(setup):
    """One oversized request must not starve fit-able smaller ones queued
    behind it (bounded skip-ahead, approximate FIFO preserved)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                 n_blocks=9)                         # 8 physical blocks
    hog = ServeRequest(prompt=list(range(1, 25)), max_new_tokens=8)
    assert eng.admit(hog)                            # 4 of 8 blocks
    big = ServeRequest(prompt=list(range(1, 33)), max_new_tokens=8)
    smalls = [ServeRequest(prompt=[7, 8, 9], max_new_tokens=4)
              for _ in range(2)]
    admitted = eng.admit_many([big] + smalls)
    # big needs 5 blocks (only 4 free) and is skipped; smalls drain past it
    assert [r.rid for r in admitted] == [r.rid for r in smalls]
    assert eng.stats.alloc_failures == 1
    eng.drain()
    assert eng.admit(big)                            # room freed: big fits
    eng.drain()
    assert big.done and hog.done and all(r.done for r in smalls)

    # the window is bounded: admission stops scanning after admit_window
    # failures instead of walking an arbitrarily long queue
    eng2 = Engine(cfg, params, max_batch=8, max_len=64, block_size=8,
                  n_blocks=3, admit_window=2)        # 2 physical blocks
    rs = [ServeRequest(prompt=list(range(1, 30)), max_new_tokens=4)
          for _ in range(6)]
    assert eng2.admit_many(rs) == []
    assert eng2.stats.alloc_failures == 2


# -- tensor store: pinned keys survive take ------------------------------------

def test_take_pinned_key_returns_none():
    """Regression: ``take`` used to consume a key regardless of refcount,
    yanking a pinned partition out from under attached engines."""
    store = TensorStore()
    store.put("m", "w", {"x": jnp.zeros((8,), jnp.float32)})
    ref = store.attach("m", "w")
    assert store.take("m", "w") is None       # pinned: not consumable
    assert store.contains("m", "w")
    assert store.attach("m", "w") is ref      # still the same arrays
    store.detach("m", "w")
    store.detach("m", "w")
    assert store.take("m", "w") is not None   # unpinned: consumed
    assert not store.contains("m", "w")
    assert store.check_consistent()


# -- server: preempt -> publish -> attach, budget-capped ----------------------

def _run_server(cfg, params, engine_kw, budget=None, n_new=20,
                use_kv_migration=True):
    store = TensorStore(budget_bytes=budget)
    srv = GlobalServer(cfg, store, max_batch=4, max_len=64,
                       use_kv_migration=use_kv_migration,
                       engine_kw=engine_kw)
    srv.add_pipeline(params, ["inst-A"])
    reqs = [ServeRequest(prompt=list(range(1, 10 + 2 * i)),
                         max_new_tokens=n_new) for i in range(3)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    return srv, reqs


def test_server_preempt_publish_attach_byte_identical(setup):
    cfg, params = setup
    _, ref = _run_server(cfg, params, {"block_size": 8})
    srv, out = _run_server(
        cfg, params,
        {"block_size": 8, "n_blocks": 11, "kv_overcommit": 2.5})
    kinds = [k for _, k, _ in srv.events]
    assert kinds.count("preempt") >= 1
    assert kinds.count("kv_publish") >= 1 and kinds.count("kv_attach") >= 1
    assert all(r.done for r in out)
    assert [list(r.generated) for r in out] \
        == [list(r.generated) for r in ref]
    # consumed payloads must not pin store memory
    assert not [k for k in srv.store._store if k[0] == "__kv__"]
    assert srv.store.check_consistent()


def test_server_preempt_without_store_recomputes(setup):
    cfg, params = setup
    _, ref = _run_server(cfg, params, {"block_size": 8})
    srv, out = _run_server(
        cfg, params,
        {"block_size": 8, "n_blocks": 11, "kv_overcommit": 2.5},
        use_kv_migration=False)
    kinds = [k for _, k, _ in srv.events]
    assert kinds.count("preempt") >= 1 and kinds.count("kv_publish") == 0
    assert [list(r.generated) for r in out] \
        == [list(r.generated) for r in ref]


def test_kv_publish_respects_store_budget(setup):
    """The KV-publish path evicts to the store's byte budget before (and
    via put, after) each publish: unpinned residency stays capped through
    an interruption storm of payloads, and accounting stays consistent."""
    cfg, params = setup
    store = TensorStore()
    srv = GlobalServer(cfg, store, max_batch=4, max_len=64,
                       use_kv_migration=True, engine_kw={"block_size": 8})
    srv.add_pipeline(params, ["inst-A", "inst-B"])
    srv.add_pipeline(params, ["inst-C"])
    weights_bytes = store.resident_bytes()     # pinned by the pipelines
    reqs = [ServeRequest(prompt=list(range(1, 12)), max_new_tokens=16)
            for _ in range(4)]
    for r in reqs:
        srv.submit(r)
    for _ in range(4):
        srv.step()
        srv.tick()
    # budget leaves room for roughly ONE KV payload beyond the weights
    one_kv = None
    for p in srv.pipelines:
        live = p.engine.export_live_kv()
        if live:
            one_kv = next(iter(live.values()))
            break
    assert one_kv is not None
    kv_bytes = one_kv["k"].nbytes + one_kv["v"].nbytes
    store.budget_bytes = weights_bytes + int(1.5 * kv_bytes)
    srv.interrupt_instance("inst-A")
    kv_resident = sum(b for k, b in store._bytes.items()
                      if k[0] == "__kv__")
    assert kv_resident <= int(1.5 * kv_bytes)
    assert store.check_consistent()
    srv.run_until_drained()
    assert all(r.done for r in reqs)           # evictees recomputed instead
    assert store.check_consistent()


# -- simulator: preemption priced as self-inflicted kv_restore -----------------

def test_sim_kv_pool_preemption_prices_restore():
    import dataclasses as dc

    from repro.cluster.simulator import ClusterSim, FTConfig
    from repro.cluster.workload import Request
    from repro.core import populate_cluster
    from repro.hw import AWS_INSTANCES, effective, paper_cluster
    spec = get_config("llama-3.1-70b").to_modelspec()
    insts = {n: dc.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232,
                            beam_k=1)
    reqs = [Request(rid=i, arrival_s=0.0, s_in=512, s_out=64)
            for i in range(8)]

    def run(pool):
        ft = FTConfig(use_spot=False, kv_pool_tokens=pool)
        sim = ClusterSim(spec, plan.pipelines[:1], ft, 512, 64,
                         efficiency=0.5)
        return sim.run(reqs, duration_s=50_000.0, offline=True)

    free = run(0)
    tight = run(1100)          # < 2 finished contexts' worth of pool
    assert free.kv_preemptions == 0
    assert tight.kv_preemptions >= 1
    assert len(tight.completed) == len(free.completed) == 8
    # the self-inflicted restore round trips cost wall time
    assert max(r.finish_s for r in tight.completed) \
        > max(r.finish_s for r in free.completed)

    # regression: a spot interruption clears the kv_preempted flag — the
    # payload died with the node, so re-admission pays recompute (with
    # migration off, from scratch) and TTFT stays well-defined
    pool_name = plan.pipelines[0].stages[0].instance.name
    ft = FTConfig(use_spot=True, request_migration=False,
                  kv_pool_tokens=1100)
    sim = ClusterSim(spec, plan.pipelines[:1], ft, 512, 64, efficiency=0.5)
    res = sim.run(reqs, duration_s=50_000.0,
                  events=[(20.0, pool_name, -1)], offline=True)
    assert len(res.completed) == 8
    assert all(r.first_token_s >= 0 for r in res.completed)
    assert all(t >= 0 for t in res.latencies("ttft"))
