"""Trip-weighted HLO cost analyzer unit tests (synthetic HLO + real jits)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_costs import (normalize_cost_analysis, parse_costs,
                                    trip_weighted_costs)

SAMPLE = """
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %w = f32[8,8]{1,0} parameter(0)
  %d = f32[8,8]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %d0 = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %w0 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8] get-tuple-element(%w0), index=1
}
"""


def test_dot_flops_from_shapes():
    comps, entry = parse_costs(SAMPLE)
    assert entry == "main"
    # each dot: 2 * 8*8 (out) * 8 (contract) = 1024 flops
    assert comps["main"].flops == pytest.approx(1024)
    assert comps["body"].flops == pytest.approx(1024)


def test_trip_weighting():
    t1 = trip_weighted_costs(SAMPLE, trip_hints=())
    t5 = trip_weighted_costs(SAMPLE, trip_hints=(5,))
    # +1 flop: the while-cond compare counts as one elementwise op
    assert t1["flops"] == pytest.approx(1024 * 2 + 1)    # body once
    assert t5["flops"] == pytest.approx(1024 * 6 + 1)    # 1 top + 5x body


def test_matches_real_scan_exactly():
    def scanned(a, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, a, ws)
        return out
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(a, ws).compile()
    t = trip_weighted_costs(comp.as_text(), trip_hints=(4,))
    assert t["flops"] == pytest.approx(4 * 2 * 64 ** 3, rel=0.02)


def test_xla_cost_analysis_counts_scan_body_once():
    """The empirical fact that motivates hlo_costs (EXPERIMENTS §Roofline)."""
    def scanned(a, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, a, ws)
        return out
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    comp = jax.jit(scanned).lower(a, ws).compile()
    # cost_analysis() is a dict on older JAX, a list of per-computation
    # dicts on newer JAX — normalize before poking at it
    ca = normalize_cost_analysis(comp.cost_analysis())
    assert ca["flops"] == pytest.approx(2 * 64 ** 3, rel=0.02)
