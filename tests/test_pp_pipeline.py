"""PP-over-pods building blocks (no big-mesh compile needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.pipeline import (pack_pp_params, pp_layer_split,
                                   pp_supported)
from repro.models import build_model


def test_pp_supported_families():
    assert pp_supported(get_config("qwen3-32b"))
    assert pp_supported(get_config("granite-moe-3b-a800m"))
    assert not pp_supported(get_config("mamba2-1.3b"))       # ssm
    assert not pp_supported(get_config("h2o-danube-3-4b"))   # swa
    assert not pp_supported(get_config("whisper-tiny"))      # enc-dec


def test_layer_split_covers_all_layers():
    cfg = get_config("llama-3.1-70b")
    for n_stages in (2, 4):
        split = pp_layer_split(cfg, n_stages)
        assert len(split) == n_stages
        assert sum(split) == cfg.n_layers
        assert all(x >= 1 for x in split)


def test_homogeneous_split_near_even():
    cfg = get_config("qwen3-32b")
    split = pp_layer_split(cfg, 2)
    assert abs(split[0] - split[1]) <= 2


def test_heterogeneous_split_asymmetric():
    """The paper's §2.3 mechanism: a slower pod gets fewer layers."""
    cfg = get_config("qwen3-32b")
    split = pp_layer_split(cfg, 2, pod_flops=[1.0, 0.5])
    assert split[0] > split[1], split
    # roughly proportional to capability (memory-bound decode => ~bandwidth
    # ratio; both flops and bw scaled by 0.5 here)
    assert 1.5 < split[0] / split[1] < 3.0


def test_pack_pp_params_roundtrip():
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    split = [3, 1]
    packed = pack_pp_params(params, split)
    assert "pp_mask" in packed
    mask = np.asarray(packed["pp_mask"])
    assert mask.shape == (2, 3)
    assert mask.sum() == 4                       # 3 + 1 active layers
    # stage 0 rows 0..2 equal original layers 0..2; stage 1 row 0 == layer 3
    for leaf_name in ("ln_attn",):
        orig = np.asarray(params["layers"][leaf_name]["w"])
        new = np.asarray(packed["layers"][leaf_name]["w"])
        np.testing.assert_array_equal(new[0, :3], orig[:3])
        np.testing.assert_array_equal(new[1, 0], orig[3])
        assert np.all(new[1, 1:] == 0)           # padding
