"""jaxlint fixture tests (ISSUE 8): every check has a known-bad snippet
that must flag and a known-good snippet that must pass, plus suppression,
baseline round-trip / line-drift stability, and the repo gate (the
committed baseline keeps `python -m repro.analysis src/` at exit 0)."""

import pathlib
import textwrap

from repro.analysis import (
    CHECKS,
    LintConfig,
    analyze_file,
    analyze_paths,
    load_baseline,
    write_baseline,
)
from repro.analysis.__main__ import main as jaxlint_main

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _lint(tmp_path, src, config=None, tests_blob=""):
    p = tmp_path / "mod.py"
    p.write_text(textwrap.dedent(src))
    return analyze_file(str(p), config or LintConfig(),
                        tests_blob=tests_blob)


def _checks(findings):
    return {f.check for f in findings}


# -- donated-use ---------------------------------------------------------------

def test_donated_use_flags_read_after_dispatch(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def make(f):
            g = jax.jit(f, donate_argnums=(0,))

            def run(cache, tok):
                out = g(cache, tok)
                return out, cache["k"]
            return run
        """)
    assert _checks(fs) == {"donated-use"}
    assert "donated to `g`" in fs[0].message


def test_donated_use_flags_same_statement_reuse(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def make(f):
            g = jax.jit(f, donate_argnums=(0,))

            def run(x):
                return g(x) + x
            return run
        """)
    assert _checks(fs) == {"donated-use"}


def test_donated_use_passes_rebinding_idiom(tmp_path):
    # the engine's idiom: the dispatch statement rebinds the donated name
    fs = _lint(tmp_path, """
        import jax

        def make(f):
            g = jax.jit(f, donate_argnums=(1,))

            def run(params, cache, tok):
                logits, cache = g(params, cache, tok)
                return logits, cache["pos"]
            return run
        """)
    assert fs == []


def test_donated_use_passes_later_rebind_then_read(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        def make(f):
            g = jax.jit(f, donate_argnums=(0,))

            def run(cache, tok):
                out = g(cache, tok)
                cache = out["cache"]
                return cache["k"]
            return run
        """)
    assert fs == []


# -- host-sync -----------------------------------------------------------------

_HOT = LintConfig(hot_functions=(r"^hot$",))


def test_host_sync_flags_hot_path_syncs(tmp_path):
    fs = _lint(tmp_path, """
        import numpy as np

        def hot(x, vals):
            a = np.asarray(x)
            b = x.item()
            c = int(vals[0])
            return a, b, c
        """, config=_HOT)
    assert _checks(fs) == {"host-sync"} and len(fs) == 3


def test_host_sync_passes_host_literals_and_cold_paths(tmp_path):
    fs = _lint(tmp_path, """
        import numpy as np

        def hot(ms):
            slots = np.array([m.slot for m in ms], np.int32)
            n = int(len(ms))
            return slots, n

        def cold(x):
            return np.asarray(x)
        """, config=_HOT)
    assert fs == []


# -- retrace -------------------------------------------------------------------

def test_retrace_flags_varying_slice_into_jit(tmp_path):
    fs = _lint(tmp_path, """
        import jax
        import jax.numpy as jnp

        def make(f):
            g = jax.jit(f)

            def caller(payload, n):
                return g(jnp.asarray(payload[:, :n]))
            return caller
        """)
    assert _checks(fs) == {"retrace"}


def test_retrace_passes_constant_slices_and_blessed(tmp_path):
    src = """
        import jax

        def make(f):
            g = jax.jit(f)

            def caller(payload, n):
                return g(payload[:, :8])
            return caller
        """
    assert _lint(tmp_path, src) == []
    varying = src.replace(":8]", ":n]")
    assert _checks(_lint(tmp_path, varying)) == {"retrace"}
    blessed = LintConfig(blessed_retrace=(r"caller$",))
    assert _lint(tmp_path, varying, config=blessed) == []


# -- pallas-grid ---------------------------------------------------------------

def test_pallas_grid_flags_magic_numbers(tmp_path):
    fs = _lint(tmp_path, """
        from jax.experimental import pallas as pl

        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, interpret=False):
            return pl.pallas_call(
                kern,
                grid=(8, 128),
                in_specs=[pl.BlockSpec((1, 128), lambda i, j: (i, j))],
                interpret=interpret,
            )(x)
        """, tests_blob="run(x)")
    assert _checks(fs) == {"pallas-grid"}
    assert len(fs) == 3                    # 8, 128 in grid; 128 in spec


def test_pallas_grid_passes_named_constants(tmp_path):
    fs = _lint(tmp_path, """
        from jax.experimental import pallas as pl

        B = 8
        N = 128

        def kern(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, interpret=False):
            return pl.pallas_call(
                kern,
                grid=(B, N),
                in_specs=[pl.BlockSpec((1, N), lambda i, j: (i, j))],
                interpret=interpret,
            )(x)
        """, tests_blob="run(x)")
    assert fs == []


# -- pallas-test ---------------------------------------------------------------

_PALLAS_WRAPPER = """
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def run(x{sig}):
        return pl.pallas_call(kern, grid=(1,){kw})(x)
    """


def test_pallas_test_flags_missing_interpret_and_coverage(tmp_path):
    src = _PALLAS_WRAPPER.format(sig="", kw="")
    fs = _lint(tmp_path, src, tests_blob="something_else()")
    msgs = " ".join(f.message for f in fs)
    assert _checks(fs) == {"pallas-test"} and len(fs) == 2
    assert "interpret" in msgs and "not referenced" in msgs


def test_pallas_test_passes_covered_wrapper(tmp_path):
    src = _PALLAS_WRAPPER.format(sig=", interpret=False",
                                 kw=", interpret=interpret")
    fs = _lint(tmp_path, src, tests_blob="assert run(x) == ref")
    assert fs == []


# -- traced-flow ---------------------------------------------------------------

def test_traced_flow_flags_branch_and_concretize(tmp_path):
    fs = _lint(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x

        @jax.jit
        def h(x):
            return int(x)
        """)
    assert _checks(fs) == {"traced-flow"} and len(fs) == 2


def test_traced_flow_passes_static_args_and_none_checks(tmp_path):
    fs = _lint(tmp_path, """
        import functools

        import jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def g(x, n):
            if n > 4:
                return x[:4]
            return x

        @jax.jit
        def k(x, opt=None):
            if opt is None:
                return x
            return x + opt
        """)
    assert fs == []


# -- suppression / baseline ----------------------------------------------------

def test_inline_and_preceding_comment_suppression(tmp_path):
    fs = _lint(tmp_path, """
        import numpy as np

        def hot(x, y):
            a = np.asarray(x)  # jaxlint: disable=host-sync -- intended
            # jaxlint: disable=host-sync -- comment-line form
            b = np.asarray(y)
            return a, b
        """, config=_HOT)
    assert fs == []


def test_suppression_is_check_specific(tmp_path):
    fs = _lint(tmp_path, """
        import numpy as np

        def hot(x):
            return np.asarray(x)  # jaxlint: disable=retrace -- wrong check
        """, config=_HOT)
    assert _checks(fs) == {"host-sync"}


def test_baseline_roundtrip_and_line_drift_stability(tmp_path):
    src = """
        import numpy as np

        def hot(x):
            return np.asarray(x)
        """
    fs = _lint(tmp_path, src, config=_HOT)
    assert len(fs) == 1
    bl = tmp_path / "baseline"
    assert write_baseline(str(bl), fs) == 1
    assert load_baseline(str(bl)) == {fs[0].fingerprint}
    # unrelated edits above the finding must not rotate the fingerprint
    drifted = textwrap.dedent(src).replace(
        "import numpy as np", "import os\n\nimport numpy as np")
    (tmp_path / "mod.py").write_text(drifted)
    fs2 = analyze_file(str(tmp_path / "mod.py"), _HOT, tests_blob="")
    assert fs2[0].line != fs[0].line
    assert fs2[0].fingerprint == fs[0].fingerprint


def test_every_check_has_catalogue_entry():
    assert set(CHECKS) == {"donated-use", "host-sync", "retrace",
                           "pallas-grid", "pallas-test", "traced-flow"}


# -- repo gate -----------------------------------------------------------------

def test_repo_is_clean_against_committed_baseline():
    """The committed baseline covers every finding on src/ — the CI
    analysis job runs exactly this gate."""
    cfg = LintConfig(tests_dir=str(ROOT / "tests"))
    findings = analyze_paths([str(ROOT / "src")], cfg)
    accepted = load_baseline(str(ROOT / ".jaxlint-baseline"))
    fresh = [f for f in findings if f.fingerprint not in accepted]
    assert fresh == [], "\n".join(f.render() for f in fresh)
    # and the baseline carries no stale (already-fixed) entries
    assert accepted <= {f.fingerprint for f in findings}


def test_cli_exit_codes(capsys):
    rc = jaxlint_main([str(ROOT / "src"),
                       "--baseline", str(ROOT / ".jaxlint-baseline"),
                       "--tests-dir", str(ROOT / "tests"),
                       "--fail-on-stale"])
    assert rc == 0
    assert jaxlint_main(["--list-checks"]) == 0
    assert jaxlint_main(["--select", "nope"]) == 2
    out = capsys.readouterr().out
    assert "donated-use" in out
