"""Discrete-event cluster simulator: typed event core, contended network
links, closed-form parity in the uncontended limit, link contention,
multi-region spot pools with correlated preemptions, per-pipeline spot/OD
mix, and the cost-vs-SLO frontier sweep."""

import dataclasses

import pytest

from repro.cluster import (ClusterSim, FTConfig, RegionSpec, Topology,
                           azure_conversation_like,
                           correlated_interruption_count, diurnal_rate,
                           generate_multi_region_trace, pareto_front,
                           scaled_pools, sweep_frontier)
from repro.cluster.events import (Arrive, EventQueue, Interrupt, Wake,
                                  dispatch)
from repro.cluster.network import LinkSpec, NetworkLink
from repro.cluster.spot_trace import PAPER_POOLS
from repro.configs import get_config
from repro.core import Placement, Stage, populate_cluster
from repro.core.modelspec import uniform_decoder
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.hw.profiles import DeviceProfile, InstanceProfile

# -- tiny analytical fixtures (pure estimator math, no JAX compute) ----------

TINY = uniform_decoder("des-4l", 4, 2048, 16, 16, 8192, 32000)


def _inst(name: str, mem_gb: float = 24.0, tflops: float = 100.0,
          price: float = 2.0) -> InstanceProfile:
    dev = DeviceProfile(f"{name}-dev", mem_gb, tflops * 1e12, 800e9,
                        5e-6, 32e9)
    return InstanceProfile(name, dev, 1, 5e-5, 25e9 / 8,
                           price, price * 0.35, name)


def _single(spec, inst) -> Placement:
    return Placement(
        spec, (Stage(inst, 1, spec.n_layers, first=True, last=True),))


NODE = _inst("des-node")
PL = _single(TINY, NODE)


# -- event core ---------------------------------------------------------------

def test_event_queue_orders_by_time_then_fifo():
    q = EventQueue()
    a, b, c = Wake(0), Wake(1), Wake(2)
    q.push(2.0, a)
    q.push(1.0, b)
    q.push(1.0, c)          # same time as b: FIFO tie-break
    assert len(q) == 3 and q.peek_time() == 1.0
    assert q.pop() == (1.0, b)
    assert q.pop() == (1.0, c)
    assert q.pop() == (2.0, a)
    assert not q


def test_dispatch_routes_by_type_and_respects_until():
    q = EventQueue()
    seen = []
    q.push(1.0, Arrive("r"))
    q.push(2.0, Interrupt("pool", 2))
    q.push(50.0, Wake(7))           # beyond the horizon: never handled
    handlers = {
        Arrive: lambda t, e: seen.append(("arrive", t, e.req)),
        Interrupt: lambda t, e: seen.append(("int", t, e.pool, e.count)),
        Wake: lambda t, e: seen.append(("wake", t)),
    }
    t_last = dispatch(q, handlers, until=10.0)
    assert seen == [("arrive", 1.0, "r"), ("int", 2.0, "pool", 2)]
    assert t_last == 2.0


def test_dispatch_raises_on_missing_handler():
    q = EventQueue()
    q.push(0.0, Wake(0))
    with pytest.raises(KeyError):
        dispatch(q, {})


# -- network links ------------------------------------------------------------

def test_link_serializes_and_accounts_wait():
    ln = NetworkLink("l", bw_bps=100.0, latency_s=1.0)
    t1 = ln.submit(0.0, "a", 200.0)       # 1 + 2 = 3s
    t2 = ln.submit(0.0, "b", 100.0)       # queued behind t1
    assert (t1.start_s, t1.end_s) == (0.0, 3.0)
    assert (t2.start_s, t2.end_s) == (3.0, 5.0)
    assert t2.wait_s == 3.0
    assert ln.busy_until == 5.0 and ln.queue_wait_s(1.0) == 4.0
    assert ln.n_transfers == 2 and ln.total_bytes == 300.0
    assert ln.wait_s == 3.0
    # idle gap: a late submit starts immediately
    t3 = ln.submit(10.0, "a", 100.0)
    assert t3.start_s == 10.0 and t3.wait_s == 0.0
    assert ln.by_kind == {"a": 2, "b": 1}


def test_bytes_for_duration_inverts_service_curve():
    ln = NetworkLink("l", bw_bps=3.125e9, latency_s=0.05)
    for d in (0.5, 61.85, 120.0):
        assert ln.duration_s(ln.bytes_for_duration(d)) == pytest.approx(
            d, abs=1e-12)
    assert ln.bytes_for_duration(0.01) == 0.0     # below latency floor


def test_topology_links_are_shared_per_region():
    topo = Topology({"us": LinkSpec(1e9, 0.1)})
    assert topo.store_link("us") is topo.store_link("us")
    assert topo.store_link("us").bw_bps == 1e9
    assert topo.store_link("eu") is not topo.store_link("us")
    assert topo.cross_link("us", "eu") is topo.cross_link("eu", "us")
    assert len(topo.links()) == 3
    topo.store_link("us").submit(0.0, "warmup", 1e9)
    assert topo.stats()["store:us"]["n"] == 1


# -- closed-form parity (uncontended limit) -----------------------------------

@pytest.fixture(scope="module")
def cluster():
    cfg = get_config("qwen3-32b")
    spec = cfg.to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232, beam_k=1)
    assert len(plan.pipelines) >= 2
    return spec, plan


PARITY_FTS = {
    "no_events": (FTConfig(), False),
    "shunt": (FTConfig(), True),
    "no_migration": (FTConfig(request_migration=False), True),
    "no_ci": (FTConfig(concurrent_init=False), True),
    "nohandle": (FTConfig(request_migration=False,
                          concurrent_init=False), True),
    "hybrid_kv": (FTConfig(recovery_policy="hybrid",
                           kv_store_migration=True), True),
    "transfer": (FTConfig(recovery_policy="transfer"), True),
    "kv_pool": (FTConfig(kv_pool_tokens=30_000), True),
    "short_grace": (FTConfig(grace_period_s=30.0), True),
    "prefix_warm": (FTConfig(prefix_warm_bytes=1e9), True),
}


@pytest.mark.parametrize("name", sorted(PARITY_FTS))
def test_des_matches_closed_form_uncontended(cluster, name):
    """With an idle topology the DES timeline must reproduce the legacy
    closed-form metrics to float precision on every scenario shape the
    old simulator tests cover — transfers are calibrated so an
    uncontended link IS the constant the closed form charges."""
    spec, plan = cluster
    ft, with_events = PARITY_FTS[name]
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(120.0, pool, -1), (300.0, pool, -1)] if with_events else ()
    reqs = azure_conversation_like(duration_s=600.0, rate_rps=3.0, seed=3)
    base = ClusterSim(spec, plan.pipelines, ft).run(
        reqs, 600.0, events=events)
    des = ClusterSim(spec, plan.pipelines, ft, network=Topology()).run(
        reqs, 600.0, events=events)
    assert des.rps == pytest.approx(base.rps, abs=1e-6)
    assert des.total_downtime_s == pytest.approx(base.total_downtime_s,
                                                 abs=1e-6)
    assert des.cost_usd == pytest.approx(base.cost_usd, abs=1e-6)
    assert len(des.completed) == len(base.completed)
    assert des.interruptions == base.interruptions
    assert des.kv_preemptions == base.kv_preemptions
    for kind in ("ttft", "tpot", "e2e"):
        if base.latencies(kind):
            assert des.mean(kind) == pytest.approx(base.mean(kind),
                                                   abs=1e-6)
    if with_events:
        assert des.interruptions > 0
        assert des.transfers > 0          # warm-ups actually rode the link


# -- link contention ----------------------------------------------------------

def _contention_ft():
    return FTConfig(grace_period_s=30.0, node_provision_s=40.0,
                    store_load_s=60.0, engine_init_s=30.0)


def test_simultaneous_warmups_contend_on_store_link():
    """Two pipelines reclaimed in the same region at the same instant:
    the closed form prices both warm-ups at store_load_s, but on one
    store link they serialize — the second replacement revives later and
    total downtime grows measurably (the §5 effect the refactor adds)."""
    ft = _contention_ft()
    reqs = azure_conversation_like(duration_s=400.0, rate_rps=0.5, seed=0)
    events = [(100.0, NODE.name, -2)]
    base = ClusterSim(TINY, [PL, PL], ft).run(reqs, 400.0, events=events)
    des = ClusterSim(TINY, [PL, PL], ft, network=Topology()).run(
        reqs, 400.0, events=events)
    assert base.interruptions == des.interruptions == 2
    ratio = des.total_downtime_s / base.total_downtime_s
    assert ratio >= 1.1, f"contention ratio {ratio:.3f}"
    # the queued warm-up is charged its real wait on the shared link
    assert des.link_stats["store:local"]["wait_s"] > 0.0
    # closed form: 2 x (provision + store_load - grace) = 140s; DES: the
    # second warm-up starts when the first finishes -> +60s exactly
    assert base.total_downtime_s == pytest.approx(140.0, abs=1e-6)
    assert des.total_downtime_s == pytest.approx(200.0, abs=1e-6)


def test_single_warmup_uncontended_no_penalty():
    """One interruption on the same topology: nothing contends, DES ==
    closed form (the contention test's control arm)."""
    ft = _contention_ft()
    reqs = azure_conversation_like(duration_s=400.0, rate_rps=0.5, seed=0)
    events = [(100.0, NODE.name, -1)]
    base = ClusterSim(TINY, [PL, PL], ft).run(reqs, 400.0, events=events)
    des = ClusterSim(TINY, [PL, PL], ft, network=Topology()).run(
        reqs, 400.0, events=events)
    assert des.total_downtime_s == pytest.approx(base.total_downtime_s,
                                                 abs=1e-6)


# -- multi-region pools + correlated preemptions ------------------------------

def _regions(crunch=0.02):
    pools = {"des-node": dataclasses.replace(
        PAPER_POOLS["g6.12xlarge"], name="des-node", capacity=20)}
    return [RegionSpec("us", pools, crunch_per_min=crunch),
            RegionSpec("eu", pools, crunch_per_min=crunch)]


def test_multi_region_trace_namespaced_and_deterministic():
    regs = _regions()
    tr1 = generate_multi_region_trace(regs, minutes=300, seed=5)
    tr2 = generate_multi_region_trace(regs, minutes=300, seed=5)
    assert set(tr1.counts) == {"us/des-node", "eu/des-node"}
    for k in tr1.counts:
        assert (tr1.counts[k] == tr2.counts[k]).all()
        assert tr1.counts[k].min() >= 0
        assert tr1.counts[k].max() <= 20
    # adding a region never perturbs existing ones (independent streams)
    tr3 = generate_multi_region_trace(regs + [RegionSpec("ap",
                                                         regs[0].pools)],
                                      minutes=300, seed=5)
    assert (tr3.counts["us/des-node"] == tr1.counts["us/des-node"]).all()


def test_region_crunch_produces_correlated_interruptions():
    pools = {n: dataclasses.replace(pm, capacity=pm.capacity * 8)
             for n, pm in scaled_pools(1).items()}
    regs = [RegionSpec("us", pools, crunch_per_min=0.05),
            RegionSpec("eu", pools, crunch_per_min=0.05)]
    tr = generate_multi_region_trace(regs, minutes=400, seed=2)
    ev = tr.events()
    n_corr = correlated_interruption_count(ev)
    assert n_corr >= 50
    # no-crunch control: far fewer simultaneous multi-pool drops
    calm = generate_multi_region_trace(
        [RegionSpec(r.name, r.pools) for r in regs], minutes=400, seed=2)
    assert correlated_interruption_count(calm.events()) < n_corr


def test_region_scoped_events_hit_only_that_region():
    ft = _contention_ft()
    reqs = azure_conversation_like(duration_s=300.0, rate_rps=0.5, seed=1)
    sim = ClusterSim(TINY, [PL, PL], ft, network=Topology(),
                     regions=["us", "eu"])
    res = sim.run(reqs, 300.0, events=[(100.0, "us/des-node", -2)])
    assert res.interruptions == 1           # only the us pipeline matches
    assert list(res.downtime_s) == [0]
    # bare pool names keep matching any region (legacy traces)
    sim2 = ClusterSim(TINY, [PL, PL], ft, network=Topology(),
                      regions=["us", "eu"])
    res2 = sim2.run(reqs, 300.0, events=[(100.0, "des-node", -2)])
    assert res2.interruptions == 2


def test_cross_region_restore_rides_cross_link():
    """A hybrid-recovery interruption in "us" whose migrated requests
    land on the "eu" pipeline restores KV across regions: the cross link
    carries real bytes. (transfer policy pins the mechanism, and a
    bandwidth-starved device keeps requests mid-decode at the event.)"""
    slow_dev = DeviceProfile("des-slow-dev", 24.0, 1e12, 0.8e9, 5e-6, 32e9)
    slow = InstanceProfile("des-slow", slow_dev, 1, 5e-5, 25e9 / 8,
                           2.0, 0.7, "des-slow")
    pl = _single(TINY, slow)
    ft = dataclasses.replace(_contention_ft(), recovery_policy="transfer")
    reqs = azure_conversation_like(duration_s=300.0, rate_rps=2.0, seed=1)
    sim = ClusterSim(TINY, [pl, pl], ft, network=Topology(),
                     regions=["us", "eu"])
    res = sim.run(reqs, 300.0, events=[(100.0, "us/des-slow", -1)])
    assert res.interruptions == 1
    xr = res.link_stats.get("xr:eu<->us")
    assert xr is not None and xr["bytes"] > 0
    assert any(tr.kind == "kv_restore" for tr in sim.transfer_log)


def test_ondemand_pipelines_immune_and_priced_up():
    ft = _contention_ft()
    reqs = azure_conversation_like(duration_s=300.0, rate_rps=0.5, seed=1)
    mixed = ClusterSim(TINY, [PL, PL], ft, spot=[True, False])
    res_mixed = mixed.run(reqs, 300.0, events=[(50.0, NODE.name, -2)])
    assert res_mixed.interruptions == 1     # OD pipeline never reclaimed
    all_spot = ClusterSim(TINY, [PL, PL], ft)
    res_spot = all_spot.run(reqs, 300.0)
    assert res_mixed.cost_usd > res_spot.cost_usd   # OD premium on base


# -- shared estimator caches at scale ----------------------------------------

def test_replicated_placement_shares_estimator_caches():
    ft = FTConfig()
    sim = ClusterSim(TINY, [PL] * 64, ft)
    p0 = sim.pipes[0]
    assert all(p._iter_cache is p0._iter_cache for p in sim.pipes)
    assert all(p.weight == p0.weight and p.b_max == p0.b_max
               for p in sim.pipes)
    p0.t_iter(1)
    assert 1 in sim.pipes[63]._iter_cache   # one estimate serves all


# -- frontier sweep -----------------------------------------------------------

def test_frontier_sweep_grid_and_pareto():
    reqs = azure_conversation_like(duration_s=300.0, rate_rps=1.0, seed=4)
    events = [(60.0, NODE.name, -1), (150.0, NODE.name, -1)]
    seen = []
    pts = sweep_frontier(
        TINY, [PL, PL], reqs, 300.0, events=events,
        spot_fracs=(0.0, 1.0), graces=(30.0, 120.0),
        policies=("recompute", "hybrid"),
        network_factory=Topology, on_point=seen.append)
    assert len(pts) == 8 and seen == pts
    by = {(p.spot_frac, p.grace_s, p.policy): p for p in pts}
    # all-on-demand: no interruptions, higher cost than all-spot
    assert by[(0.0, 30.0, "recompute")].interruptions == 0
    assert (by[(0.0, 30.0, "recompute")].cost_usd
            > by[(1.0, 30.0, "recompute")].cost_usd)
    # spot cells actually took the hits
    assert by[(1.0, 120.0, "recompute")].interruptions == 2
    front = pareto_front(pts)
    assert front and set(front) <= set(pts)
    for f in front:
        assert not any(q.dominates(f) for q in pts)
    # every dominated point is excluded
    for p in pts:
        if any(q.dominates(p) for q in pts):
            assert p not in front


def test_diurnal_rate_profile_shapes_arrivals():
    assert diurnal_rate(0.0) == pytest.approx(1.0)
    assert diurnal_rate(21600.0) == pytest.approx(2.0)      # quarter period
    assert diurnal_rate(64800.0) == pytest.approx(0.1)      # trough floored
    flat = azure_conversation_like(duration_s=3600.0, rate_rps=4.0, seed=9)
    peak = azure_conversation_like(duration_s=3600.0, rate_rps=4.0, seed=9,
                                   rate_profile=lambda t: 2.0)
    assert len(peak) > len(flat) * 1.5


# -- 1000-node churn smoke (bench enforces the wall-clock budget) -------------

def test_thousand_node_churn_completes():
    ft = FTConfig()
    n = 1000
    regions = ["us" if i % 2 == 0 else "eu" for i in range(n)]
    sim = ClusterSim(TINY, [PL] * n, ft, network=Topology(),
                     regions=regions)
    reqs = azure_conversation_like(duration_s=120.0, rate_rps=20.0, seed=6)
    events = [(30.0 + i, ("us" if i % 2 else "eu") + "/des-node", -1)
              for i in range(60)]
    res = sim.run(reqs, 120.0, events=events)
    assert res.interruptions == 60
    assert len(res.completed) > 0
