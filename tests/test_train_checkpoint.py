"""Training substrate: optimizer, grad accumulation, checkpoint, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.models import build_model, make_batch
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw
from repro.train.train_step import (choose_microbatches, init_train_state,
                                    make_train_step)

SHAPE = ShapeSpec("t", 32, 4, "train_step")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, remat=True, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases(setup):
    cfg, model, params = setup
    state = init_train_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2, warmup_steps=1)))
    batch = make_batch(cfg, SHAPE)       # same batch => must overfit
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses


def test_grad_accum_equivalent(setup):
    """n_micro=2 (no bf16 compression) == n_micro=1 loss/update approx."""
    cfg, model, params = setup
    oc = AdamWConfig(compress_grads_bf16=False)
    batch = make_batch(cfg, SHAPE)
    s1, m1 = make_train_step(model, oc, 1)(init_train_state(params), batch)
    s2, m2 = make_train_step(model, oc, 2)(init_train_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_adamw_moments_fp32():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = init_adamw(params)
    assert st.m["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4, 4), 0.1, jnp.bfloat16)}
    # lr large enough that the update survives bf16 rounding near 1.0
    new_p, st2 = adamw_update(AdamWConfig(lr=0.1, warmup_steps=1), grads,
                              st, params)
    assert jnp.asarray(new_p["w"]).dtype == jnp.bfloat16
    assert int(st2.step) == 1
    assert not np.allclose(np.asarray(new_p["w"], np.float32), 1.0)


def test_choose_microbatches():
    # big vocab forces accumulation
    nm = choose_microbatches(256, 4096, 256128, 256)
    assert nm >= 8
    assert choose_microbatches(8, 128, 1000, 256) == 1


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params = setup
    state = init_train_state(params)
    d = str(tmp_path / "ck")
    ckpt.save_checkpoint(d, 7, state)
    assert ckpt.latest_step(d) == 7
    restored = ckpt.restore_checkpoint(d, 7, state)
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), state, restored)
    assert all(jax.tree.leaves(same))


def test_checkpoint_rotation_and_atomicity(tmp_path, setup):
    cfg, model, params = setup
    state = init_train_state(params)
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(d, s, state, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert not [x for x in os.listdir(d) if x.startswith(".tmp")]


def test_checkpoint_digest_detects_corruption(tmp_path, setup):
    cfg, model, params = setup
    state = init_train_state(params)
    d = str(tmp_path / "ck")
    path = ckpt.save_checkpoint(d, 1, state)
    # corrupt one leaf file
    victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, victim))
    arr = np.asarray(arr)
    if arr.size:
        arr = arr.copy()
        arr.flat[0] = arr.flat[0] + 1
    np.save(os.path.join(path, victim), arr)
    with pytest.raises(IOError):
        ckpt.restore_checkpoint(d, 1, state)


def test_elastic_plan_resize():
    from repro.train.elastic import ElasticState, plan_resize
    old = ElasticState(mesh=None, n_devices=256, global_batch=256)
    shape, batch = plan_resize(old, 192, model_axis=16)
    assert shape[0] * shape[1] == 192
    assert 192 % shape[1] == 0
    assert batch == 192                  # per-device batch preserved (=1)
