"""Cluster simulator + spot trace tests (the paper's §7.2 methodology)."""

import dataclasses

import numpy as np
import pytest

from repro.cluster import (ClusterSim, FTConfig, azure_conversation_like,
                           generate_trace, interruption_events_for_window,
                           select_scenario)
from repro.cluster.spot_trace import PAPER_POOLS, window_score
from repro.configs import get_config
from repro.core import populate_cluster
from repro.hw import AWS_INSTANCES, effective, paper_cluster


@pytest.fixture(scope="module")
def cluster():
    cfg = get_config("qwen3-32b")
    spec = cfg.to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232, beam_k=1)
    assert len(plan.pipelines) >= 2
    return spec, plan


def _run(spec, plan, ft, events=(), duration=600.0, rate=3.0, seed=3):
    reqs = azure_conversation_like(duration_s=duration, rate_rps=rate,
                                   seed=seed)
    sim = ClusterSim(spec, plan.pipelines, ft)
    return sim.run(reqs, duration_s=duration, events=events)


def test_no_events_completes_requests(cluster):
    spec, plan = cluster
    res = _run(spec, plan, FTConfig(use_spot=True))
    assert res.rps > 0.5
    assert res.mean("ttft") > 0
    assert res.mean("tpot") > 0


def test_ft_config_ordering(cluster):
    """Paper Fig 13: OnDemand >= ShuntServe(RM+CI) >= CI >= RM >= NoHandle
    under interruptions (allowing small simulation noise)."""
    spec, plan = cluster
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(120.0, pool, -1), (300.0, pool, -1)]
    variants = {
        "ondemand": (FTConfig(use_spot=False), ()),
        "shunt": (FTConfig(), events),
        "ci": (FTConfig(request_migration=False), events),
        "rm": (FTConfig(concurrent_init=False), events),
        "nohandle": (FTConfig(request_migration=False,
                              concurrent_init=False), events),
    }
    res = {k: _run(spec, plan, ft, ev, rate=8.0) for k, (ft, ev) in
           variants.items()}                      # rate saturates the plan
    rps = {k: r.rps for k, r in res.items()}
    assert rps["ondemand"] >= rps["shunt"] * 0.95
    assert rps["shunt"] >= rps["nohandle"] * 0.99
    assert rps["ci"] >= rps["nohandle"] * 0.99
    assert rps["rm"] >= rps["nohandle"] * 0.98
    # structural: CI strictly reduces downtime vs the non-CI variants
    assert (sum(res["shunt"].downtime_s.values())
            <= sum(res["nohandle"].downtime_s.values()) + 1e-9)


def test_downtime_ci_vs_plain(cluster):
    spec, plan = cluster
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(100.0, pool, -1)]
    r_ci = _run(spec, plan, FTConfig(), events)
    r_pl = _run(spec, plan, FTConfig(concurrent_init=False), events)
    assert sum(r_ci.downtime_s.values()) < sum(r_pl.downtime_s.values())
    assert r_ci.interruptions == r_pl.interruptions == 1


def test_spot_cost_below_ondemand(cluster):
    spec, plan = cluster
    r_spot = _run(spec, plan, FTConfig())
    r_od = _run(spec, plan, FTConfig(use_spot=False))
    assert r_spot.cost_usd < r_od.cost_usd * 0.6   # ~65% discount configured


def test_migration_preserves_progress_counter(cluster):
    spec, plan = cluster
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(60.0, pool, -1)]
    res = _run(spec, plan, FTConfig(), events, duration=400.0)
    migrated = [r for r in res.completed + res.unfinished
                if r.migrations > 0]
    assert migrated, "interruption should affect at least one request"


# ---- spot traces ------------------------------------------------------------

def test_trace_generation_stationary():
    trace = generate_trace(PAPER_POOLS, minutes=2000, seed=0)
    # scarce pools are mostly empty; mid-tier mostly available
    assert np.mean(trace.counts["p6.48xlarge"]) < 0.2
    g6 = trace.counts["g6.12xlarge"]
    assert np.mean(g6 > 0) > 0.8


def test_scenario_selection_worst_window():
    trace = generate_trace(PAPER_POOLS, minutes=2000, seed=1)
    start, score, zero_frac = select_scenario(trace, dur_min=50)
    assert score >= window_score(trace, 0, 50)
    assert 0.0 <= zero_frac < 1.0
    events = interruption_events_for_window(trace, start, 50)
    assert any(d < 0 for _, _, d in events)


def test_workload_statistics():
    reqs = azure_conversation_like(duration_s=3600, rate_rps=4.67, seed=0)
    rate = len(reqs) / 3600.0
    mean_in = np.mean([r.s_in for r in reqs])
    mean_out = np.mean([r.s_out for r in reqs])
    assert 3.5 < rate < 6.0
    assert 500 < mean_in < 1100        # clipping pulls below 763 target
    assert 150 < mean_out < 330
    assert max(r.s_in for r in reqs) <= 2048
