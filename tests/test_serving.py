"""Serving runtime: engine continuous batching, tensor store, migration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (Engine, FTTimes, GlobalServer, ServeRequest,
                           TensorStore)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    m = build_model(cfg, remat=False, attn_chunk=0)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, params


def gen_solo(cfg, params, prompt, n):
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    r = ServeRequest(prompt=list(prompt), max_new_tokens=n)
    eng.admit(r)
    eng.drain()
    return list(r.generated)


def test_engine_generates(setup):
    cfg, params = setup
    out = gen_solo(cfg, params, [1, 2, 3], 8)
    assert len(out) == 8
    assert all(0 <= t < cfg.vocab for t in out)


def test_continuous_batching_exactness(setup):
    """Requests admitted at different times produce the same tokens as
    solo runs — per-slot positions and cache isolation are correct."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=3, max_len=64)
    rs = [ServeRequest(prompt=[i + 1, i + 2, i + 3], max_new_tokens=6 + i)
          for i in range(3)]
    eng.admit(rs[0])
    eng.step()
    eng.admit(rs[1])
    eng.step()
    eng.admit(rs[2])
    eng.drain()
    for r in rs:
        assert list(r.generated) == gen_solo(cfg, params, r.prompt,
                                             r.max_new_tokens), r.rid


def test_migration_preserves_generated_output(setup):
    """Paper §5.1: tokens generated before the interruption are preserved
    verbatim, and the continuation equals a fresh run prefilled with the
    same full context (recomputation semantics)."""
    cfg, params = setup
    prompt = [5, 17, 42, 7, 99]
    ref = gen_solo(cfg, params, prompt, 12)

    store = TensorStore()
    srv = GlobalServer(cfg, store, max_batch=2, max_len=64)
    p0 = srv.add_pipeline(params, ["inst-A", "inst-B"])
    srv.add_pipeline(params, ["inst-C"])
    r = ServeRequest(prompt=prompt, max_new_tokens=12)
    p0.queue.append(r)
    for _ in range(5):
        while p0.queue and p0.engine.free_slots():
            p0.engine.admit(p0.queue.pop(0))
        p0.engine.step()
    pre = list(r.generated)
    assert pre == ref[:len(pre)]
    srv.interrupt_instance("inst-A")
    assert not p0.alive
    assert r.migrations == 1
    assert list(r.generated)[:len(pre)] == pre          # output preserved
    srv.run_until_drained()
    assert len(r.generated) == 12
    # continuation == recompute-from-full-context reference
    eng = Engine(cfg, params, max_batch=1, max_len=64)
    r2 = ServeRequest(prompt=prompt, max_new_tokens=12)
    r2.generated = list(pre)
    eng.admit(r2)
    eng.drain()
    assert list(r.generated) == list(r2.generated)


def test_no_migration_loses_progress(setup):
    cfg, params = setup
    srv = GlobalServer(cfg, TensorStore(), use_migration=False,
                       max_batch=2, max_len=64)
    p0 = srv.add_pipeline(params, ["inst-A"])
    srv.add_pipeline(params, ["inst-B"])
    r = ServeRequest(prompt=[1, 2, 3], max_new_tokens=8)
    p0.queue.append(r)
    while p0.queue and p0.engine.free_slots():
        p0.engine.admit(p0.queue.pop(0))
    p0.engine.step()
    assert len(r.generated) >= 1
    srv.interrupt_instance("inst-A")
    assert r.generated == []          # progress lost (No-Handle baseline)


def test_concurrent_init_downtime_shorter(setup):
    cfg, params = setup
    ft = FTTimes(grace_period_s=120.0)

    def downtime(ci):
        srv = GlobalServer(cfg, TensorStore(), ft=ft,
                           use_concurrent_init=ci, max_batch=2, max_len=64)
        p = srv.add_pipeline(params, ["i0"])
        srv.interrupt_instance("i0")
        return p.down_until - srv.clock

    d_ci, d_plain = downtime(True), downtime(False)
    # paper: CI total ~111.3s < 120s grace => near-zero extra beyond grace;
    # without CI the reload happens after grace expires
    assert d_ci <= ft.grace_period_s + 1e-6
    assert d_plain > ft.grace_period_s + ft.store_load_s


def test_tensor_store_zero_copy_attach():
    store = TensorStore()
    params = {"w": jnp.ones((4, 4))}
    store.put("m", "full", params)
    a = store.attach("m", "full")
    b = store.attach("m", "full")
    assert a["w"] is b["w"] is params["w"]          # same arrays, no copy
    assert store.refcount("m", "full") == 2
    store.detach("m", "full")
    store.detach("m", "full")
    assert store.evict_unreferenced() == 1
    assert not store.contains("m", "full")


def test_tensor_store_load_once():
    loads = []
    store = TensorStore(load_time_model=lambda n: n * 1e-9)
    def loader():
        loads.append(1)
        return {"w": jnp.ones((8, 8), jnp.float32)}
    _, t1 = store.load("m", "p0", loader)
    _, t2 = store.load("m", "p0", loader)
    assert len(loads) == 1            # second load is an attach
    assert t1 > 0 and t2 == 0.0


def test_weighted_round_robin(setup):
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=4, max_len=64)
    p0 = srv.add_pipeline(params, ["a"], weight=3.0)
    p1 = srv.add_pipeline(params, ["b"], weight=1.0)
    for i in range(40):
        srv.submit(ServeRequest(prompt=[1], max_new_tokens=1))
    assert len(p0.queue) == 30 and len(p1.queue) == 10
