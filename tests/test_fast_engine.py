"""Pins the prefix-sum evaluation engine (repro.core.eval_engine) to the
reference estimator (repro.core.estimator) — equivalence over a matrix of
(model family x inventory x beam width) plus a search wall-clock bound."""

import dataclasses
import time

import pytest

from repro.core.estimator import Placement, Stage, estimate
from repro.core.eval_engine import FastEstimator
from repro.core.modelspec import uniform_decoder
from repro.core.objective import Objective
from repro.core.placement import PlacementOptimizer, exhaustive_search
from repro.hw.profiles import AWS_INSTANCES, effective, paper_cluster

REL = 1e-6


def _specs():
    out = [("tiny-dense", uniform_decoder("tiny", 6, 256, 4, 2, 512, 1000)),
           ("tiny-swa", uniform_decoder("swa", 6, 256, 4, 2, 512, 1000,
                                        window=64)),
           ("tiny-moe", uniform_decoder("moe", 6, 256, 4, 2, 128, 1000,
                                        n_experts=8, top_k=2))]
    from repro.configs import get_config
    for arch in ("qwen3-32b", "mamba2-1.3b", "zamba2-2.7b", "whisper-tiny"):
        out.append((arch, get_config(arch).to_modelspec()))
    return out


def _mark(stages):
    return tuple(
        dataclasses.replace(s, first=(i == 0), last=(i == len(stages) - 1))
        for i, s in enumerate(stages))


@pytest.mark.parametrize("name,spec", _specs())
def test_estimate_equivalence(name, spec):
    """FastEstimator.estimate == estimator.estimate on multi-stage
    placements across every layer family (dense, SWA, MoE, SSM, hybrid,
    encoder-decoder)."""
    insts = AWS_INSTANCES
    eng = FastEstimator(spec, 256, 64)
    n = spec.n_layers
    cases = [
        (Stage(insts["g6e.xlarge"], 1, n),),
        (Stage(insts["g6.12xlarge"], 4, n // 2),
         Stage(insts["g6e.xlarge"], 1, n - n // 2)),
        (Stage(insts["g6.12xlarge"], 2, n // 3),
         Stage(insts["g5.12xlarge"], 1, n // 3),
         Stage(insts["g6e.xlarge"], 1, n - 2 * (n // 3))),
    ]
    for stages in cases:
        p = Placement(spec, _mark(list(stages)))
        ref = estimate(spec, p, 256, 64)
        fast = eng.estimate(p)
        assert fast.batch == ref.batch, (name, p.describe())
        if ref.batch <= 0:
            continue
        assert fast.throughput_rps == pytest.approx(ref.throughput_rps,
                                                    rel=REL)
        assert fast.ttft_s == pytest.approx(ref.ttft_s, rel=REL)
        assert fast.tpot_s == pytest.approx(ref.tpot_s, rel=REL)
        assert fast.e2e_latency_s == pytest.approx(ref.e2e_latency_s,
                                                   rel=REL)
        for a, b in zip(fast.prefill_stage_s, ref.prefill_stage_s):
            assert a == pytest.approx(b, rel=REL)
        for a, b in zip(fast.decode_stage_s, ref.decode_stage_s):
            assert a == pytest.approx(b, rel=REL)


SEARCH_MATRIX = [
    # (spec builder args, inventory, beam_k)
    ((6, 256, 4, 2, 512, 1000), {"g6e.xlarge": 2, "g6.12xlarge": 1}, 1),
    ((6, 256, 4, 2, 512, 1000), {"g6e.xlarge": 2, "g6.12xlarge": 1}, 3),
    ((8, 512, 8, 4, 2048, 32000), {"g6.12xlarge": 2, "g5.12xlarge": 1}, 3),
    ((8, 512, 8, 4, 2048, 32000), {"g6e.xlarge": 3}, 2),
]


@pytest.mark.parametrize("args,inv,k", SEARCH_MATRIX)
def test_search_equivalence_with_reference(args, inv, k):
    """With dominance pruning off, the fast DP explores the same beams as
    the seed estimate()-based scorer: same placement, or equal score."""
    spec = uniform_decoder("m", *args)
    common = dict(objective=Objective(), beam_k=k, max_stages=3)
    ref = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                             use_fast=False, **common).search()
    fast = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                              prune_dominated=False, **common).search()
    assert (fast.placement is None) == (ref.placement is None)
    if ref.placement is None:
        return
    same = fast.placement.describe() == ref.placement.describe()
    assert same or fast.score == pytest.approx(ref.score, rel=REL), (
        fast.placement.describe(), ref.placement.describe(),
        fast.score, ref.score)


@pytest.mark.parametrize("args,inv,k", SEARCH_MATRIX)
def test_dominance_pruning_no_worse(args, inv, k):
    """Dominance pruning is a heuristic: dropping (score, inventory)-
    dominated candidates frees beam slots for genuinely different ones, so
    the found score must stay within a whisker of the unpruned search (in
    practice it matches or improves)."""
    spec = uniform_decoder("m", *args)
    common = dict(beam_k=k, max_stages=3)
    plain = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                               prune_dominated=False, **common).search()
    pruned = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                                prune_dominated=True, **common).search()
    assert pruned.score >= plain.score * 0.98


def test_pruning_keeps_recoverable_zero_score_partials():
    """Regression: on a memory-tight cluster every 2-stage prefix scores 0
    while the LM head sits on its (overfull) last stage, but becomes
    feasible once the head migrates to a later stage.  Dominance pruning
    must not let a permanently-infeasible zero-score partial (m_nonlast
    == 0, fewer devices) evict the recoverable one (m_nonlast > 0)."""
    inst = AWS_INSTANCES["g6.12xlarge"]
    tight = dataclasses.replace(
        inst, device=dataclasses.replace(inst.device, mem_gb=4))
    insts = {"g6.12xlarge": tight}
    spec = uniform_decoder("m", 4, 8192, 32, 8, 32768, 500000)
    inv = {"g6.12xlarge": 2}
    common = dict(beam_k=3, max_stages=4)
    ref = PlacementOptimizer(spec, inv, insts, 32, 8, use_fast=False,
                             **common).search()
    pruned = PlacementOptimizer(spec, inv, insts, 32, 8,
                                prune_dominated=True, **common).search()
    assert ref.placement is not None
    assert pruned.placement is not None
    assert pruned.score == pytest.approx(ref.score, rel=REL)


def test_exhaustive_matches_reference_scoring():
    """exhaustive_search now scores through the engine; its optimum must
    match a reference-scored brute force on a tiny problem."""
    spec = uniform_decoder("tiny", 4, 256, 4, 2, 512, 1000)
    inv = {"g6e.xlarge": 2, "g6.12xlarge": 1}
    obj = Objective()
    ex = exhaustive_search(spec, inv, AWS_INSTANCES, 128, 32, obj,
                           max_stages=3)
    assert ex.placement is not None
    # re-score the winner with the reference path
    ref_score = obj.score(ex.placement,
                          estimate(spec, ex.placement, 128, 32))
    assert ex.score == pytest.approx(ref_score, rel=REL)


def test_custom_objective_falls_back_to_reference():
    class Doubled(Objective):
        def score(self, placement, perf):
            return 2.0 * super().score(placement, perf)

    spec = uniform_decoder("tiny", 4, 256, 4, 2, 512, 1000)
    inv = {"g6e.xlarge": 2}
    opt = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                             objective=Doubled())
    assert not opt.use_fast          # subclass => reference scoring
    res = opt.search()
    assert res.placement is not None


HIST_MATRIX = [
    ((6, 256, 4, 2, 512, 1000), {"g6e.xlarge": 2, "g6.12xlarge": 1}, 2),
    ((8, 512, 8, 4, 2048, 32000), {"g6.12xlarge": 2, "g5.12xlarge": 1}, 3),
    ((8, 512, 8, 4, 2048, 32000), {"g6e.xlarge": 3}, 2),
]


@pytest.mark.parametrize("args,inv,k", HIST_MATRIX)
def test_histogram_objective_search_equivalence(args, inv, k):
    """HistogramCostObjective rides the fast DP path — the incremental
    composition replayed per traffic bucket against that bucket's tables —
    and must land on the reference scorer's search optimum.  Dominance
    pruning is left at its default: histogram mode bypasses it
    internally, so this also pins that bypass."""
    from repro.core.buckets import (HistogramCostObjective,
                                    workload_histogram)
    spec = uniform_decoder("m", *args)
    hist = workload_histogram(
        [(100, 50)] * 6 + [(700, 200)] * 3 + [(1800, 900)])
    obj = HistogramCostObjective(hist)
    common = dict(objective=obj, beam_k=k, max_stages=3)
    ref = PlacementOptimizer(spec, inv, AWS_INSTANCES, 763, 232,
                             use_fast=False, **common).search()
    fast_opt = PlacementOptimizer(spec, inv, AWS_INSTANCES, 763, 232,
                                  **common)
    assert fast_opt.use_fast            # histogram no longer falls back
    fast = fast_opt.search()
    assert (fast.placement is None) == (ref.placement is None)
    if ref.placement is None:
        return
    assert fast.score == pytest.approx(ref.score, rel=REL), (
        fast.placement.describe(), ref.placement.describe())
    # the fast score must be the histogram scorer's own number for the
    # winning placement, not merely close to the reference search's
    rescored = obj.score(fast.placement,
                         estimate(spec, fast.placement, 763, 232))
    assert fast.score == pytest.approx(rescored, rel=REL)


def test_slo_objective_equivalence():
    """Eq. 7 with a soft SLO penalty goes through the fast path too."""
    spec = uniform_decoder("m", 8, 512, 8, 4, 2048, 32000)
    inv = {"g6e.xlarge": 2, "g6.12xlarge": 1}
    obj = Objective(gamma=0.5, slo_s=0.05)
    common = dict(objective=obj, beam_k=2, max_stages=3)
    ref = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                             use_fast=False, **common).search()
    fast = PlacementOptimizer(spec, inv, AWS_INSTANCES, 128, 32,
                              prune_dominated=False, **common).search()
    assert fast.score == pytest.approx(ref.score, rel=REL)


def test_paper_cluster_search_wall_clock():
    """Acceptance: the paper 24-GPU cluster search (qwen3-32b,
    max_stages=6, beam_k=3) completes fast.  The seed took >120 s; the
    engine takes a few seconds — 30 s is a generous CI bound."""
    from repro.configs import get_config
    spec = get_config("qwen3-32b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    t0 = time.perf_counter()
    res = PlacementOptimizer(spec, paper_cluster(), insts, 763, 232,
                             beam_k=3, max_stages=6).search()
    wall = time.perf_counter() - t0
    assert res.placement is not None
    assert sum(s.n_layers for s in res.placement.stages) == spec.n_layers
    assert wall < 30.0, f"paper-cluster search took {wall:.1f}s"
