"""Execution-plane engine v2: shape-stable bucketed admission, chunked
prefill, output-preserving interruption equivalence (paper §5.1), and the
estimator-driven serving loop."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.models import build_model
from repro.serving import Engine, GlobalServer, ServeRequest, TensorStore


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    m = build_model(cfg, remat=False, attn_chunk=0)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, params


def gen_solo(cfg, params, prompt, n, **engine_kw):
    eng = Engine(cfg, params, max_batch=2, max_len=64, **engine_kw)
    r = ServeRequest(prompt=list(prompt), max_new_tokens=n)
    eng.admit(r)
    eng.drain()
    return list(r.generated)


# -- bucketed batched admission ------------------------------------------------

def test_batched_admission_matches_solo(setup):
    """A mixed-length batch admitted in one call produces exactly the
    tokens of per-request solo runs (padding + masked scatter are exact)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=8, max_len=64)
    rs = [ServeRequest(prompt=list(range(1, 4 + 3 * i)),
                       max_new_tokens=4 + i) for i in range(5)]
    admitted = eng.admit_many(rs)
    assert len(admitted) == 5
    eng.drain()
    for r in rs:
        assert list(r.generated) == gen_solo(cfg, params, r.prompt,
                                             r.max_new_tokens), r.rid


def test_retrace_count_bounded_by_buckets(setup):
    """Bucketed admission traces at most one prefill per length bucket
    across a mixed-length workload (seed: one per distinct length)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64)
    rng = np.random.RandomState(0)
    lens = [4, 7, 11, 15, 17, 23, 30, 33, 40, 47, 55, 60]
    for n in lens:
        r = ServeRequest(prompt=rng.randint(0, cfg.vocab, n).tolist(),
                         max_new_tokens=1)
        assert eng.admit(r)
        eng.drain()
    assert eng.stats.prefills == len(lens)
    assert eng.stats.prefill_retraces <= len(eng.bucket_lens())
    # the legacy path really does trace per distinct length
    leg = Engine(cfg, params, max_batch=4, max_len=64, admission="legacy")
    for n in lens[:6]:
        r = ServeRequest(prompt=rng.randint(0, cfg.vocab, n).tolist(),
                         max_new_tokens=1)
        leg.admit(r)
        leg.drain()
    assert leg.stats.prefill_retraces == 6


def test_admission_more_requests_than_slots(setup):
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    rs = [ServeRequest(prompt=[1 + i, 2, 3], max_new_tokens=3)
          for i in range(5)]
    admitted = eng.admit_many(rs)
    assert len(admitted) == 2                  # bounded by free slots
    fin = eng.drain()
    assert len(fin) == 2


def test_moe_admission_stays_exact():
    """MoE expert capacity is batch-global, so the engine must fall back
    to batch-1 exact-length admission to keep solo == batched outputs."""
    cfg = get_config("granite-moe-3b-a800m").reduced()
    m = build_model(cfg, remat=False, attn_chunk=0)
    params = m.init(jax.random.PRNGKey(1))
    eng = Engine(cfg, params, max_batch=4, max_len=64)
    assert eng._group == 1                    # capacity isolation
    rs = [ServeRequest(prompt=list(range(1, 5 + 2 * i)), max_new_tokens=3)
          for i in range(3)]
    eng.admit_many(rs)
    eng.drain()
    for r in rs:
        assert list(r.generated) == gen_solo(cfg, params, r.prompt,
                                             r.max_new_tokens), r.rid


# -- chunked prefill -----------------------------------------------------------

def test_chunked_prefill_equivalence(setup):
    """Chunk-by-chunk prefill of a long context produces byte-identical
    output to single-shot prefill."""
    cfg, params = setup
    prompt = list(range(1, 42))
    ref = gen_solo(cfg, params, prompt, 6)
    out = gen_solo(cfg, params, prompt, 6, prefill_chunk=8)
    assert out == ref


def test_chunked_prefill_interleaves_with_decode(setup):
    """While a long context prefills in chunks, live slots keep emitting
    tokens every step (bounded head-of-line blocking)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, prefill_chunk=8)
    live = ServeRequest(prompt=[3, 1, 4], max_new_tokens=20)
    eng.admit(live)
    long_req = ServeRequest(prompt=list(range(1, 41)), max_new_tokens=4)
    eng.admit(long_req)                 # becomes pending, chunked
    before = len(live.generated)
    for _ in range(3):                  # 3 chunks still pending after this
        eng.step()
    assert len(live.generated) == before + 3   # live slot never stalled
    assert eng.stats.prefill_chunks == 3
    assert not long_req.generated       # still prefilling
    eng.drain()
    assert list(long_req.generated) == gen_solo(cfg, params,
                                                long_req.prompt, 4)
    assert list(live.generated) == gen_solo(cfg, params, live.prompt, 20)


# -- interruption equivalence (paper §5.1, end-to-end) -------------------------

def _serve(cfg, params, interrupt_round, prompts, n_new, **server_kw):
    srv = GlobalServer(cfg, TensorStore(), max_batch=2, max_len=64,
                       **server_kw)
    srv.add_pipeline(params, ["inst-A", "inst-B"])
    srv.add_pipeline(params, ["inst-C"])
    reqs = [ServeRequest(prompt=list(p), max_new_tokens=n_new)
            for p in prompts]
    for r in reqs:
        srv.submit(r)
    rounds = 0
    while srv.pending() and rounds < 10_000:
        if rounds == interrupt_round:
            srv.interrupt_instance("inst-A")
        srv.step()
        srv.tick()
        rounds += 1
    return reqs


def test_interruption_equivalence_greedy(setup):
    """§5.1 core claim, end-to-end: with greedy sampling a run with a
    mid-stream interruption produces byte-identical token sequences to an
    uninterrupted run."""
    cfg, params = setup
    prompts = [[5, 17, 42, 7, 99], [1, 2, 3], [9, 8, 7, 6], [4, 4, 4]]
    ref = _serve(cfg, params, interrupt_round=-1, prompts=prompts, n_new=12)
    out = _serve(cfg, params, interrupt_round=4, prompts=prompts, n_new=12)
    assert sum(r.migrations for r in out) >= 1
    for r_ref, r_out in zip(ref, out):
        assert r_out.done
        assert list(r_out.generated) == list(r_ref.generated)


def test_interruption_equivalence_with_chunked_recompute(setup):
    """Same equivalence when migration recompute runs through the chunked
    prefill path."""
    cfg, params = setup
    prompts = [[5, 17, 42, 7, 99, 3, 1, 2, 8, 11], [1, 2, 3, 4, 5, 6]]
    ref = _serve(cfg, params, interrupt_round=-1, prompts=prompts, n_new=14)
    out = _serve(cfg, params, interrupt_round=6, prompts=prompts, n_new=14,
                 prefill_chunk=4)
    assert sum(r.migrations for r in out) >= 1
    for r_ref, r_out in zip(ref, out):
        assert r_out.done
        assert list(r_out.generated) == list(r_ref.generated)


def test_single_pipeline_interruption_requeues(setup):
    """Regression (seed bug): interrupting the ONLY pipeline must requeue
    in-flight requests on that pipeline's own queue — submit() returning
    None silently dropped every one of them."""
    cfg, params = setup
    srv = GlobalServer(cfg, TensorStore(), max_batch=2, max_len=64)
    p0 = srv.add_pipeline(params, ["solo-inst"])
    reqs = [ServeRequest(prompt=[2 + i, 3, 5], max_new_tokens=6)
            for i in range(2)]
    for r in reqs:
        srv.submit(r)
    srv.step()
    affected = srv.interrupt_instance("solo-inst")
    assert len(affected) == 2
    assert len(p0.queue) == 2              # requeued, not dropped
    # no manual clock warp: tick() fast-forwards past the grace period
    # when nothing is alive, so draining just works
    srv.run_until_drained()
    for r in reqs:
        assert r.done
        assert len(r.generated) == 6


# -- pallas kernel routing -----------------------------------------------------

def test_engine_use_pallas_matches_reference(setup):
    """use_pallas routes decode/flash kernels (interpret mode on CPU);
    greedy tokens must match the pure-jnp engine."""
    cfg, params = setup
    prompt = [3, 14, 15, 9, 2]
    ref = gen_solo(cfg, params, prompt, 4)
    out = gen_solo(cfg, params, prompt, 4, use_pallas=True)
    assert out == ref


# -- estimator-driven serving loop ---------------------------------------------

def test_estimator_driven_weights_and_clock(setup):
    cfg, params = setup
    spec = get_config("llama-3.1-70b").to_modelspec()
    from repro.core import populate_cluster
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232,
                            beam_k=1, max_pipelines=2)
    assert plan.pipelines
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64)
    pipes = [srv.add_pipeline(params, [f"i{i}"], placement=pl)
             for i, pl in enumerate(plan.pipelines[:2])]
    for p in pipes:
        assert p.weight > 0                      # estimator rps, not 1.0
        assert p.round_s != 0.01                 # estimator decode latency
        assert p.round_s > 0
    t0 = srv.clock
    srv.step()
    srv.tick()
    assert srv.clock - t0 == pytest.approx(max(p.round_s for p in pipes))
    # faster placements get proportionally more dispatch credit
    if len(pipes) == 2 and pipes[0].weight != pipes[1].weight:
        for _ in range(20):
            srv.submit(ServeRequest(prompt=[1], max_new_tokens=1))
        q0, q1 = len(pipes[0].queue), len(pipes[1].queue)
        heavier = 0 if pipes[0].weight > pipes[1].weight else 1
        assert (q0, q1)[heavier] >= (q0, q1)[1 - heavier]


def test_default_round_s_without_placement(setup):
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64)
    p = srv.add_pipeline(params, ["a"])
    assert p.weight == 1.0 and p.round_s == 0.01
