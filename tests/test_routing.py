"""Length-aware, cost-aware heterogeneous routing: bucket throughput
tables, the $/token placement objective, bucket-aware dispatch, and
hot-prefix pinning in the tensor store."""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (BucketEstimator, FastEstimator,
                        HistogramCostObjective, LengthBuckets, Objective,
                        Placement, PlacementOptimizer, Stage, bucket_table,
                        workload_histogram)
from repro.core.modelspec import uniform_decoder
from repro.hw.profiles import DeviceProfile, InstanceProfile
from repro.serving import GlobalServer, ServeRequest, TensorStore

# A spec with real KV pressure: ~0.8 GB of weights, ~33 KB KV per token,
# so a 1 GB device serves the short bucket but not the long one.
SPEC = uniform_decoder("route-4l", 4, 2048, 16, 16, 8192, 32000)


def _inst(name: str, mem_gb: float, tflops: float, price: float,
          num_devices: int = 1) -> InstanceProfile:
    dev = DeviceProfile(f"{name}-dev", mem_gb, tflops * 1e12, 800e9,
                        5e-6, 32e9)
    return InstanceProfile(name, dev, num_devices, 5e-5, 25e9 / 8,
                           price, price * 0.35, name)


LOW_HBM = _inst("low-hbm", 1.0, 100, 1.0)       # long bucket infeasible
HIGH_HBM = _inst("high-hbm", 24.0, 100, 2.0)    # everything fits


def _single(spec, inst) -> Placement:
    return Placement(
        spec, (Stage(inst, 1, spec.n_layers, first=True, last=True),))


# -- bucket tables ----------------------------------------------------------

def test_bucket_table_matches_estimator():
    """Every bucket-table cell equals a direct FastEstimator.estimate at
    the bucket's representative point (same engine, no drift)."""
    bk = LengthBuckets()
    p = _single(SPEC, HIGH_HBM)
    tbl = bucket_table(p, buckets=bk)
    for bi, bo in bk.pairs():
        s_in, s_out = bk.rep(bi, bo)
        ref = FastEstimator(SPEC, s_in, s_out).estimate(p)
        want = ref.throughput_rps * s_out if ref.batch > 0 else 0.0
        assert tbl.tok_s[bi][bo] == pytest.approx(want, rel=1e-9), (bi, bo)
        if want > 0:
            assert tbl.cost_per_token(bi, bo) == pytest.approx(
                p.price_hr(spot=True) / 3600.0 / want, rel=1e-9)
        else:
            assert tbl.cost_per_token(bi, bo) == math.inf


def test_low_hbm_long_bucket_infeasible():
    """The Eq. 6 memory bound zeroes the long bucket on the low-HBM
    instance while the short bucket stays feasible — the asymmetry
    bucket-aware routing exploits."""
    tbl_low = bucket_table(_single(SPEC, LOW_HBM))
    tbl_high = bucket_table(_single(SPEC, HIGH_HBM))
    assert tbl_low.tok_s[0][0] > 0            # short/short feasible
    assert tbl_low.tok_s[-1][-1] == 0.0       # long/long infeasible
    assert tbl_high.tok_s[-1][-1] > 0


def test_workload_histogram_normalized():
    bk = LengthBuckets()
    hist = workload_histogram(
        [(100, 50)] * 3 + [(2000, 1000)] * 1, bk)
    assert hist[0][0] == pytest.approx(0.75)
    assert hist[-1][-1] == pytest.approx(0.25)
    assert sum(map(sum, hist)) == pytest.approx(1.0)


# -- $/token objective -------------------------------------------------------

CHEAP = _inst("cheap-slow", 24.0, 50, 1.0)
FAST = _inst("fast-expensive", 24.0, 500, 30.0)


def test_cost_objective_ranks_cheap_above_fast():
    """The $/token objective prefers the cheap-slow placement; the pure
    throughput objective prefers the fast-but-expensive one."""
    hist = workload_histogram([(100, 50)] * 6 + [(1500, 800)] * 4)
    cost_obj = HistogramCostObjective(hist)
    p_cheap, p_fast = _single(SPEC, CHEAP), _single(SPEC, FAST)
    assert cost_obj.score(p_cheap, None) > cost_obj.score(p_fast, None)
    assert (cost_obj.cost_per_token(p_cheap)
            < cost_obj.cost_per_token(p_fast))

    tps_obj = Objective(per_cost=False)
    est = BucketEstimator(SPEC)
    perf_cheap = est.estimator(2, 2).estimate(p_cheap)
    perf_fast = est.estimator(2, 2).estimate(p_fast)
    assert tps_obj.score(p_fast, perf_fast) > tps_obj.score(p_cheap,
                                                            perf_cheap)


def test_optimizer_picks_cheap_mix_under_cost_objective():
    """PlacementOptimizer consumes the histogram $/token objective (now on
    the fast per-bucket-table path) and answers 'which mix is cheapest':
    the cheap instance wins the whole pipeline."""
    hist = workload_histogram([(100, 50)] * 8 + [(1500, 800)] * 2)
    insts = {CHEAP.name: CHEAP, FAST.name: FAST}
    inv = {CHEAP.name: 1, FAST.name: 1}
    opt = PlacementOptimizer(SPEC, inv, insts, 763, 232,
                             objective=HistogramCostObjective(hist),
                             beam_k=2, max_stages=2)
    assert opt.use_fast                 # histogram rides the fast DP path
    res = opt.search()
    assert res.placement is not None
    used = {s.instance.name for s in res.placement.stages}
    assert used == {CHEAP.name}

    opt_t = PlacementOptimizer(SPEC, inv, insts, 763, 232,
                               objective=Objective(per_cost=False),
                               beam_k=2, max_stages=2)
    res_t = opt_t.search()
    assert FAST.name in {s.instance.name for s in res_t.placement.stages}


def test_tokens_per_req_fast_reference_equivalence():
    """Objective(tokens_per_req=...) stays on the fast DP path and matches
    the reference path exactly (PR-1 equivalence discipline)."""
    insts = {CHEAP.name: CHEAP, HIGH_HBM.name: HIGH_HBM}
    inv = {CHEAP.name: 1, HIGH_HBM.name: 1}
    obj = Objective(tokens_per_req=232.0)
    fast = PlacementOptimizer(SPEC, inv, insts, 256, 64, objective=obj,
                              beam_k=2, max_stages=2, use_fast=True,
                              prune_dominated=False)
    assert fast.use_fast
    ref = PlacementOptimizer(SPEC, inv, insts, 256, 64, objective=obj,
                             beam_k=2, max_stages=2, use_fast=False)
    rf, rr = fast.search(), ref.search()
    assert rf.score == pytest.approx(rr.score, rel=1e-6)
    assert rf.placement.describe() == rr.placement.describe()
    # tokens_per_req scales the score, never the argmax
    plain = PlacementOptimizer(SPEC, inv, insts, 256, 64,
                               objective=Objective(), beam_k=2,
                               max_stages=2).search()
    assert rf.score == pytest.approx(plain.score * 232.0, rel=1e-6)


# -- bucket-aware dispatch ---------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    from repro.models import build_model
    m = build_model(cfg, remat=False, attn_chunk=0)
    return cfg, m.init(jax.random.PRNGKey(0))


def _mk_server(cfg, params, dispatch="cost"):
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64,
                       dispatch=dispatch)
    p_low = srv.add_pipeline(params, ["low-0"],
                             placement=_single(SPEC, LOW_HBM))
    p_high = srv.add_pipeline(params, ["high-0"],
                              placement=_single(SPEC, HIGH_HBM))
    return srv, p_low, p_high


def _req(s_in, s_out):
    return ServeRequest(prompt=list(range(1, s_in + 1)),
                        max_new_tokens=s_out)


def test_dispatch_shunts_longs_to_high_hbm(setup):
    """Long-context requests all land on the high-HBM pipeline (the low
    one's long-bucket weight is zero); short requests are spread so
    neither pipeline starves."""
    cfg, params = setup
    srv, p_low, p_high = _mk_server(cfg, params, dispatch="cost")
    longs = [_req(1800, 900) for _ in range(10)]
    shorts = [_req(60, 30) for _ in range(10)]
    for r in longs + shorts:
        srv.submit(r)
    long_ids = {r.rid for r in longs}
    assert {r.rid for r in p_low.queue}.isdisjoint(long_ids)
    assert sum(r.rid in long_ids for r in p_high.queue) == len(longs)
    # shorts: both pipelines serve some (per-bucket weighted RR)
    shorts_low = sum(r.rid not in long_ids for r in p_low.queue)
    shorts_high = sum(r.rid not in long_ids for r in p_high.queue)
    assert shorts_low > 0 and shorts_high > 0
    assert shorts_low + shorts_high == len(shorts)


def test_uniform_dispatch_ignores_weights(setup):
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64,
                       dispatch="uniform")
    p0 = srv.add_pipeline(params, ["a"], weight=5.0)
    p1 = srv.add_pipeline(params, ["b"], weight=1.0)
    for _ in range(10):
        srv.submit(ServeRequest(prompt=[1], max_new_tokens=1))
    assert len(p0.queue) == len(p1.queue) == 5


def test_weighted_dispatch_unchanged(setup):
    """Legacy scalar path is byte-compatible: 3:1 weights -> 30/10."""
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64)
    p0 = srv.add_pipeline(params, ["a"], weight=3.0)
    p1 = srv.add_pipeline(params, ["b"], weight=1.0)
    for _ in range(40):
        srv.submit(ServeRequest(prompt=[1], max_new_tokens=1))
    assert len(p0.queue) == 30 and len(p1.queue) == 10


class _Tbl:
    """Stub bucket table with hand-set per-bucket weights."""

    def __init__(self, w):
        self.w = w

    def weight(self, bi, bo, policy="cost", spot=True):
        return self.w.get((bi, bo), 0.0)


def test_requeue_preserves_bucket(setup):
    """A migrated request keeps its ORIGINAL bucket assignment: its
    recompute context has grown past the input-bucket edge, and
    reclassifying would re-route it to the wrong pipeline."""
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64, dispatch="cost")
    p_victim = srv.add_pipeline(params, ["victim-0"])
    p_short = srv.add_pipeline(params, ["short-0"])
    p_mid = srv.add_pipeline(params, ["mid-0"])
    # bucket (0,0) traffic belongs on p_short; bucket (1,0) on p_mid
    p_victim.bucket_tbl = _Tbl({(0, 0): 0.1, (1, 0): 0.1})
    p_short.bucket_tbl = _Tbl({(0, 0): 100.0, (1, 0): 0.0})
    p_mid.bucket_tbl = _Tbl({(0, 0): 0.0, (1, 0): 100.0})
    # prompt 120 + max_new 60 classifies (0,0); after 40 generated tokens
    # the recompute context is 160 > the 128 input edge -> (1, 0) if
    # (wrongly) reclassified
    r = ServeRequest(prompt=list(range(1, 121)), max_new_tokens=60)
    b0 = srv.bucket_for(r)
    assert b0 == (0, 0)
    p_victim.queue.append(r)          # force-place on the victim
    r.generated = [7] * 40
    srv.interrupt_instance("victim-0")
    assert srv.bucket_for(r) == b0                    # sticky
    assert r in p_short.queue and r not in p_mid.queue


def test_dispatch_falls_back_without_placements(setup):
    """Bucket policies degrade to scalar weighted RR when no pipeline has
    a placement (no bucket tables -> scalar weights)."""
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64, dispatch="cost")
    p0 = srv.add_pipeline(params, ["a"], weight=3.0)
    p1 = srv.add_pipeline(params, ["b"], weight=1.0)
    for _ in range(40):
        srv.submit(ServeRequest(prompt=[1], max_new_tokens=1))
    assert len(p0.queue) == 30 and len(p1.queue) == 10


def test_prefix_affinity_tie_break(setup):
    """With prefix sharing on, a near-tie routes to the pipeline already
    holding the prompt's published prefix."""
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64,
                       use_prefix_share=False)   # engines plain; map stubbed
    srv.use_prefix_share = True                  # dispatch-side affinity
    p0 = srv.add_pipeline(params, ["a"], weight=1.0)
    p1 = srv.add_pipeline(params, ["b"], weight=1.0)
    run = (5, 6, 7, 8)
    srv._prefix_home[run] = {p1.pid}
    # equal weights: fresh credits would pick p0 (first max); affinity
    # flips the near-tie to the holder p1
    r = ServeRequest(prompt=[5, 6, 7, 8, 9, 10], max_new_tokens=4)
    assert srv.submit(r) is p1
    # a prompt NOT extending the run is unaffected
    r2 = ServeRequest(prompt=[9, 9, 9], max_new_tokens=4)
    assert srv.submit(r2) is p0


# -- hot-prefix pinning ------------------------------------------------------

def _payload(n_bytes):
    return {"w": jnp.zeros((n_bytes // 4,), jnp.float32)}


def test_store_pins_hot_prefix():
    """Budget-capped LRU skips the top-k keys by hit count: the hottest
    published prefix survives even as the LRU-stalest unreferenced key.
    Without pinning the same sequence evicts it (regression)."""
    kb = 1024
    for pin_k, survives in ((1, True), (0, False)):
        store = TensorStore(budget_bytes=3 * kb, pin_hot_k=pin_k)
        store.put("__prefix__", "hot", _payload(kb))
        for _ in range(5):
            assert store.peek("__prefix__", "hot") is not None
        assert store.hits("__prefix__", "hot") == 5
        # fresher cold keys push "hot" to the LRU-stalest position and
        # blow the budget on every insert
        for i in range(4):
            store.put("__prefix__", f"cold{i}", _payload(kb))
            store.peek("__prefix__", f"cold{i}")
        assert store.contains("__prefix__", "hot") == survives
        assert store.check_consistent()
        if survives:
            assert ("__prefix__", "hot") in store.hot_keys()
            # pinned keys are still reclaimable by full eviction
            store.evict_unreferenced()
            assert not store.contains("__prefix__", "hot")


# -- spot/on-demand pricing in dispatch ---------------------------------------

def test_pricing_mode_reranks_dispatch(setup):
    """Two identical placements split evenly when both are spot-billed;
    marking one ``pricing="ondemand"`` re-ranks the cost objective (its
    $/hr nearly triples) and the spot pipeline absorbs most of the load."""
    cfg, params = setup
    srv = GlobalServer(cfg, None, max_batch=2, max_len=64, dispatch="cost")
    p_spot = srv.add_pipeline(params, ["s-0"],
                              placement=_single(SPEC, HIGH_HBM))
    p_od = srv.add_pipeline(params, ["o-0"],
                            placement=_single(SPEC, HIGH_HBM),
                            pricing="ondemand")
    assert p_spot.pricing == "spot" and p_od.pricing == "ondemand"
    for _ in range(20):
        srv.submit(_req(60, 30))
    # spot $0.70/hr vs OD $2.00/hr on the same table -> ~2.9x the weight
    assert len(p_spot.queue) > 2 * len(p_od.queue)
    assert len(p_od.queue) > 0                 # weighted RR, not starvation

    # control: both spot -> even split
    srv2 = GlobalServer(cfg, None, max_batch=2, max_len=64, dispatch="cost")
    q0 = srv2.add_pipeline(params, ["a-0"],
                           placement=_single(SPEC, HIGH_HBM))
    q1 = srv2.add_pipeline(params, ["b-0"],
                           placement=_single(SPEC, HIGH_HBM))
    for _ in range(20):
        srv2.submit(_req(60, 30))
    assert len(q0.queue) == len(q1.queue) == 10
