"""Prefix-sharing KV cache: refcounted copy-on-write blocks, the radix
prefix index at admission, the committed-blocks admission ledger, grow
hysteresis, free-block admission headroom, and cluster-wide prefix warm-up
through the tensor store (ISSUE 6)."""

import jax
import numpy as np
import pytest

from repro.cluster.workload import zipf_shared_prompts
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, GlobalServer, ServeRequest, TensorStore
from repro.serving.kv_blocks import BlockManager
from repro.serving.prefix_index import PrefixIndex


def _params_for(cfg):
    m = build_model(cfg, remat=False, attn_chunk=0)
    return m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    return cfg, _params_for(cfg)


def _drain(eng, reqs, rounds=500):
    queue = list(reqs)
    for _ in range(rounds):
        if not (queue or eng.active() or eng._pending or eng._preempted):
            break
        if queue:
            adm = eng.admit_many(queue)
            taken = {id(r) for r in adm}
            queue = [r for r in queue if id(r) not in taken]
        eng.step()
        # recompute path for anything the pool preempted (no server here)
        for req, _ in eng.take_preempted():
            queue.insert(0, req)
    assert all(r.done for r in reqs)


# -- block manager: refcounts, sharing, COW ------------------------------------

def test_refcount_share_and_free():
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=4,
                      max_blocks_per_slot=6)
    assert bm.reserve(0, 12)                       # donor: 3 blocks
    donor = bm.slot_blocks(0)
    assert all(bm.refcount[b] == 1 for b in donor)
    # sharer maps the donor's first two blocks read-only + 1 fresh
    assert bm.reserve(1, 12, shared=donor[:2])
    assert all(bm.refcount[b] == 2 for b in donor[:2])
    assert bm.slot_blocks(1)[:2] == donor[:2]
    assert bm.shared_blocks(1) == 2
    assert bm.blocks_in_use() == 4                 # unique blocks, not 6
    assert bm.check_no_leak()
    # the donor finishing must NOT release the shared blocks
    released = bm.free(0)
    assert released == 1                           # only its private block
    assert all(bm.refcount[b] == 1 for b in donor[:2])
    assert bm.free(1) == 3
    assert bm.blocks_in_use() == 0 and bm.blocks_free() == 8
    assert bm.check_no_leak()


def test_share_reclaims_free_list_blocks_content_intact():
    """A finished donor's blocks sit on the free list content-intact; a
    later sharer reclaims those exact ids instead of popping fresh ones."""
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=4,
                      max_blocks_per_slot=6)
    assert bm.reserve(0, 8)
    donor = bm.slot_blocks(0)
    bm.free(0)
    assert all(b in bm._free for b in donor)
    assert bm.reserve(1, 12, shared=donor)
    assert bm.slot_blocks(1)[:2] == donor          # same ids, same content
    assert all(bm.refcount[b] == 1 for b in donor)
    assert bm.check_no_leak()


def test_cow_boundary_dest_and_free_source_protection():
    bm = BlockManager(n_blocks=6, block_size=4, max_slots=4,
                      max_blocks_per_slot=5)
    assert bm.reserve(0, 6)                        # 2 blocks, 2nd partial
    full, boundary = bm.slot_blocks(0)
    bm.free(0)                                     # both -> free list
    # sharer: full block shared, boundary copy-on-written into its first
    # fresh block; the free-list-resident source must survive the pops
    assert bm.reserve(1, 10, shared=[full], boundary=boundary)
    ids = bm.slot_blocks(1)
    assert ids[0] == full
    dst = ids[1]                                   # table[slot, len(shared)]
    assert dst != boundary and boundary in bm._free
    assert bm.check_no_leak()


def test_committed_ledger_charges_shared_blocks_once():
    """Satellite: the committed-blocks gate (unique in-use + outstanding)
    equals the old sum-of-reservations without sharing, and admits MORE
    with it — shared blocks are charged once, and converting reservations
    to allocations never double-counts."""
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=8,
                      max_blocks_per_slot=8)
    assert bm.reserve(0, 16, 8)                    # 2 live + 2 outstanding
    assert bm.outstanding_blocks() == 2
    # no sharing: committed == sum of worst-case reservations (old gate)
    assert bm.committed_blocks() == bm.blocks_for(16) == 4
    assert bm.grow(0, 12)                          # reserved -> allocated
    assert bm.committed_blocks() == 4              # conversion, not growth
    donor = bm.slot_blocks(0)
    assert bm.reserve(1, 16, 12, shared=donor[:2])
    # sharer adds only its FRESH worst case (4 - 2 shared = 2)
    assert bm.committed_blocks() == 6
    # without sharing the same pair would commit 8 — the freed headroom is
    # real admission capacity at the same pool
    assert bm.check_no_leak()


# -- prefix index --------------------------------------------------------------

def test_index_match_full_partial_and_cap():
    bm = BlockManager(n_blocks=12, block_size=4, max_slots=4,
                      max_blocks_per_slot=8)
    idx = PrefixIndex(4, bm)
    toks = list(range(1, 11))                      # 2 full blocks + tail 2
    assert bm.reserve(0, len(toks))
    idx.insert(toks, bm.slot_blocks(0))
    ids = bm.slot_blocks(0)
    # full-block walk
    m = idx.match(toks[:8] + [99, 98, 97])
    assert m.n_tokens == 8 and m.full == ids[:2] and m.boundary is None
    # partial boundary tail: first tail token matches, second diverges
    m = idx.match(toks[:9] + [55, 54])
    assert m.n_tokens == 9 and m.boundary == ids[2]
    assert m.boundary_tokens == 1
    # at least one token must remain to prefill: full-prompt match capped
    m = idx.match(toks[:8])
    assert m.n_tokens == 4                         # not 8
    # idempotent: re-inserting under different blocks keeps the first entry
    assert bm.reserve(1, len(toks))
    idx.insert(toks, bm.slot_blocks(1))
    assert idx.match(toks[:8] + [99]).full == ids[:2]


def test_index_invalidation_drops_deeper_runs():
    bm = BlockManager(n_blocks=12, block_size=4, max_slots=4,
                      max_blocks_per_slot=8)
    idx = PrefixIndex(4, bm)
    toks = list(range(1, 13))                      # 3 full blocks
    assert bm.reserve(0, len(toks))
    ids = bm.slot_blocks(0)
    idx.insert(toks, ids)
    assert ids[1] in bm.indexed
    # losing block 1 must drop the depth-2 run AND the deeper depth-3 run
    # (which extends through it), but keep depth 1
    idx.invalidate_block(ids[1])
    assert idx.match(toks + [99]).n_tokens == 4
    assert ids[1] not in bm.indexed


def test_partial_lru_keeps_hot_tail_under_cap_pressure():
    """Hit-count LRU partial eviction (ISSUE 8 satellite): a repeatedly
    matched boundary tail survives a stream of one-off tails past the
    ``max_partials`` cap — the old FIFO evicted the hot tail first
    precisely because it arrived first."""
    bm = BlockManager(n_blocks=32, block_size=4, max_slots=16,
                      max_blocks_per_slot=8)
    idx = PrefixIndex(4, bm, max_partials=2)
    base = [1, 2, 3, 4]                            # one full block
    assert bm.reserve(0, 6)
    idx.insert(base + [7, 8], bm.slot_blocks(0))   # hot tail (7, 8)
    hot_bid = bm.slot_blocks(0)[1]
    for _ in range(3):                             # heat it up
        m = idx.match(base + [7, 8, 9])
        assert m.boundary == hot_bid and m.boundary_tokens == 2
    # cap pressure: four distinct one-off tails churn through the cap
    for i in range(1, 5):
        assert bm.reserve(i, 6)
        idx.insert(base + [30 + i, 40 + i], bm.slot_blocks(i))
    assert len(idx._partial[tuple(base)]) == 2     # cap still enforced
    m = idx.match(base + [7, 8, 9])                # hot tail survived
    assert m is not None and m.boundary == hot_bid and m.boundary_tokens == 2
    # duplicate re-insert counts as reuse evidence, not a new entry
    assert bm.reserve(5, 6)
    idx.insert(base + [7, 8], bm.slot_blocks(5))
    assert len(idx._partial[tuple(base)]) == 2
    assert idx.match(base + [7, 8, 9]).boundary == hot_bid


# -- engine: byte-identity, COW, survival --------------------------------------

def _share_pair(cfg, params, prompts, max_new=4, **kw):
    """Outputs for the same workload with sharing off vs on."""
    outs = []
    for share in (False, True):
        eng = Engine(cfg, params, max_batch=8, max_len=64,
                     kv_layout="paged", block_size=4, prefix_share=share,
                     **kw)
        reqs = [ServeRequest(prompt=list(p), max_new_tokens=max_new)
                for p in prompts]
        _drain(eng, reqs)
        assert eng.bm.check_no_leak()
        outs.append(([list(r.generated) for r in reqs], eng))
    return outs


def test_shared_prefix_byte_identity(setup):
    cfg, params = setup
    base = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]    # 3 full blocks
    prompts = [base + [10 + i, 20 + i] for i in range(4)]
    (ref, _), (out, eng) = _share_pair(cfg, params, prompts)
    assert out == ref
    assert eng.stats.prefix_hits == 3
    assert eng.stats.prefix_shared_tokens == 3 * 12


def test_boundary_cow_byte_identity(setup):
    """Sharers diverging INSIDE the donor's partial boundary block force a
    copy-on-write; outputs must still match the no-sharing engine."""
    cfg, params = setup
    base = [3, 1, 4, 1, 5, 9, 2, 6]                # 2 full blocks
    donor = base + [7, 7]                          # partial boundary block
    prompts = [donor] + [base + [7, 30 + i, 40 + i] for i in range(3)]
    (ref, _), (out, eng) = _share_pair(cfg, params, prompts)
    assert out == ref
    assert eng.stats.cow_copies >= 1
    # boundary sharers matched 2 full blocks + 1 boundary token
    assert eng.stats.prefix_hits == 3


def test_prefix_survives_request_completion(setup):
    """Freed blocks keep content until reallocated: a second wave sharing
    the first wave's prefix hits the index with no donor alive."""
    cfg, params = setup
    base = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8]
    eng = Engine(cfg, params, max_batch=4, max_len=64, kv_layout="paged",
                 block_size=4, prefix_share=True)
    r1 = [ServeRequest(prompt=base + [10, 11], max_new_tokens=3)]
    _drain(eng, r1)
    assert eng.bm.blocks_in_use() == 0             # wave 1 fully freed
    r2 = [ServeRequest(prompt=base + [20 + i, 21], max_new_tokens=3)
          for i in range(3)]
    _drain(eng, r2)
    assert eng.stats.prefix_hits == 3
    ref = Engine(cfg, params, max_batch=4, max_len=64, kv_layout="paged",
                 block_size=4)
    rr = [ServeRequest(prompt=list(r.prompt), max_new_tokens=3) for r in r2]
    _drain(ref, rr)
    assert [list(a.generated) for a in r2] == \
        [list(b.generated) for b in rr]


def test_seeded_share_churn_no_leak(setup):
    """Satellite: seeded admit/share/COW/preempt/finish churn on a tight
    overcommitted pool keeps every refcount invariant intact and stays
    byte-identical to the no-sharing engine."""
    cfg, params = setup
    rng = np.random.RandomState(0)
    base = [int(t) for t in rng.randint(1, cfg.vocab, size=10)]
    prompts = []
    for i in range(12):
        cut = int(rng.choice([4, 8, 10]))          # full / partial overlap
        tail = [int(t) for t in rng.randint(1, cfg.vocab, size=12 - cut)]
        prompts.append(base[:cut] + tail)
    outs = []
    for share in (False, True):
        eng = Engine(cfg, params, max_batch=4, max_len=64,
                     kv_layout="paged", block_size=4, n_blocks=17,
                     kv_overcommit=1.5, prefix_share=share)
        reqs = [ServeRequest(prompt=list(p),
                             max_new_tokens=3 + (i % 5))
                for i, p in enumerate(prompts)]
        queue = list(reqs)
        for _ in range(500):
            if not (queue or eng.active() or eng._pending
                    or eng._preempted):
                break
            if queue:
                adm = eng.admit_many(queue)
                taken = {id(r) for r in adm}
                queue = [r for r in queue if id(r) not in taken]
            eng.step()
            for req, _ in eng.take_preempted():
                queue.insert(0, req)
            assert eng.bm.check_no_leak()          # invariant EVERY round
        assert all(r.done for r in reqs)
        assert eng.bm.blocks_in_use() == 0
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]


# -- grow hysteresis -----------------------------------------------------------

def test_grow_hysteresis_fewer_dispatches_same_tokens(setup):
    """Satellite: grow_ahead=k allocates k blocks per boundary crossing
    when the pool has headroom, so later crossings skip the grow entirely —
    same outputs, fewer grow rounds."""
    cfg, params = setup
    outs = {}
    for k in (1, 4):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     kv_layout="paged", block_size=4, grow_ahead=k)
        reqs = [ServeRequest(prompt=[7, 3, 5, 2, 9, 1],
                             max_new_tokens=20)]
        _drain(eng, reqs)
        outs[k] = ([list(r.generated) for r in reqs], eng.stats)
    assert outs[1][0] == outs[4][0]
    assert outs[4][1].grow_ahead_skips > 0
    assert outs[1][1].grow_ahead_skips == 0        # k=1 is the old behavior


# -- admission headroom --------------------------------------------------------

def test_admit_headroom_defers_instead_of_preempting(setup):
    """Satellite: with live slots one token from a block boundary, an
    admission that would consume their next block is deferred — no
    admission-triggered preemption storm. Gating off reproduces the storm."""
    cfg, params = setup
    out = {}
    for headroom in (True, False):
        eng = Engine(cfg, params, max_batch=4, max_len=32,
                     kv_layout="paged", block_size=4, n_blocks=9,
                     kv_overcommit=2.0, admit_headroom=headroom)
        a = ServeRequest(prompt=[5, 4, 3, 2, 1, 6, 7], max_new_tokens=9)
        assert eng.admit_many([a])
        assert a.ctx_len == 8                      # boundary on next decode
        b = ServeRequest(prompt=[11] * 24, max_new_tokens=4)
        queue = [b]
        for _ in range(60):
            if a.done and b.done:
                break
            adm = eng.admit_many(queue)
            taken = {id(r) for r in adm}
            queue = [r for r in queue if id(r) not in taken]
            eng.step()
            for req, _ in eng.take_preempted():
                queue.insert(0, req)
        assert a.done and b.done
        assert eng.bm.check_no_leak()
        out[headroom] = (eng.stats.admit_deferred, eng.stats.preemptions,
                         list(a.generated), list(b.generated))
    deferred_on, preempts_on = out[True][0], out[True][1]
    deferred_off, preempts_off = out[False][0], out[False][1]
    assert deferred_on > 0 and preempts_on == 0
    assert deferred_off == 0 and preempts_off > 0
    assert out[True][2:] == out[False][2:]         # same tokens either way


# -- cluster warm-up through the tensor store ----------------------------------

def test_server_publishes_and_warms_prefixes(setup):
    cfg, params = setup
    prompts = zipf_shared_prompts(10, n_prefixes=2, prefix_len=12,
                                  suffix_len=4, share_ratio=1.0,
                                  vocab=cfg.vocab, zipf_a=3.0, seed=3)
    store = TensorStore()
    srv = GlobalServer(cfg, store, max_batch=4, max_len=64,
                       engine_kw={"kv_layout": "paged", "block_size": 4},
                       use_prefix_share=True, prefix_hot_hits=2)
    p0 = srv.add_pipeline(params, ["inst-A"])
    for p in prompts:
        p0.queue.append(ServeRequest(prompt=list(p), max_new_tokens=4))
    srv.run_until_drained()
    assert any(k == "prefix_publish" for _, k, _ in srv.events)
    assert store.keys(srv._PREFIX_MODEL)
    # a newly-placed pipeline warms from the store...
    p1 = srv.add_pipeline(params, ["inst-B"])
    assert p1.engine.stats.prefix_warmups >= 1
    warms = sum(1 for _, k, _ in srv.events if k == "prefix_warm")
    assert warms >= 1
    # ...and an interrupt-rebuilt pipeline re-warms its cold cache
    srv.interrupt_instance("inst-A")
    assert sum(1 for _, k, _ in srv.events if k == "prefix_warm") > warms
    # warmed blocks serve a FIRST-contact request without recompute
    hot = prompts[0][:12]
    probe = ServeRequest(prompt=list(hot) + [7, 9, 11, 13],
                         max_new_tokens=3)
    p1.queue.append(probe)
    srv.run_until_drained()
    assert p1.engine.stats.prefix_hits >= 1
    assert all(p.engine.bm.check_no_leak() for p in srv.pipelines)
    assert store.check_consistent()


def test_warm_prefix_recompute_fallback(setup):
    """An empty or incompatible store leaves warm-up on the recompute
    path: no events, no warmups, requests still complete."""
    cfg, params = setup
    srv = GlobalServer(cfg, TensorStore(), max_batch=2, max_len=64,
                       engine_kw={"kv_layout": "paged", "block_size": 4},
                       use_prefix_share=True)
    p0 = srv.add_pipeline(params, ["inst-A"])      # store empty: no warm
    assert p0.engine.stats.prefix_warmups == 0
    assert not any(k == "prefix_warm" for _, k, _ in srv.events)
    # incompatible payload (wrong arch) is skipped, not attached
    assert not p0.engine.warm_prefix(
        {"arch": "other", "block_size": 4, "tokens": [1, 2, 3, 4],
         "k": None, "v": None})
    r = ServeRequest(prompt=[1, 2, 3, 4, 5], max_new_tokens=4)
    srv.submit(r)
    srv.run_until_drained()
    assert r.done and len(r.generated) == 4


def test_store_peek_and_keys():
    store = TensorStore()
    store.put("__prefix__", "a", {"x": 1})
    store.put("__prefix__", "b", {"x": 2})
    store.put("m", "w", {"x": 3})
    assert store.keys("__prefix__") == [("__prefix__", "a"),
                                        ("__prefix__", "b")]
    # peek is non-consuming and touches LRU order
    assert store.peek("__prefix__", "a")["x"] == 1
    assert store.contains("__prefix__", "a")
    assert store.keys("__prefix__")[0] == ("__prefix__", "b")
    assert store.peek("__prefix__", "missing") is None
    assert store.check_consistent()
