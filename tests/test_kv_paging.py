"""Paged block-KV cache: block manager, paged-vs-contig equivalence across
attention configs, Pallas block-table kernel, fragmentation/backpressure,
block-granular KV migration through the tensor store, and the kv_restore
recovery branch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import build_model
from repro.serving import Engine, GlobalServer, ServeRequest, TensorStore
from repro.serving.kv_blocks import BlockManager


def _params_for(cfg):
    m = build_model(cfg, remat=False, attn_chunk=0)
    return m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    return cfg, _params_for(cfg)


# -- block manager -------------------------------------------------------------

def test_block_manager_alloc_free_roundtrip():
    bm = BlockManager(n_blocks=9, block_size=4, max_slots=4,
                      max_blocks_per_slot=6)
    assert bm.blocks_free() == 8                  # block 0 reserved
    assert bm.alloc(0, 10)                        # 3 blocks
    assert bm.alloc(1, 4)                         # 1 block
    assert bm.blocks_in_use() == 4
    assert bm.frag_tokens() == (3 * 4 - 10) + 0
    assert 0 not in bm.slot_blocks(0)             # trash never handed out
    assert (bm.table[0, :3] > 0).all() and bm.table[0, 3] == 0
    assert not bm.alloc(2, 100)                   # exceeds per-slot width
    assert not bm.alloc(2, 17)                    # 5 blocks > 4 free
    assert bm.blocks_in_use() == 4                # failed allocs take nothing
    assert bm.free(0) == 3
    assert (bm.table[0] == 0).all()
    assert bm.alloc(2, 17)                        # fits after the free
    bm.free_all()
    assert bm.blocks_in_use() == 0 and bm.check_no_leak()


# -- paged vs contig equivalence matrix ----------------------------------------

def _cfg_matrix():
    gqa = get_config("internlm2-1.8b").reduced()
    mha = dataclasses.replace(gqa, n_kv_heads=gqa.n_heads)
    swa = get_config("h2o-danube-3-4b").reduced()  # window=8 when reduced
    assert swa.swa_window
    return [("gqa", gqa), ("mha", mha), ("windowed", swa)]


@pytest.mark.parametrize("name,cfg", _cfg_matrix())
def test_paged_matches_contig(name, cfg):
    """Greedy outputs are byte-identical between kv_layout='paged' and
    'contig' on staggered mixed-length admissions."""
    params = _params_for(cfg)
    outs = {}
    for layout in ("contig", "paged"):
        eng = Engine(cfg, params, max_batch=4, max_len=64,
                     kv_layout=layout, block_size=8)
        rs = [ServeRequest(prompt=list(range(1, 4 + 3 * i)),
                           max_new_tokens=5 + i) for i in range(5)]
        eng.admit_many(rs[:3])
        eng.step()
        eng.admit_many(rs[3:])
        eng.drain()
        outs[layout] = [list(r.generated) for r in rs]
    assert outs["paged"] == outs["contig"]


def test_paged_chunked_prefill_matches_contig(setup):
    cfg, params = setup
    prompt = list(range(1, 42))

    def gen(layout):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     prefill_chunk=8, kv_layout=layout)
        r = ServeRequest(prompt=prompt, max_new_tokens=6)
        eng.admit(r)
        eng.drain()
        return list(r.generated)
    assert gen("paged") == gen("contig")


def test_paged_pallas_kernel_matches_jnp(setup):
    """use_pallas routes decode through the block-table gather kernel
    (interpret mode on CPU); tokens must match the jnp paged engine."""
    cfg, params = setup

    def gen(**kw):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     kv_layout="paged", **kw)
        r = ServeRequest(prompt=[3, 14, 15, 9, 2], max_new_tokens=4)
        eng.admit(r)
        eng.drain()
        return list(r.generated)
    assert gen(use_pallas=True) == gen()


def test_model_prefill_and_chunk_into_paged_cache(setup):
    """Model-level threading: prefill/prefill_chunk write through block
    tables; a paged decode after either matches the contig decode."""
    cfg, params = setup
    model = build_model(cfg, remat=False, attn_chunk=0)
    toks = jnp.asarray([list(range(1, 18)), list(range(21, 38))], jnp.int32)
    b, s = toks.shape
    logits_ref, cache_ref = model.prefill(params, {"tokens": toks},
                                          max_len=32, ring=False)
    bm = BlockManager(2 * b * 4 + 1, 8, b, 4)
    for row in range(b):
        assert bm.alloc(row, 32)
    paged = model.init_cache(b, 32, vector_pos=True, kv_layout="paged",
                             n_blocks=bm.n_blocks, block_size=8)
    paged["block_tbl"] = jnp.asarray(bm.table)
    logits_pg, cache_pg = model.prefill(params, {"tokens": toks},
                                        cache=paged)
    # tolerances: the paged path gathers pages before attending, so XLA's
    # reduction/fusion order differs from the contig path at float32 noise
    np.testing.assert_allclose(np.asarray(logits_ref), np.asarray(logits_pg),
                               rtol=1e-4, atol=1e-6)
    nxt = jnp.asarray([[7], [9]], jnp.int32)
    lr, _ = model.decode_step(params, cache_ref, nxt)
    cache_pg["pos"] = jnp.full((b,), s, jnp.int32)
    lp, _ = model.decode_step(params, cache_pg, nxt)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), rtol=1e-4,
                               atol=1e-6)
    # chunked prefill through the same tables reproduces the full prefill
    paged2 = model.init_cache(b, 32, vector_pos=True, kv_layout="paged",
                              n_blocks=bm.n_blocks, block_size=8)
    paged2["block_tbl"] = jnp.asarray(bm.table)
    cache_c = paged2
    for base in range(0, s, 8):
        end = min(base + 8, s)
        pad = jnp.zeros((b, 8), jnp.int32).at[:, :end - base].set(
            toks[:, base:end])
        last = jnp.full((b,), min(7, s - 1 - base), jnp.int32)
        logits_c, cache_c = model.prefill_chunk(params, cache_c, pad,
                                                jnp.asarray(base, jnp.int32),
                                                last_pos=last)
    np.testing.assert_allclose(np.asarray(logits_ref), np.asarray(logits_c),
                               rtol=1e-4, atol=1e-6)


def test_attention_paged_refs_match_contig():
    """Direct oracle check: gather-based paged attention equals contiguous
    attention on a randomly permuted block pool."""
    rng = np.random.RandomState(1)
    b, nh, nkv, d, bs, mb = 3, 4, 2, 16, 8, 4
    nb = b * mb + 2
    pool_k = jnp.asarray(rng.randn(nb, bs, nkv, d), jnp.float32)
    pool_v = jnp.asarray(rng.randn(nb, bs, nkv, d), jnp.float32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, nb))[:b * mb].reshape(b, mb), jnp.int32)
    ck = jnp.take(pool_k, tbl, axis=0).reshape(b, mb * bs, nkv, d)
    cv = jnp.take(pool_v, tbl, axis=0).reshape(b, mb * bs, nkv, d)
    pos = jnp.asarray([5, 17, 30], jnp.int32)
    q = jnp.asarray(rng.randn(b, 1, nh, d), jnp.float32)
    for window in (None, 8):
        ref = attn.decode_attention(q, ck, cv, pos, None, window=window)
        out = attn.decode_attention_paged(q, pool_k, pool_v, tbl, pos,
                                          window=window)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-6)
    qc = jnp.asarray(rng.randn(b, 5, nh, d), jnp.float32)
    qp = jnp.broadcast_to(6 + jnp.arange(5)[None], (b, 5))
    ref = attn.chunk_attention(qc, ck, cv, qp)
    out = attn.chunk_attention_paged(qc, pool_k, pool_v, tbl, qp)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=1e-6)


# -- fragmentation / backpressure ----------------------------------------------

def test_admit_finish_churn_never_leaks_blocks(setup):
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, kv_layout="paged",
                 block_size=8)
    rng = np.random.RandomState(3)
    for _ in range(6):
        rs = [ServeRequest(
            prompt=rng.randint(0, cfg.vocab, rng.randint(3, 40)).tolist(),
            max_new_tokens=int(rng.randint(1, 6))) for _ in range(4)]
        eng.admit_many(rs)
        eng.drain()
        assert all(r.done for r in rs)
    assert eng.bm.blocks_in_use() == 0
    assert eng.bm.check_no_leak()
    assert eng.stats.alloc_failures == 0


def test_block_exhaustion_backpressures_admission(setup):
    """A pool smaller than the slot capacity refuses admissions instead of
    overflowing; freed blocks let the queue drain later."""
    cfg, params = setup
    # 5 non-trash blocks of 8 tokens = 40 tokens shared by 4 slots
    eng = Engine(cfg, params, max_batch=4, max_len=64, kv_layout="paged",
                 block_size=8, n_blocks=6)
    rs = [ServeRequest(prompt=list(range(1, 15)), max_new_tokens=2)
          for _ in range(4)]                      # 16 tokens -> 2 blocks each
    admitted = eng.admit_many(rs)
    assert len(admitted) == 2                     # 3rd would need a 3rd pair
    # skip-ahead admission tries (and refuses) BOTH remaining requests
    assert eng.stats.alloc_failures == 2
    eng.drain()
    assert eng.bm.blocks_in_use() == 0
    assert len(eng.admit_many(rs[2:])) == 2       # backpressure released
    eng.drain()
    assert all(r.done for r in rs)


# -- KV migration through the tensor store -------------------------------------

def _serve(cfg, params, interrupt_round, prompts, n_new, **server_kw):
    srv = GlobalServer(cfg, TensorStore(), max_batch=2, max_len=64,
                       **server_kw)
    srv.add_pipeline(params, ["inst-A", "inst-B"])
    srv.add_pipeline(params, ["inst-C"])
    reqs = [ServeRequest(prompt=list(p), max_new_tokens=n_new)
            for p in prompts]
    for r in reqs:
        srv.submit(r)
    rounds = 0
    while srv.pending() and rounds < 10_000:
        if rounds == interrupt_round:
            srv.interrupt_instance("inst-A")
        srv.step()
        srv.tick()
        rounds += 1
    return srv, reqs


PROMPTS = [[5, 17, 42, 7, 99], [1, 2, 3], [9, 8, 7, 6], [4, 4, 4]]


def test_kv_migration_byte_identical_no_reprefill(setup):
    """An interrupted run that migrates KV blocks through the store matches
    the uninterrupted run byte-for-byte, with the migrated requests
    re-admitted via attach (kv_imports) instead of recompute."""
    cfg, params = setup
    _, ref = _serve(cfg, params, -1, PROMPTS, 12)
    srv, out = _serve(cfg, params, 4, PROMPTS, 12, use_kv_migration=True)
    kinds = [k for _, k, _ in srv.events]
    assert kinds.count("kv_publish") >= 1
    assert kinds.count("kv_attach") == kinds.count("kv_publish")
    assert sum(p.engine.stats.kv_imports for p in srv.pipelines) \
        == kinds.count("kv_attach")
    assert sum(r.migrations for r in out) >= 1
    for r_ref, r_out in zip(ref, out):
        assert r_out.done
        assert list(r_out.generated) == list(r_ref.generated)
    # consumed payloads must not pin store memory
    assert not [k for k in srv.store._store if k[0] == "__kv__"]


def test_kv_migration_recompute_fallback_on_contig(setup):
    """Contig engines publish nothing; migration falls back to the §5.1
    recompute path and stays byte-identical."""
    cfg, params = setup
    _, ref = _serve(cfg, params, -1, PROMPTS, 12,
                    engine_kw={"kv_layout": "contig"})
    srv, out = _serve(cfg, params, 4, PROMPTS, 12, use_kv_migration=True,
                      engine_kw={"kv_layout": "contig"})
    assert not [k for _, k, _ in srv.events if k == "kv_publish"]
    assert sum(p.engine.stats.kv_imports for p in srv.pipelines) == 0
    assert sum(r.migrations for r in out) >= 1
    for r_ref, r_out in zip(ref, out):
        assert list(r_out.generated) == list(r_ref.generated)


def test_kv_migration_with_pending_chunked_prefill(setup):
    """Slots mid-chunked-prefill have incomplete KV: they are excluded from
    publication and recompute instead — outputs still byte-identical."""
    cfg, params = setup
    prompts = [[5, 17, 42, 7, 99, 3, 1, 2, 8, 11] * 3, [1, 2, 3, 4, 5, 6]]
    _, ref = _serve(cfg, params, -1, prompts, 10)
    srv, out = _serve(cfg, params, 1, prompts, 10, use_kv_migration=True,
                      prefill_chunk=8)
    assert sum(r.migrations for r in out) >= 1
    for r_ref, r_out in zip(ref, out):
        assert r_out.done
        assert list(r_out.generated) == list(r_ref.generated)


# -- batched chunked prefill (pending groups) ----------------------------------

def test_pending_group_single_dispatch_per_step(setup):
    """Pendings admitted together advance as ONE chunk dispatch per step
    (not one per request), and outputs match solo runs."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, prefill_chunk=8)
    longs = [ServeRequest(prompt=list(range(1 + i, 41 + i)),
                          max_new_tokens=4) for i in range(3)]
    eng.admit_many(longs)
    assert len(eng._pending) == 1 and len(eng._pending[0].members) == 3
    before = eng.stats.prefill_chunks
    eng.step()
    assert eng.stats.prefill_chunks == before + 1     # one fused dispatch
    eng.drain()
    for r in longs:
        solo = Engine(cfg, params, max_batch=2, max_len=64)
        r2 = ServeRequest(prompt=list(r.prompt), max_new_tokens=4)
        solo.admit(r2)
        solo.drain()
        assert list(r.generated) == list(r2.generated)


def test_pending_group_mixed_lengths_finish_independently(setup):
    """Members with different context lengths leave the group as they
    finish; stragglers keep prefilling."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=4, max_len=64, prefill_chunk=8)
    short = ServeRequest(prompt=list(range(1, 13)), max_new_tokens=3)
    long = ServeRequest(prompt=list(range(1, 41)), max_new_tokens=3)
    eng.admit_many([short, long])
    eng.step()
    eng.step()                        # base=16: short done, long pending
    assert short.generated and not long.generated
    eng.drain()
    for r in (short, long):
        solo = Engine(cfg, params, max_batch=2, max_len=64)
        r2 = ServeRequest(prompt=list(r.prompt), max_new_tokens=3)
        solo.admit(r2)
        solo.drain()
        assert list(r.generated) == list(r2.generated)


# -- tensor store: LRU budget + accounting -------------------------------------

def _arr(n_bytes):
    return {"w": jnp.zeros((n_bytes // 4,), jnp.float32)}


def test_store_evict_to_lru_respects_refcounts():
    store = TensorStore()
    store.put("m", "a", _arr(400))
    store.put("m", "b", _arr(400))
    store.put("m", "c", _arr(400))
    store.attach("m", "a")                    # pin a
    store.attach("m", "b")
    store.detach("m", "b")                    # b unreferenced, recently used
    assert store.resident_bytes() == 1200
    freed = store.evict_to(900)
    assert freed == 400
    assert not store.contains("m", "c")       # LRU victim: c (never touched)
    assert store.contains("m", "a") and store.contains("m", "b")
    # a referenced key is never evicted, even when the budget is unmeetable
    store.take("m", "b")
    assert store.evict_to(0) == 0
    assert store.contains("m", "a")
    assert store.check_consistent()


def test_store_budget_enforced_on_insert():
    store = TensorStore(budget_bytes=1000)
    store.put("kv", "r1", _arr(400))
    store.put("kv", "r2", _arr(400))
    store.put("kv", "r3", _arr(400))          # evicts r1 (LRU)
    assert store.resident_bytes() <= 1000
    assert not store.contains("kv", "r1")
    assert store.contains("kv", "r3")


def test_store_accounting_agrees_across_put_and_load_paths():
    """Regression: ``put`` and ``load`` must register keys identically so
    resident_bytes/refcount never drift between the paths."""
    store = TensorStore()
    store.put("m", "pre", _arr(400))          # preloaded params
    assert store.refcount("m", "pre") == 0    # resident but unreferenced
    params, _ = store.load("m", "pre", lambda: _arr(9999))
    assert params["w"].nbytes == 400          # resident key: no loader call
    assert store.refcount("m", "pre") == 1
    assert store.loads[-1].cold is False and store.loads[-1].wall_s == 0.0
    store.load("m", "cold", lambda: _arr(800))
    assert store.refcount("m", "cold") == 1
    assert store.resident_bytes() == 1200
    assert store.check_consistent()
    store.detach("m", "cold")
    store.evict_unreferenced()
    assert store.resident_bytes() == 400      # "pre" still attached once
    assert store.contains("m", "pre") and not store.contains("m", "cold")


def test_store_attach_missing_key_raises():
    with pytest.raises(KeyError):
        TensorStore().attach("m", "nope")


# -- recovery: kv_restore branch -----------------------------------------------

def test_decide_prefers_kv_restore_when_store_holds_blocks():
    from repro.cluster.recovery import decide
    from repro.core import populate_cluster
    from repro.hw import AWS_INSTANCES, effective, paper_cluster
    spec = get_config("llama-3.1-70b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232,
                            beam_k=1)
    p = plan.pipelines[0]
    base = decide(spec, p, ctx=4096, remaining_grace_s=120.0,
                  policy="hybrid", efficiency=0.05, chunk=16)
    held = decide(spec, p, ctx=4096, remaining_grace_s=120.0,
                  policy="hybrid", efficiency=0.05, chunk=16,
                  store_has_kv=True)
    assert base.mechanism != "kv_restore"     # nothing resident: unchanged
    assert held.mechanism == "kv_restore"
    assert held.kv_restore_s < held.recompute_s
    assert held.kv_restore_s < held.transfer_s
    # and the default decision surface is untouched
    assert base.recompute_s == held.recompute_s
    assert base.transfer_s == held.transfer_s
