"""Hybrid recovery policy (paper §8.1 future work — beyond-paper feature)."""

import dataclasses

import pytest

from repro.cluster import ClusterSim, FTConfig
from repro.cluster.recovery import (decide, kv_bytes_for_ctx,
                                    recompute_seconds, transfer_seconds)
from repro.cluster.workload import Request
from repro.configs import get_config
from repro.core import populate_cluster
from repro.hw import AWS_INSTANCES, effective, paper_cluster


@pytest.fixture(scope="module")
def setup():
    spec = get_config("llama-3.1-70b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232,
                            beam_k=1)
    return spec, plan


def test_kv_bytes_monotone(setup):
    spec, _ = setup
    assert kv_bytes_for_ctx(spec, 2048) > kv_bytes_for_ctx(spec, 512)


def test_decide_short_context_recomputes(setup):
    """Paper Fig 5: recomputation wins at short contexts."""
    spec, plan = setup
    p = plan.pipelines[0]
    d = decide(spec, p, ctx=512, remaining_grace_s=120.0, policy="hybrid")
    assert d.mechanism == "recompute"
    assert d.recompute_s < d.transfer_s


def test_decide_long_context_transfers_when_slow_compute(setup):
    """With a heavily derated engine (busy/slow cluster), long contexts tip
    to transfer — the §8.1 motivation."""
    spec, plan = setup
    p = plan.pipelines[0]
    d = decide(spec, p, ctx=32768, remaining_grace_s=300.0,
               policy="hybrid", efficiency=0.05)
    assert d.transfer_s < d.recompute_s
    assert d.mechanism == "transfer"


def test_grace_constraint_forces_recompute(setup):
    """Paper §5.1: transfer must fit the grace period or we fall back."""
    spec, plan = setup
    p = plan.pipelines[0]
    d = decide(spec, p, ctx=32768, remaining_grace_s=0.5,
               policy="transfer", efficiency=0.05)
    assert not d.fits_grace
    assert d.mechanism == "recompute"


def test_policy_recompute_never_transfers(setup):
    spec, plan = setup
    p = plan.pipelines[0]
    d = decide(spec, p, ctx=65536, remaining_grace_s=1e9,
               policy="recompute", efficiency=1e-3)
    assert d.mechanism == "recompute"


def test_sim_hybrid_not_worse_on_long_contexts(setup):
    """End-to-end: on a long-context workload under interruptions, the
    hybrid policy completes at least as many requests as pure
    recomputation."""
    spec, plan = setup
    reqs = [Request(i, 0.0, 2048, 64) for i in range(200)]
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(100.0, pool, -1)]

    def run(policy):
        ft = FTConfig(recovery_policy=policy)
        sim = ClusterSim(spec, plan.pipelines, ft, mean_s_in=2048,
                         mean_s_out=64, efficiency=0.05)
        return sim.run(reqs, duration_s=1200.0, events=events,
                       offline=True)

    r_rec = run("recompute")
    r_hyb = run("hybrid")
    assert len(r_hyb.completed) >= len(r_rec.completed)
    migrated_h = [r for r in r_hyb.completed + r_hyb.unfinished
                  if r.migrations]
    assert migrated_h, "the interruption must affect requests"
