"""End-to-end behaviour tests for the full system: placement -> serving ->
interruption -> migration -> completion (the paper's pipeline, small scale).
"""

import dataclasses

import jax
import pytest

from repro.cluster import (ClusterSim, FTConfig, azure_conversation_like)
from repro.configs import get_config
from repro.core import Objective, populate_cluster
from repro.core.baselines import alpaserve_dp, hexgen_genetic, vllm_even
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.models import build_model
from repro.serving import GlobalServer, ServeRequest, TensorStore


def test_end_to_end_placement_to_serving():
    """Optimizer places the paper's 70B model on the paper's cluster; the
    simulator then serves the trace; ShuntServe beats naive baselines."""
    spec = get_config("llama-3.1-70b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    inv = paper_cluster()
    shunt = populate_cluster(spec, inv, insts, 763, 232, beam_k=1)
    vllm = vllm_even(spec, inv, insts, 763, 232)
    assert shunt.pipelines, "ShuntServe must place the model"
    reqs = azure_conversation_like(duration_s=240, rate_rps=4.67, seed=0)

    def run(plan):
        if not plan.pipelines:
            return 0.0
        sim = ClusterSim(spec, plan.pipelines, FTConfig(use_spot=True))
        return sim.run(reqs, duration_s=240, offline=True).rps

    assert run(shunt) >= run(vllm) * 0.99


def test_end_to_end_real_engine_with_interruptions():
    """Real token generation through the global server across an
    interruption: every request finishes; outputs of migrated requests keep
    their pre-interruption prefix."""
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    store = TensorStore()
    srv = GlobalServer(cfg, store, max_batch=2, max_len=64)
    srv.add_pipeline(params, ["n0", "n1"], weight=1.0)
    srv.add_pipeline(params, ["n2"], weight=1.0)
    reqs = [ServeRequest(prompt=[7 + i, 3, 11], max_new_tokens=6)
            for i in range(6)]
    for r in reqs:
        srv.submit(r)
    for _ in range(2):
        srv.step()
    snapshot = {r.rid: list(r.generated) for r in reqs}
    srv.interrupt_instance("n0")
    srv.run_until_drained()
    for r in reqs:
        assert r.done, r.rid
        assert list(r.generated)[:len(snapshot[r.rid])] == snapshot[r.rid]
    assert sum(1 for r in reqs if r.migrations > 0) >= 1


def test_baselines_produce_plans():
    spec = get_config("qwen3-32b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    inv = paper_cluster()
    for fn in (vllm_even, alpaserve_dp):
        plan = fn(spec, inv, insts, 763, 232)
        assert plan.pipelines, fn.__name__
        for p in plan.pipelines:
            assert sum(s.n_layers for s in p.stages) == spec.n_layers
    gen = hexgen_genetic(spec, inv, insts, 763, 232, pop_size=6,
                         generations=3, seed=0)
    for p in gen.pipelines:
        assert sum(s.n_layers for s in p.stages) == spec.n_layers
