"""Dry-run machinery on a small host-device mesh (subprocess, so the 8-device
XLA flag never pollutes this test process's single-device view)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.launch.steps import build_step, lower_step
    from repro.launch import hlo_utils
    from repro.launch.hlo_costs import normalize_cost_analysis

    out = {}
    cfg = get_config("internlm2-1.8b").reduced()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for shape in [ShapeSpec("t", 64, 8, "train_step"),
                  ShapeSpec("p", 64, 4, "prefill_step"),
                  ShapeSpec("d", 64, 8, "serve_step")]:
        built = build_step(cfg, shape, mesh, attn_chunk=32)
        comp = lower_step(built, mesh).compile()
        ca = normalize_cost_analysis(comp.cost_analysis())
        cb = hlo_utils.collective_bytes(comp.as_text(), built.trip_hints)
        out[shape.step] = {"flops": ca.get("flops", -1.0),
                           "coll": cb["total"]}
    # multi-pod mesh: DP serve + PP serve
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for pp in (False, True):
        built = build_step(cfg, ShapeSpec("d", 64, 8, "serve_step"), mesh3,
                           serve_pp=pp)
        comp = lower_step(built, mesh3).compile()
        cb = hlo_utils.collective_bytes(comp.as_text(), built.trip_hints)
        key = "serve_pp" if pp else "serve_dp_multipod"
        out[key] = {"coll": cb["total"],
                    "split": built.meta.get("pp_split")}
    # hybrid family lowers too (zamba2 reduced)
    zcfg = get_config("zamba2-2.7b").reduced()
    built = build_step(zcfg, ShapeSpec("d", 64, 8, "serve_step"), mesh)
    lower_step(built, mesh).compile()
    out["hybrid_serve_ok"] = True
    print("JSON::" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-4000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("JSON::")][0]
    out = json.loads(line[len("JSON::"):])
    assert out["train_step"]["flops"] > 0
    assert out["train_step"]["coll"] > 0          # FSDP/TP collectives exist
    assert out["serve_step"]["coll"] > 0
    assert out["serve_pp"]["split"] is not None
    assert sum(out["serve_pp"]["split"]) == 4     # reduced config layers
    assert out["hybrid_serve_ok"]
