"""Hypothesis property tests on system invariants.

``hypothesis`` is an *optional* dev dependency (see pytest.ini): the module
skips cleanly when it is not installed so the tier-1 suite still collects.
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import comm, roofline
from repro.core.estimator import Placement, Stage, estimate, max_batch_size
from repro.core.modelspec import LayerSpec, uniform_decoder
from repro.core.placement import PlacementOptimizer
from repro.hw.profiles import AWS_INSTANCES


@settings(max_examples=40, deadline=None)
@given(s_in=st.integers(1, 4096), s_out=st.integers(1, 1024),
       window=st.one_of(st.none(), st.integers(1, 8192)))
def test_decode_ctx_sum_matches_loop(s_in, s_out, window):
    expect = sum(min(s_in + t, window) if window else s_in + t
                 for t in range(1, s_out + 1))
    assert roofline._decode_ctx_sum(s_in, s_out, window) == pytest.approx(
        expect)


@settings(max_examples=30, deadline=None)
@given(h=st.sampled_from([256, 512, 1024]), nh=st.sampled_from([4, 8]),
       nkv=st.sampled_from([1, 2, 4]), batch=st.integers(1, 64),
       s_in=st.integers(16, 2048), d_tp=st.sampled_from([1, 2, 4, 8]))
def test_flops_scale_linearly_in_batch_and_inverse_tp(h, nh, nkv, batch,
                                                      s_in, d_tp):
    l = LayerSpec("attn+ffn", h, nh, nkv, h // nh, 4 * h)
    f1 = roofline.layer_flops(l, "prefill", batch, s_in, 0, 1)
    fb = roofline.layer_flops(l, "prefill", 2 * batch, s_in, 0, 1)
    ftp = roofline.layer_flops(l, "prefill", batch, s_in, 0, d_tp)
    assert fb == pytest.approx(2 * f1, rel=1e-6)
    assert ftp == pytest.approx(f1 / d_tp, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 1 << 24), p=st.integers(2, 64))
def test_allreduce_equals_rs_plus_ag(n, p):
    link = comm.Link(1e-5, 1e9)
    ar = comm.ring_allreduce(n, p, link)
    assert ar == pytest.approx(2 * comm.ring_allgather(n, p, link))


@settings(max_examples=15, deadline=None)
@given(n_layers=st.integers(2, 12),
       inv_e=st.integers(0, 3), inv_g=st.integers(0, 2),
       s_in=st.sampled_from([128, 763]), seed=st.integers(0, 5))
def test_placement_always_valid(n_layers, inv_e, inv_g, s_in, seed):
    """For any inventory, a returned placement covers all layers exactly and
    never exceeds device inventory."""
    if inv_e + inv_g == 0:
        return
    spec = uniform_decoder("t", n_layers, 256, 4, 2, 512, 1000 + seed)
    inv = {}
    if inv_e:
        inv["g6e.xlarge"] = inv_e
    if inv_g:
        inv["g6.12xlarge"] = inv_g
    res = PlacementOptimizer(spec, inv, dict(AWS_INSTANCES), s_in, 32,
                             beam_k=1, max_stages=4).search()
    if res.placement is None:
        return
    p = res.placement
    assert sum(s.n_layers for s in p.stages) == n_layers
    used = {}
    for s in p.stages:
        used[s.instance.name] = used.get(s.instance.name, 0) + s.tp
    for name, d in used.items():
        assert d <= inv[name] * AWS_INSTANCES[name].num_devices


@settings(max_examples=20, deadline=None)
@given(n_layers=st.integers(2, 8), s_in=st.integers(64, 2048),
       s_out=st.integers(8, 512))
def test_eq6_batch_fits_memory(n_layers, s_in, s_out):
    """The Eq. 6 batch actually satisfies every stage's memory budget."""
    from repro.core.estimator import (stage_kv_bytes_per_seq,
                                      stage_weight_bytes)
    spec = uniform_decoder("t", n_layers, 512, 8, 4, 2048, 32000)
    inst = AWS_INSTANCES["g6e.xlarge"]
    half = n_layers // 2 or 1
    stages = (Stage(inst, 1, half, first=True),
              Stage(inst, 1, n_layers - half, last=True))
    if n_layers == 1:
        stages = (Stage(inst, 1, 1, first=True, last=True),)
    p = Placement(spec, stages)
    b = max_batch_size(spec, p, s_in, s_out)
    if b == 0:
        return
    for stage, (lo, hi) in zip(p.stages, p.layer_ranges()):
        w = stage_weight_bytes(spec, stage, lo, hi)
        kv = stage_kv_bytes_per_seq(spec, lo, hi, s_in, s_out)
        assert w + b * kv <= stage.mem_bytes * 0.9 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_workload_reproducible(seed):
    from repro.cluster.workload import azure_conversation_like
    a = azure_conversation_like(duration_s=120, seed=seed)
    b = azure_conversation_like(duration_s=120, seed=seed)
    assert [(r.arrival_s, r.s_in, r.s_out) for r in a] == \
           [(r.arrival_s, r.s_in, r.s_out) for r in b]


@settings(max_examples=10, deadline=None)
@given(minutes=st.integers(100, 500), seed=st.integers(0, 20))
def test_trace_counts_bounded(minutes, seed):
    from repro.cluster.spot_trace import PAPER_POOLS, generate_trace
    tr = generate_trace(PAPER_POOLS, minutes=minutes, seed=seed)
    for name, series in tr.counts.items():
        cap = PAPER_POOLS[name].capacity
        assert series.min() >= 0 and series.max() <= cap


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4), s=st.sampled_from([16, 32]),
       nh=st.sampled_from([2, 4]), seed=st.integers(0, 3))
def test_ring_cache_equivalent_to_linear(b, s, nh, seed):
    """SWA ring cache decode == linear cache decode with window masking."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("h2o-danube-3-4b").reduced()
    m = build_model(cfg, remat=False, attn_chunk=0)
    params = m.init(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    l_ring, c_ring = m.prefill(params, {"tokens": toks}, max_len=s + 2,
                               ring=True)
    l_lin, c_lin = m.prefill(params, {"tokens": toks}, max_len=s + 2,
                             ring=False)
    np.testing.assert_allclose(np.asarray(l_ring), np.asarray(l_lin),
                               atol=2e-4, rtol=1e-3)
    nxt = m.sample_greedy(l_ring)[:, None].astype(jnp.int32)
    d_ring, _ = m.decode_step(params, c_ring, nxt)
    d_lin, _ = m.decode_step(params, c_lin, nxt)
    np.testing.assert_allclose(np.asarray(d_ring), np.asarray(d_lin),
                               atol=2e-4, rtol=1e-3)
