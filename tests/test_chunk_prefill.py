"""Direct-to-pool chunked prefill: paged engines land every prefill chunk
straight in the slot's pool blocks (no transient group cache, no terminal
scatter).  Pins byte-identity against the contig transient+scatter baseline
across staggered admissions, Pallas vs jnp reads, prefix sharing,
preemption churn, and enc-dec chunking, plus the device-side poison probe
that checkifies gathered KV against the sanitizer's KV_POISON sentinel.
"""

import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, ServeRequest
from repro.serving.kv_blocks import KV_POISON


def _params_for(cfg):
    m = build_model(cfg, remat=False, attn_chunk=0)
    return m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    return cfg, _params_for(cfg)


def test_direct_paged_matches_contig_scatter(setup):
    """Greedy outputs are byte-identical between the paged direct-write
    chunk path and the contig transient+scatter path on staggered
    mixed-length admissions — and the stats counters prove which path
    each engine actually took."""
    cfg, params = setup
    outs, engines = {}, {}
    for layout in ("contig", "paged"):
        eng = Engine(cfg, params, max_batch=4, max_len=64,
                     prefill_chunk=8, kv_layout=layout)
        rs = [ServeRequest(prompt=list(range(1 + i, 30 + 3 * i)),
                           max_new_tokens=4 + i) for i in range(4)]
        eng.admit_many(rs[:2])
        eng.step()
        eng.admit_many(rs[2:])
        eng.drain()
        outs[layout] = [list(r.generated) for r in rs]
        engines[layout] = eng
    assert outs["paged"] == outs["contig"]
    assert engines["paged"].stats.chunk_direct > 0
    assert engines["paged"].stats.chunk_scatters == 0
    assert engines["contig"].stats.chunk_direct == 0
    assert engines["contig"].stats.chunk_scatters > 0


def test_direct_paged_pallas_matches_jnp(setup):
    """use_pallas routes the chunk dispatch through the flash paged chunk
    kernel (interpret mode on CPU); tokens must match the jnp oracle
    engine exactly."""
    cfg, params = setup

    def gen(**kw):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     prefill_chunk=8, kv_layout="paged", **kw)
        rs = [ServeRequest(prompt=list(range(1, 42)), max_new_tokens=6),
              ServeRequest(prompt=list(range(3, 20)), max_new_tokens=4)]
        eng.admit_many(rs)
        eng.drain()
        assert eng.stats.chunk_direct > 0
        return [list(r.generated) for r in rs]
    assert gen(use_pallas=True) == gen()


def test_direct_chunk_with_prefix_share(setup):
    """Chunked prefill composes with prefix sharing: shared-prefix
    admissions under share=on match share=off byte-for-byte while still
    taking the direct chunk path for the unshared members."""
    cfg, params = setup
    common = list(range(1, 25))

    def gen(share):
        eng = Engine(cfg, params, max_batch=4, max_len=64, prefill_chunk=8,
                     kv_layout="paged", prefix_share=share)
        rs = [ServeRequest(prompt=common + [40 + i], max_new_tokens=5)
              for i in range(3)]
        eng.admit(rs[0])
        eng.drain()                     # first run warms the prefix index
        eng.admit_many(rs[1:])
        eng.drain()
        assert eng.bm.check_no_leak()
        return [list(r.generated) for r in rs]
    assert gen(True) == gen(False)


def test_direct_chunk_survives_preemption_churn(setup):
    """An overcommitted pool preempts while chunked prefills are in
    flight; the direct-write path (pool blocks ARE the cache) must stay
    byte-identical to an unconstrained run through the export/attach
    round trip."""
    cfg, params = setup

    def gen(**kw):
        eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                     prefill_chunk=8, **kw)
        rs = [ServeRequest(prompt=list(range(1, 28 + 4 * i)),
                           max_new_tokens=12) for i in range(3)]
        assert len(eng.admit_many(rs)) == 3
        eng.drain()
        assert all(r.done for r in rs)
        assert eng.bm.check_no_leak() and eng.bm.blocks_in_use() == 0
        return eng, [list(r.generated) for r in rs]

    _, ref = gen()
    eng, out = gen(n_blocks=15, kv_overcommit=2.5)
    assert out == ref
    assert eng.stats.chunk_direct > 0
    assert eng.stats.preemptions >= 1


@pytest.mark.parametrize("use_pallas", [False, True])
def test_poison_probe_trips_on_corrupted_block(setup, use_pallas):
    """With the sanitizer armed, the decode dispatch carries a device-side
    probe: poison planted in a mapped (readable) pool block raises at the
    very step that reads it, on both the jnp oracle and the Pallas kernel
    path."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, block_size=8,
                 kv_sanitize=True, use_pallas=use_pallas)
    req = ServeRequest(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=8)
    assert eng.admit(req)
    eng.step()
    slot = next(i for i, r in enumerate(eng.slots) if r is req)
    blk = int(eng.bm.table[slot, 0])
    eng.cache["k"] = eng.cache["k"].at[:, blk].set(KV_POISON)
    with pytest.raises(Exception, match="poisoned KV block"):
        eng.step()


def test_poison_probe_trips_mid_chunk(setup):
    """The chunk dispatch probes too: corrupting an already-written block
    of a mid-prefill slot fires on the next chunk, not only at decode."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, block_size=8,
                 prefill_chunk=8, kv_sanitize=True)
    req = ServeRequest(prompt=list(range(1, 42)), max_new_tokens=4)
    assert eng.admit(req)
    eng.step()                                   # first chunk written
    assert not req.generated                     # still mid-prefill
    slot = eng._pending[0].members[0].slot
    blk = int(eng.bm.table[slot, 0])
    eng.cache["v"] = eng.cache["v"].at[:, blk].set(-KV_POISON)
    with pytest.raises(Exception, match="poisoned KV block"):
        eng.step()


def test_probe_off_by_default(setup):
    """Without kv_sanitize the probe is dark: same corruption decodes
    garbage-free-of-exceptions (byte identity is the sanitizer's job)."""
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, block_size=8)
    req = ServeRequest(prompt=[1, 2, 3, 4, 5, 6], max_new_tokens=4)
    assert eng.admit(req)
    eng.step()
    slot = next(i for i, r in enumerate(eng.slots) if r is req)
    blk = int(eng.bm.table[slot, 0])
    eng.cache["k"] = eng.cache["k"].at[:, blk].set(KV_POISON)
    eng.step()                                   # must not raise
    assert not eng._kv_probe


def test_encdec_chunked_prefill_matches_full():
    """Enc-dec engines chunk their decoder prefill now (the cross-attn
    cache threads through the chunk body): outputs byte-identical to the
    one-shot prefill engine, with chunks actually dispatched."""
    cfg = get_config("whisper-tiny").reduced()
    params = _params_for(cfg)

    def gen(chunk):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     prefill_chunk=chunk)
        rs = [ServeRequest(prompt=list(range(1, 38)), max_new_tokens=6),
              ServeRequest(prompt=list(range(2, 14)), max_new_tokens=4)]
        eng.admit_many(rs)
        eng.drain()
        return eng, [list(r.generated) for r in rs]

    eng_c, chunked = gen(8)
    _, full = gen(0)
    assert chunked == full
    assert eng_c.stats.prefill_chunks > 0


def test_encdec_chunk_interleaves_with_decode():
    """A live enc-dec request keeps decoding while a long admission
    chunk-prefills beside it."""
    cfg = get_config("whisper-tiny").reduced()
    params = _params_for(cfg)
    eng = Engine(cfg, params, max_batch=2, max_len=64, prefill_chunk=8)
    short = ServeRequest(prompt=[1, 2, 3], max_new_tokens=8)
    eng.admit(short)
    eng.step()
    long = ServeRequest(prompt=list(range(1, 38)), max_new_tokens=4)
    eng.admit(long)
    eng.step()
    assert len(short.generated) >= 2 and not long.generated
    eng.drain()

    for r in (short, long):
        solo = Engine(cfg, params, max_batch=2, max_len=64)
        r2 = ServeRequest(prompt=list(r.prompt),
                          max_new_tokens=r.max_new_tokens)
        solo.admit(r2)
        solo.drain()
        assert list(r.generated) == list(r2.generated)
