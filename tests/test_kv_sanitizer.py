"""KV-block sanitizer (ISSUE 8): the shadow ledger must catch injected
double-frees, refcount underflow, use-after-free reads, shared-block
writes, and outside tampering at the op that caused them, while a fully
sanitized engine run stays byte-identical to a plain one (the freed-block
poison sentinel is output-neutral under correct masking)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, ServeRequest
from repro.serving.kv_blocks import (
    KV_POISON,
    BlockManager,
    KVSanitizerError,
)


def _params_for(cfg):
    m = build_model(cfg, remat=False, attn_chunk=0)
    return m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    return cfg, _params_for(cfg)


def _bm(**kw):
    kw.setdefault("n_blocks", 9)
    kw.setdefault("block_size", 4)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_blocks_per_slot", 6)
    kw.setdefault("sanitize", True)
    return BlockManager(**kw)


# -- mode selection ------------------------------------------------------------

def test_env_var_enables_sanitizer(monkeypatch):
    monkeypatch.setenv("REPRO_KV_SANITIZE", "1")
    assert BlockManager(4, 4, 2, 2).sanitize
    monkeypatch.setenv("REPRO_KV_SANITIZE", "0")
    assert not BlockManager(4, 4, 2, 2).sanitize
    # explicit argument beats the environment
    assert BlockManager(4, 4, 2, 2, sanitize=True).sanitize


# -- injected bug classes ------------------------------------------------------

def test_double_free_raises():
    bm = _bm()
    assert bm.reserve(0, 8)
    bm.free(0)
    with pytest.raises(KVSanitizerError, match="double free"):
        bm.free(0)


def test_plain_mode_double_free_still_noops():
    # the non-sanitizing path keeps the engine-friendly no-op contract
    bm = _bm(sanitize=False)
    assert bm.reserve(0, 8)
    bm.free(0)
    assert bm.free(0) == 0
    assert bm.check_no_leak()


def test_refcount_underflow_raises():
    bm = _bm()
    assert bm.reserve(0, 8)
    bm.refcount[bm.slot_blocks(0)[0]] -= 1        # tamper
    with pytest.raises(KVSanitizerError, match="underflow"):
        bm.free(0)


def test_use_after_free_read_raises():
    bm = _bm()
    assert bm.reserve(0, 8)
    bm.check_read(0, 8)                           # mapped: fine
    bm.free(0)
    with pytest.raises(KVSanitizerError, match="use-after-free"):
        bm.check_read(0, 8)


def test_dangling_table_entry_raises():
    bm = _bm()
    assert bm.reserve(0, 8) and bm.reserve(1, 8)
    dead = bm.slot_blocks(1)[0]
    bm.free(1)
    bm.table[0, 0] = dead                         # injected dangling ref
    with pytest.raises(KVSanitizerError, match="use-after-free"):
        bm.check_read(0, 8)


def test_shared_block_write_raises():
    bm = _bm()
    assert bm.reserve(0, 8)
    donor = bm.slot_blocks(0)
    assert bm.reserve(1, 12, shared=donor)
    # sharer writing into its read-only shared prefix
    with pytest.raises(KVSanitizerError, match="read-only shared-prefix"):
        bm.check_write(1, 0, 4)
    # donor writing its own block while refcount > 1 (COW hazard)
    with pytest.raises(KVSanitizerError, match="COW required"):
        bm.check_write(0, 0, 4)
    bm.check_write(1, 8, 12)                      # fresh region: fine
    bm.free(1)
    bm.check_write(0, 0, 4)                       # last sharer: fine again


def test_note_live_delta_drives_write_check():
    bm = _bm()
    assert bm.reserve(0, 8)
    donor = bm.slot_blocks(0)
    assert bm.reserve(1, 12, live_tokens=8, shared=donor)
    assert bm.grow(1, 12)
    bm.note_live(1, 12)                           # fresh block: fine
    bm._live[1] = 4                               # tamper live watermark
    with pytest.raises(KVSanitizerError, match="shared"):
        bm.note_live(1, 8)                        # delta covers shared blk


def test_note_cow_validates_source_and_destination():
    bm = _bm()
    assert bm.reserve(0, 8)
    donor = bm.slot_blocks(0)
    assert bm.reserve(1, 12, shared=donor)
    fresh = bm.slot_blocks(1)[2]
    bm.note_cow(donor[1], fresh)                  # valid: rc-1 dest
    with pytest.raises(KVSanitizerError, match="refcount"):
        bm.note_cow(donor[1], donor[0])           # dest shared (rc 2)


def test_crosscheck_detects_free_list_tampering():
    bm = _bm()
    assert bm.reserve(0, 8)
    bm._free.append(bm.slot_blocks(0)[0])         # block free AND mapped
    with pytest.raises(KVSanitizerError, match="shadow ledger"):
        bm.reserve(1, 4)


def test_crosscheck_detects_refcount_tampering():
    bm = _bm()
    assert bm.reserve(0, 8)
    bm.refcount[bm.slot_blocks(0)[0]] += 1        # tamper upward
    with pytest.raises(KVSanitizerError, match="refcount .* diverged"):
        bm.reserve(1, 4)


def test_released_blocks_reported_for_poisoning():
    bm = _bm()
    assert bm.reserve(0, 8)
    ids = bm.slot_blocks(0)
    bm.indexed.add(ids[0])                        # prefix index holds blk 0
    bm.free(0)
    # only the un-indexed block's content is dead (warm prefix survives)
    assert bm.last_released == [ids[1]]
    # reusing the dead block clears its poison
    assert bm.reserve(1, 8)
    bm.check_read(1, 8)


def test_warm_cycle_is_sanitizer_clean():
    bm = _bm()
    ids = bm.warm_blocks(2)
    assert ids is not None
    with pytest.raises(KVSanitizerError, match="non-borrowed"):
        bm.warm_release([7 if 7 not in ids else 6])
    bm.warm_release(ids)
    assert bm.reserve(0, 8)
    bm.free(0)


# -- engine integration --------------------------------------------------------

def test_sanitized_engine_byte_identical_with_churn(setup):
    """Full engine run (grows, preemptions, KV re-attach) under the
    sanitizer: no false positives, outputs byte-identical to plain mode —
    proving the device poison writes are output-neutral."""
    cfg, params = setup

    def run(sanitize):
        eng = Engine(cfg, params, max_batch=4, max_len=64, block_size=8,
                     n_blocks=13, kv_overcommit=2.0, kv_sanitize=sanitize)
        rng = np.random.RandomState(11)
        reqs = [ServeRequest(
            prompt=rng.randint(0, cfg.vocab, rng.randint(3, 30)).tolist(),
            max_new_tokens=int(rng.randint(2, 12))) for _ in range(8)]
        queue = list(reqs)
        for _ in range(400):
            if not (queue or eng.active() or eng._pending
                    or eng._preempted):
                break
            if queue:
                adm = eng.admit_many(queue[:2])
                taken = {id(r) for r in adm}
                queue = [r for r in queue if id(r) not in taken]
            eng.step()
            for req, _ in eng.take_preempted():
                queue.insert(0, req)
        assert all(r.done for r in reqs)
        return [r.generated for r in reqs], eng

    plain, _ = run(False)
    sanitized, eng = run(True)
    assert sanitized == plain
    assert eng.stats.preemptions >= 1             # poison path exercised


def test_engine_step_catches_freed_blocks_behind_its_back(setup):
    cfg, params = setup
    eng = Engine(cfg, params, max_batch=2, max_len=64, block_size=8,
                 kv_sanitize=True)
    req = ServeRequest(prompt=[1, 2, 3, 4], max_new_tokens=8)
    assert eng.admit(req)
    eng.step()
    slot = next(i for i, r in enumerate(eng.slots) if r is req)
    eng.bm.free(slot)                             # inject: yank the blocks
    with pytest.raises(KVSanitizerError, match="use-after-free"):
        eng.step()


def test_poison_sentinel_is_finite():
    # NaN would propagate through p @ v even at masked positions; the
    # sentinel must be finite so 0-probability positions contribute 0.0
    assert np.isfinite(KV_POISON) and KV_POISON >= 1e6
