"""Estimator unit tests: paper Table 2 / Eqs. 1, 4, 5."""

import math

import pytest

from repro.core import estimator, roofline
from repro.core.estimator import Placement, Stage, estimate, max_batch_size
from repro.core.modelspec import LayerSpec, uniform_decoder
from repro.hw.profiles import AWS_INSTANCES, L4, L40S, effective


def dense_layer(h=512, nh=8, nkv=4, hd=64, ff=2048, window=None):
    return LayerSpec("attn+ffn", h, nh, nkv, hd, ff, window=window)


def test_decode_ctx_sum_closed_form():
    # closed form == explicit loop, with and without SWA window
    for s_in, s_out, win in [(100, 50, None), (100, 50, 64), (10, 200, 64),
                             (5, 3, 1000)]:
        expect = sum(min(s_in + t, win) if win else (s_in + t)
                     for t in range(1, s_out + 1))
        got = roofline._decode_ctx_sum(s_in, s_out, win)
        assert got == pytest.approx(expect), (s_in, s_out, win)


def test_roofline_latency_is_max_of_terms():
    l = dense_layer()
    dev = effective(L4)
    for op in roofline.layer_op_costs(l, "prefill", 4, 256, 64, 1):
        lat = op.latency(dev)
        assert lat == pytest.approx(
            max(op.flops / dev.flops_bf16, op.scan_bytes / dev.mem_bw))


def test_prefill_flops_quadratic_in_seq():
    l = dense_layer()
    f1 = roofline.layer_flops(l, "prefill", 1, 1024, 0, 1)
    f2 = roofline.layer_flops(l, "prefill", 1, 2048, 0, 1)
    # attention term quadruples, projections double => 2x < ratio < 4x
    assert 2.0 < f2 / f1 < 4.0


def test_swa_caps_decode_attention():
    def attn_flops(l):
        ops = roofline.layer_op_costs(l, "decode", 1, 8192, 256, 1)
        return next(o.flops for o in ops if o.name == "attention")
    f_full = attn_flops(dense_layer())
    f_swa = attn_flops(dense_layer(window=128))
    assert f_swa < f_full * 0.05     # window 128 vs ~8k context


def test_moe_flops_active_not_total():
    moe = LayerSpec("attn+moe", 512, 8, 4, 64, 256, n_experts=16, top_k=2)
    dense_equal = LayerSpec("attn+ffn", 512, 8, 4, 64, 256 * 2)
    f_moe = roofline.layer_flops(moe, "prefill", 2, 512, 0, 1)
    f_dense = roofline.layer_flops(dense_equal, "prefill", 2, 512, 0, 1)
    # active-expert FFN ~= dense with top_k*d_ff (router adds a little)
    assert f_moe == pytest.approx(f_dense, rel=0.1)


def test_tp_divides_compute():
    l = dense_layer()
    f1 = roofline.layer_flops(l, "prefill", 2, 512, 0, 1)
    f4 = roofline.layer_flops(l, "prefill", 2, 512, 0, 4)
    assert f4 == pytest.approx(f1 / 4)


def _placement(spec, insts=("g6e.xlarge", "g6.12xlarge")):
    inst = [AWS_INSTANCES[n] for n in insts]
    half = spec.n_layers // 2
    stages = (Stage(inst[0], 1, half, first=True),
              Stage(inst[1], 4, spec.n_layers - half, last=True))
    return Placement(spec, stages)


def test_estimate_pipeline_monotone_batch_latency():
    spec = uniform_decoder("m", 8, 512, 8, 4, 2048, 32000)
    p = _placement(spec)
    lat = []
    for b in (1, 4, 16):
        pre, dec = estimator.stage_latencies(spec, p, b, 256, 64)
        lat.append(max(pre) + max(dec))
    assert lat[0] < lat[1] < lat[2]


def test_throughput_improves_with_batch():
    spec = uniform_decoder("m", 8, 512, 8, 4, 2048, 32000)
    p = _placement(spec)
    r1 = estimate(spec, p, 256, 64, batch=1).throughput_rps
    r16 = estimate(spec, p, 256, 64, batch=16).throughput_rps
    assert r16 > r1 * 2     # batching efficiency (paper §4.2.2)


def test_max_batch_respects_memory():
    spec = uniform_decoder("m", 8, 512, 8, 4, 2048, 32000)
    p = _placement(spec)
    b = max_batch_size(spec, p, 256, 64, cap=1 << 20)
    assert b > 0
    # longer contexts pin more KV per request => smaller feasible batch
    b_long = max_batch_size(spec, p, 4096, 64, cap=1 << 20)
    assert b_long < b


def test_ssm_batch_independent_of_context():
    from repro.configs import get_config
    spec = get_config("mamba2-1.3b").to_modelspec()
    inst = AWS_INSTANCES["g6e.xlarge"]
    stages = (Stage(inst, 1, spec.n_layers, first=True, last=True),)
    p = Placement(spec, stages)
    b_short = max_batch_size(spec, p, 256, 64, cap=1 << 20)
    b_long = max_batch_size(spec, p, 16384, 2048, cap=1 << 20)
    assert b_short > 0 and b_long > 0
    # attention-free: only activations scale with s_in. A dense model of the
    # same width collapses much harder under long contexts.
    dense = uniform_decoder("d", spec.n_layers, 2048, 16, 8, 8192, 50280)
    pd = Placement(dense, (Stage(inst, 1, dense.n_layers, first=True,
                                 last=True),))
    d_short = max_batch_size(dense, pd, 256, 64, cap=1 << 20)
    d_long = max_batch_size(dense, pd, 16384, 2048, cap=1 << 20)
    ssm_ratio = b_long / b_short
    dense_ratio = (d_long / d_short) if d_short else 0.0
    assert ssm_ratio > dense_ratio * 3


def test_eq5_latency_is_bottleneck_sum():
    spec = uniform_decoder("m", 8, 512, 8, 4, 2048, 32000)
    p = _placement(spec)
    perf = estimate(spec, p, 256, 64, batch=4)
    pre, dec = estimator.stage_latencies(spec, p, 4, 256, 64)
    assert perf.throughput_rps == pytest.approx(
        4.0 / (max(pre) + max(dec)))
