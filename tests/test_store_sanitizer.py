"""TensorStore shadow-ledger sanitizer: typed invariant errors
(double-evict, pinned-evict, refcount underflow), divergence crosscheck,
env arming via REPRO_KV_SANITIZE, and the on_transfer byte-movement hook."""

import numpy as np
import pytest

from repro.serving.tensor_store import (DoubleEvictError, PinnedEvictError,
                                        RefcountUnderflowError,
                                        StoreSanitizerError, TensorStore)


def _params(scale=1.0):
    return {"w": np.ones((4, 4), np.float32) * scale}   # 64 bytes


def test_clean_lifecycle_under_sanitizer():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    got = st.attach("m", "p0")
    assert got["w"].shape == (4, 4)
    st.detach("m", "p0")
    p, _ = st.load("m", "p1", _params)
    assert p is not None
    st.detach("m", "p1")
    st.put_or_attach("m", "p0", _params)      # hit path
    st.detach("m", "p0")
    assert st.take("m", "p1")["w"].sum() == 16
    assert st.evict_unreferenced() == 1       # p0
    assert st.check_consistent()


def test_budgeted_eviction_stays_clean():
    st = TensorStore(budget_bytes=128, sanitize=True)
    for i in range(4):
        st.put("m", f"p{i}", _params())
    assert st.resident_bytes() <= 128
    st.evict_to(0)
    assert st.resident_bytes() == 0


def test_detach_underflow_raises_when_armed():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    with pytest.raises(RefcountUnderflowError):
        st.detach("m", "p0")                  # never attached
    st.attach("m", "p0")
    st.detach("m", "p0")
    with pytest.raises(RefcountUnderflowError):
        st.detach("m", "p0")                  # second detach underflows


def test_detach_underflow_tolerated_when_disarmed():
    st = TensorStore(sanitize=False)
    st.put("m", "p0", _params())
    st.detach("m", "p0")                      # legacy tolerant no-op
    assert st.refcount("m", "p0") == 0


def test_double_evict_raises():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    st._drop(("m", "p0"))
    with pytest.raises(DoubleEvictError):
        st._drop(("m", "p0"))


def test_attach_after_evict_raises_double_evict():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    st.evict_unreferenced()
    with pytest.raises(DoubleEvictError):
        st.attach("m", "p0")


def test_pinned_evict_raises():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    st.attach("m", "p0")
    with pytest.raises(PinnedEvictError):
        st._drop(("m", "p0"))
    # the public eviction paths respect the pin and stay clean
    assert st.evict_unreferenced() == 0
    assert st.evict_to(0) == 0
    assert st.take("m", "p0") is None


def test_divergence_detected_on_next_op():
    st = TensorStore(sanitize=True)
    st.put("m", "p0", _params())
    st._refcount[("m", "p0")] += 1            # bug behind the ledger's back
    with pytest.raises(StoreSanitizerError, match="refcount"):
        st.put("m", "p1", _params())


def test_env_arms_sanitizer(monkeypatch):
    monkeypatch.setenv("REPRO_KV_SANITIZE", "1")
    assert TensorStore().sanitize
    monkeypatch.setenv("REPRO_KV_SANITIZE", "0")
    assert not TensorStore().sanitize
    monkeypatch.delenv("REPRO_KV_SANITIZE")
    assert not TensorStore().sanitize


def test_on_transfer_hook_accounts_bytes():
    moved = []
    st = TensorStore(sanitize=True,
                     on_transfer=lambda kind, n: moved.append((kind, n)))
    st.put("m", "p0", _params())
    st.put("m", "p1", _params())
    st.take("m", "p0")
    assert moved == [("put", 64), ("put", 64), ("take", 64)]
    # misses don't fire the hook
    assert st.take("m", "absent") is None
    assert len(moved) == 3
