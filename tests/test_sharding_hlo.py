"""Sharding rule resolution + HLO collective parsing."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import hlo_utils
from repro.sharding import rules as R


def fake_mesh(shape=(2, 4), axes=("data", "model")):
    devs = np.array(jax.devices() * (int(np.prod(shape)) // len(
        jax.devices()) + 1))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def test_resolve_divisible():
    mesh = fake_mesh()
    spec = R.resolve(("batch", None, "heads", None), (8, 16, 8, 64),
                     R.INFER_RULES, mesh)
    assert spec == P("data", None, "model", None)


def test_resolve_drops_nondivisible():
    mesh = fake_mesh()
    # 14 heads % 4 != 0 -> replicate; batch 7 % 2 != 0 -> replicate
    spec = R.resolve(("batch", None, "heads", None), (7, 16, 14, 64),
                     R.INFER_RULES, mesh)
    assert spec == P(None, None, None, None)


def test_resolve_no_double_axis_use():
    mesh = fake_mesh()
    rules = dict(R.INFER_RULES, cache_seq=("model",))
    spec = R.resolve(("layers", "batch", "cache_seq", "kv_heads", None),
                     (24, 8, 1024, 8, 64), rules, mesh)
    # model axis consumed by cache_seq; kv_heads must NOT reuse it
    flat = []
    for e in spec:
        if isinstance(e, tuple):
            flat.extend(e)
        elif e:
            flat.append(e)
    assert flat.count("model") == 1


def test_resolve_multi_axis_batch():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = R.resolve(("batch", None), (8, 16), R.TRAIN_RULES_MULTIPOD, mesh)
    assert spec[0] == ("pod", "data")


SAMPLE_HLO = """
HloModule test

%region_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %ar = f32[8,8]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add.1
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%region_cond (p2: (s32[], f32[8,8])) -> pred[] {
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ag = f32[16,8]{1,0} all-gather(%a), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
  %w = (s32[], f32[8,8]) while(%init), condition=%region_cond, body=%region_body
  %cp = f32[8,8]{1,0} collective-permute(%a), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""


def test_collective_parse_kinds_and_weighting():
    out = hlo_utils.collective_bytes(SAMPLE_HLO, trip_hints=(10,))
    # all-gather: out 16*8*4=512B, P=2 => wire 512*(1/2)=256
    assert out["all-gather"] == pytest.approx(256.0)
    # all-reduce in while body, trips 10: out 8*8*4=256B, P=4 =>
    # wire 2*256*(3/4)=384 per exec, x10
    assert out["all-reduce"] == pytest.approx(3840.0)
    # collective-permute: one hop of 256B
    assert out["collective-permute"] == pytest.approx(256.0)
    assert out["counts"]["all-reduce"] == 10


def test_collective_parse_no_entry_fallback():
    txt = "%x = f32[4]{0} all-reduce(%y), replica_groups=[1,4]<=[4]"
    out = hlo_utils.collective_bytes(txt)
    assert out["all-reduce"] > 0
