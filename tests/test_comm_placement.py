"""Comm model (Eqs. 2-3) + placement optimizer (Algorithm 1) tests."""

import math

import pytest

from repro.core import comm
from repro.core.cluster_opt import populate_cluster
from repro.core.modelspec import uniform_decoder
from repro.core.objective import Objective
from repro.core.placement import (PlacementOptimizer, exhaustive_search,
                                  stage_options_for)
from repro.hw.profiles import AWS_INSTANCES


def test_ring_allreduce_closed_form():
    link = comm.Link(1e-5, 1e9)
    n, p = 1 << 20, 4
    t = comm.ring_allreduce(n, p, link)
    expect = 2 * (1e-5 + (n / p) / 1e9) * (p - 1)
    assert t == pytest.approx(expect)


def test_eq3_tp_comm():
    # Eq 3: 4*(alpha + BSHE/(D*beta))*(D-1)*l
    link = comm.Link(5e-6, 32e9)
    b, s, h, d, l, e = 2, 128, 512, 4, 8, 2
    t = comm.tp_comm_latency(b, s, h, d, l, link, e)
    n = b * s * h * e
    expect = 4 * (5e-6 + (n / d) / 32e9) * (d - 1) * l
    assert t == pytest.approx(expect)


def test_tp1_no_comm():
    link = comm.Link(5e-6, 32e9)
    assert comm.tp_comm_latency(2, 128, 512, 1, 8, link) == 0.0


def small_problem(n_layers=6):
    spec = uniform_decoder("tiny", n_layers, 256, 4, 2, 512, 1000)
    inv = {"g6e.xlarge": 2, "g6.12xlarge": 1}
    return spec, inv, dict(AWS_INSTANCES)


def test_dp_beam_matches_exhaustive_on_tiny():
    spec, inv, insts = small_problem(4)
    obj = Objective()
    ex = exhaustive_search(spec, inv, insts, 128, 32, obj, max_stages=3)
    dp = PlacementOptimizer(spec, inv, insts, 128, 32, objective=obj,
                            beam_k=8, max_stages=3).search()
    assert dp.placement is not None and ex.placement is not None
    # beam search should find a placement within 2% of exhaustive optimum
    assert dp.score >= ex.score * 0.98, (dp.score, ex.score)


def test_placement_covers_all_layers_and_inventory():
    spec, inv, insts = small_problem(6)
    res = PlacementOptimizer(spec, inv, insts, 128, 32, beam_k=3).search()
    p = res.placement
    assert p is not None
    assert sum(s.n_layers for s in p.stages) == spec.n_layers
    used = {}
    for s in p.stages:
        used[s.instance.name] = used.get(s.instance.name, 0) + s.tp
    for name, devs in used.items():
        assert devs <= inv[name] * insts[name].num_devices


def test_beam_width_monotone_score():
    spec, inv, insts = small_problem(6)
    scores = [PlacementOptimizer(spec, inv, insts, 128, 32,
                                 beam_k=k).search().score
              for k in (1, 4)]
    assert scores[1] >= scores[0] - 1e-12


def test_objective_slo_penalty():
    from repro.core.estimator import PerfEstimate, Placement, Stage
    spec, inv, insts = small_problem(4)
    stages = (Stage(insts["g6e.xlarge"], 1, 4, first=True, last=True),)
    placement = Placement(spec, stages)
    perf = PerfEstimate(4, [0.1], [1.0], 0.1, 0.01, 2.0, 2.0)
    base = Objective(gamma=0.0).score(placement, perf)
    soft = Objective(gamma=0.5, slo_s=1.0).score(placement, perf)
    hard = Objective(gamma=math.inf, slo_s=1.0).score(placement, perf)
    assert base > soft > hard == 0.0


def test_populate_cluster_fault_isolation():
    """No instance may serve two pipelines (paper §4.2.1)."""
    spec, _, insts = small_problem(6)
    inv = {"g6e.xlarge": 3, "g6.12xlarge": 2}
    plan = populate_cluster(spec, inv, insts, 128, 32, beam_k=2,
                            max_pipelines=8)
    assert len(plan.pipelines) >= 1
    # count whole instances consumed per type <= inventory
    total = {}
    for p in plan.pipelines:
        used = {}
        for s in p.stages:
            used[s.instance.name] = used.get(s.instance.name, 0) + s.tp
        for n, d in used.items():
            total[n] = total.get(n, 0) + math.ceil(
                d / insts[n].num_devices)
    for n, c in total.items():
        assert c <= inv[n], (n, c, inv[n])


def test_weights_sum_to_one():
    spec, _, insts = small_problem(6)
    inv = {"g6e.xlarge": 3, "g6.12xlarge": 2}
    plan = populate_cluster(spec, inv, insts, 128, 32, beam_k=2)
    if plan.pipelines:
        assert sum(plan.weights()) == pytest.approx(1.0)


def test_stage_options_power_of_two_tp():
    opts = stage_options_for([AWS_INSTANCES["g6.12xlarge"]])
    tps = sorted(o.tp for o in opts)
    assert tps == [1, 2, 4]
