import os

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process). Smoke tests run real compute on the single CPU device.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
