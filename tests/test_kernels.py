"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.chunk_attention import (chunk_attention,
                                           chunk_attention_paged)
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_paged)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.models import attention as mattn

RNG = np.random.RandomState(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,sk,nh,nkv,d,causal,window",
    [
        (1, 128, 128, 4, 4, 64, True, None),     # MHA causal
        (2, 256, 256, 4, 2, 64, True, None),     # GQA
        (1, 128, 128, 6, 6, 64, True, 32),       # SWA
        (2, 128, 256, 8, 2, 128, False, None),   # cross-ish, d=128
        (1, 384, 384, 2, 1, 32, True, None),     # odd head_dim/backup
    ])
def test_flash_attention_sweep(b, sq, sk, nh, nkv, d, causal, window, dtype):
    q = jnp.asarray(RNG.randn(b, sq, nh, d), dtype)
    k = jnp.asarray(RNG.randn(b, sk, nkv, d), dtype)
    v = jnp.asarray(RNG.randn(b, sk, nkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nh,nkv,d,window,vecpos",
    [
        (2, 256, 4, 2, 64, None, False),
        (3, 128, 6, 6, 64, 32, True),
        (2, 256, 8, 2, 128, None, True),
        (1, 512, 2, 1, 32, None, False),
    ])
def test_decode_attention_sweep(b, s, nh, nkv, d, window, vecpos, dtype):
    q = jnp.asarray(RNG.randn(b, 1, nh, d), dtype)
    ck = jnp.asarray(RNG.randn(b, s, nkv, d), dtype)
    cv = jnp.asarray(RNG.randn(b, s, nkv, d), dtype)
    pos = (jnp.asarray(RNG.randint(1, s, (b,)), jnp.int32) if vecpos
           else jnp.asarray(s - 1, jnp.int32))
    out = decode_attention(q, ck, cv, pos, window=window, interpret=True)
    ref = kref.decode_attention_ref(q, ck, cv, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,c,s,nh,nkv,d,window,vecbase",
    [
        (2, 128, 256, 4, 4, 64, None, False),    # MHA, scalar base
        (2, 128, 256, 4, 2, 64, None, True),     # GQA, per-row bases
        (1, 128, 256, 6, 6, 64, 32, False),      # SWA
        (2, 256, 512, 8, 2, 128, None, True),    # GQA, d=128, 2 q-tiles
        (1, 64, 128, 2, 1, 32, None, False),     # sub-tile chunk
    ])
def test_chunk_attention_sweep(b, c, s, nh, nkv, d, window, vecbase, dtype):
    """Flash chunk kernel (linear cache) == jnp chunk oracle across
    GQA/MHA/windowed x scalar-base/per-row-bases."""
    q = jnp.asarray(RNG.randn(b, c, nh, d), dtype)
    ck = jnp.asarray(RNG.randn(b, s, nkv, d), dtype)
    cv = jnp.asarray(RNG.randn(b, s, nkv, d), dtype)
    bases = (jnp.asarray(RNG.randint(0, s - c + 1, (b,)), jnp.int32)
             if vecbase else jnp.asarray(s - c, jnp.int32))
    out = chunk_attention(q, ck, cv, bases, window=window, interpret=True)
    q_pos = (jnp.broadcast_to(bases, (b,))[:, None]
             + jnp.arange(c)[None]).astype(jnp.int32)
    ref = mattn.chunk_attention(q, ck, cv, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def _pool(b, mb, block, nkv, d, dtype):
    """Random block pool + per-row table of distinct pool blocks (block 0
    reserved as trash, never mapped here)."""
    n_blocks = 1 + b * mb
    pk = jnp.asarray(RNG.randn(n_blocks, block, nkv, d), dtype)
    pv = jnp.asarray(RNG.randn(n_blocks, block, nkv, d), dtype)
    tbl = jnp.asarray(RNG.permutation(b * mb).reshape(b, mb) + 1, jnp.int32)
    return pk, pv, tbl


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,c,nh,nkv,d,window,vecbase",
    [
        (2, 128, 4, 4, 64, None, False),         # MHA, scalar base
        (2, 128, 4, 2, 64, None, True),          # GQA, per-row bases
        (1, 128, 6, 6, 64, 32, False),           # SWA
        (2, 64, 8, 2, 128, None, True),          # GQA, d=128, sub-tile
    ])
def test_chunk_attention_paged_sweep(b, c, nh, nkv, d, window, vecbase,
                                     dtype):
    """Flash chunk kernel walking the block pool via scalar-prefetched
    block tables == jnp paged oracle (which gathers a page view)."""
    block, mb = 64, 4                            # virtual length 256
    pk, pv, tbl = _pool(b, mb, block, nkv, d, dtype)
    s_virt = block * mb
    q = jnp.asarray(RNG.randn(b, c, nh, d), dtype)
    bases = (jnp.asarray(RNG.randint(0, s_virt - c + 1, (b,)), jnp.int32)
             if vecbase else jnp.asarray(s_virt - c, jnp.int32))
    out = chunk_attention_paged(q, pk, pv, tbl, bases, window=window,
                                interpret=True)
    q_pos = (jnp.broadcast_to(bases, (b,))[:, None]
             + jnp.arange(c)[None]).astype(jnp.int32)
    ref = mattn.chunk_attention_paged(q, pk, pv, tbl, q_pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_chunk_paged_probe_flags_readable_poison():
    """The kernel's sanitizer probe reports max |K|/|V| over mask-readable
    positions only: poison in a readable block trips the KV_POISON
    threshold, poison parked beyond every query's causal horizon stays
    invisible."""
    from repro.serving.kv_blocks import KV_POISON
    b, c, nh, nkv, d, block, mb = 1, 64, 4, 2, 32, 64, 4
    pk, pv, tbl = _pool(b, mb, block, nkv, d, jnp.float32)
    q = jnp.asarray(RNG.randn(b, c, nh, d), jnp.float32)
    bases = jnp.asarray(0, jnp.int32)        # queries cover block 0 only
    poisoned_hot = pk.at[tbl[0, 0]].set(KV_POISON)
    _, pmax = chunk_attention_paged(q, poisoned_hot, pv, tbl, bases,
                                    probe=True, interpret=True)
    assert float(jnp.max(pmax)) >= KV_POISON
    poisoned_cold = pk.at[tbl[0, 3]].set(KV_POISON)   # unreadable tail
    out, pmax = chunk_attention_paged(q, poisoned_cold, pv, tbl, bases,
                                      probe=True, interpret=True)
    assert float(jnp.max(pmax)) < KV_POISON
    clean = chunk_attention_paged(q, pk, pv, tbl, bases, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(clean),
                               atol=2e-5, rtol=2e-5)


def test_decode_paged_probe_flags_readable_poison():
    """Same probe contract on the paged decode kernel (C=1)."""
    from repro.serving.kv_blocks import KV_POISON
    b, nh, nkv, d, block, mb = 2, 4, 2, 32, 16, 4
    pk, pv, tbl = _pool(b, mb, block, nkv, d, jnp.float32)
    q = jnp.asarray(RNG.randn(b, 1, nh, d), jnp.float32)
    pos = jnp.asarray([block - 1, block * mb - 1], jnp.int32)
    poisoned = pv.at[tbl[0, 2]].set(-KV_POISON)  # row 0 can't read blk 2
    _, pmax = decode_attention_paged(q, pk, poisoned, tbl, pos,
                                     probe=True, interpret=True)
    assert float(jnp.max(pmax)) < KV_POISON
    poisoned = pv.at[tbl[1, 2]].set(-KV_POISON)  # row 1 reads everything
    _, pmax = decode_attention_paged(q, pk, poisoned, tbl, pos,
                                     probe=True, interpret=True)
    assert float(jnp.max(pmax)) >= KV_POISON


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,nh,hd,n,chunk",
    [
        (2, 128, 4, 16, 32, 32),
        (1, 100, 8, 64, 128, 64),    # non-multiple seq (padding path)
        (2, 64, 2, 32, 64, 64),      # single chunk
    ])
def test_ssd_scan_sweep(b, s, nh, hd, n, chunk, dtype):
    x = jnp.asarray(RNG.randn(b, s, nh, hd) * 0.5, dtype)
    dt = jnp.asarray(np.abs(RNG.randn(b, s, nh)) * 0.1 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(nh)) - 0.1, jnp.float32)
    bm = jnp.asarray(RNG.randn(b, s, n) * 0.3, dtype)
    cm = jnp.asarray(RNG.randn(b, s, n) * 0.3, dtype)
    y, h = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, hr = kref.ssd_scan_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               **_tol(dtype))


def test_ssd_chunked_matches_sequential():
    """Chunked SSD algorithm == O(S) sequential recurrence (independent
    second oracle)."""
    b, s, nh, hd, n = 2, 48, 3, 8, 16
    x = jnp.asarray(RNG.randn(b, s, nh, hd) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(b, s, nh)) * 0.1 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(nh)) - 0.1, jnp.float32)
    bm = jnp.asarray(RNG.randn(b, s, n) * 0.3, jnp.float32)
    cm = jnp.asarray(RNG.randn(b, s, n) * 0.3, jnp.float32)
    yc, hc = kref.ssd_scan_ref(x, dt, a, bm, cm, chunk=16)
    ys, hs = kref.ssd_scan_sequential_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs), atol=1e-5)


def test_model_pallas_path_matches_jnp_path():
    """LM with use_pallas=True (interpret) == pure-jnp path end to end."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("internlm2-1.8b").reduced()
    mj = build_model(cfg, remat=False, attn_chunk=0)
    mp = build_model(cfg, remat=False, attn_chunk=0, use_pallas=True)
    params = mj.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(RNG.randint(0, cfg.vocab, (2, 16)), jnp.int32)
    # pallas flash kernel needs block-divisible seq: 16 % block(16 cap) ok
    lj, cj = mj.prefill(params, {"tokens": toks}, max_len=24)
    lp, cp = mp.prefill(params, {"tokens": toks}, max_len=24)
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp), atol=2e-3,
                               rtol=1e-2)
