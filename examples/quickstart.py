"""Quickstart: the ShuntServe pipeline in five steps.

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

from repro.configs import get_config
from repro.core import Objective, PlacementOptimizer, estimate
from repro.hw import AWS_INSTANCES, effective, paper_cluster
from repro.models import build_model
from repro.serving import Engine, ServeRequest

# 1. Pick an architecture (any of the 10 assigned + the paper's models).
cfg = get_config("llama-3.1-70b")
spec = cfg.to_modelspec()
print(f"model: {cfg.name} ({spec.params_total()/1e9:.1f}B params)")

# 2. Calibrated heterogeneous instance profiles (paper Table 1 + §7.1.5).
insts = {n: dataclasses.replace(i, device=effective(i.device))
         for n, i in AWS_INSTANCES.items()}

# 3. Find the throughput-per-cost-optimal placement (Algorithm 1).
opt = PlacementOptimizer(spec, paper_cluster(), insts, s_in=763, s_out=232,
                         objective=Objective(), beam_k=1, max_stages=6)
res = opt.search()
print(f"placement: {res.placement.describe()}")
print(f"  est. throughput {res.throughput_rps:.2f} req/s at batch "
      f"{res.batch}, search took {res.wall_time_s:.1f}s")

# 4. Estimate serving metrics for the chosen placement (Eqs. 1-5).
perf = estimate(spec, res.placement, 763, 232)
print(f"  TTFT {perf.ttft_s:.3f}s  TPOT {perf.tpot_s*1000:.1f}ms  "
      f"cost ${res.placement.price_hr(spot=True):.2f}/h (spot)")

# 5. Actually generate tokens with the real engine (reduced config on CPU).
rcfg = cfg.reduced()
model = build_model(rcfg, remat=False, attn_chunk=0)
params = model.init(jax.random.PRNGKey(0))
eng = Engine(rcfg, params, max_batch=2, max_len=64)
req = ServeRequest(prompt=[5, 3, 11, 27], max_new_tokens=10)
eng.admit(req)
eng.drain()
print(f"generated tokens: {req.generated}")
