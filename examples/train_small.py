"""Train a ~small LM for a few hundred steps with checkpoint/restart —
the training-substrate end-to-end driver.

  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import sys

sys.argv = [sys.argv[0], "--arch", "internlm2-1.8b", "--reduced",
            "--steps", "200", "--batch", "8", "--seq", "128",
            "--microbatches", "2", "--ckpt-dir", "/tmp/repro_train_small",
            "--ckpt-every", "50"] + sys.argv[1:]

from repro.launch.train import main

main()
