"""End-to-end driver: serve a small model with batched requests through the
global server, inject a spot interruption mid-flight, and show that
output-preserving migration + the shared tensor store keep every request's
generated output intact (paper §5).

  PYTHONPATH=src python examples/serve_spot_cluster.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import GlobalServer, ServeRequest, TensorStore

cfg = get_config("qwen2-0.5b").reduced()
model = build_model(cfg, remat=False, attn_chunk=0)
params = model.init(jax.random.PRNGKey(0))

store = TensorStore()
# prefill_chunk: long migration-recompute contexts admit chunk-by-chunk
# between decode steps instead of stalling live slots; use_kv_migration:
# interrupted requests publish their KV blocks to the store and re-attach
# on the surviving pipeline instead of recomputing (§5.1 x §5.2)
srv = GlobalServer(cfg, store, max_batch=3, max_len=96, prefill_chunk=16,
                   use_kv_migration=True)
srv.add_pipeline(params, ["spot-a1", "spot-a2"], weight=2.0)
srv.add_pipeline(params, ["spot-b1"], weight=1.0)

rng = np.random.RandomState(1)
reqs = [ServeRequest(prompt=rng.randint(0, cfg.vocab, 5).tolist(),
                     max_new_tokens=14) for _ in range(8)]
for r in reqs:
    srv.submit(r)

# serve a few rounds, snapshot progress, then the provider reclaims spot-a1
for _ in range(4):
    srv.step()
snapshot = {r.rid: list(r.generated) for r in reqs}
in_flight = sum(1 for r in reqs if r.generated and not r.done)
print(f"before interruption: {in_flight} requests mid-generation")

affected = srv.interrupt_instance("spot-a1")
published = sum(1 for _, k, _ in srv.events if k == "kv_publish")
print(f"spot-a1 reclaimed -> {len(affected)} requests migrated "
      f"({published} KV block sets published to the store, rest recompute)")

srv.run_until_drained()
ok = all(list(r.generated)[:len(snapshot[r.rid])] == snapshot[r.rid]
         for r in reqs)
print(f"all {len(reqs)} requests finished; "
      f"pre-interruption outputs preserved verbatim: {ok}")
print(f"tensor store refcounts kept weights resident: "
      f"{[store.refcount(cfg.name, f'full/p{i}') for i in range(2)]}")
print("events:", [(round(t, 2), k, d) for t, k, d in srv.events])
for p in srv.pipelines:
    s = p.engine.stats
    print(f"p{p.pid} engine: {s.prefills} prefills in "
          f"{s.prefill_batches} batches + {s.prefill_chunks} chunks, "
          f"{s.kv_imports} KV attaches, {s.prefill_retraces} prefill "
          f"traces, {s.tokens_out} tokens; blocks {p.engine.block_stats()}")
