"""Compare placement algorithms on a heterogeneous cluster (paper Fig 9).

  PYTHONPATH=src python examples/placement_search.py [arch]
"""

import dataclasses
import sys

from repro.cluster import ClusterSim, FTConfig, azure_conversation_like
from repro.configs import get_config
from repro.core import populate_cluster
from repro.core.baselines import alpaserve_dp, hexgen_genetic, vllm_even
from repro.hw import AWS_INSTANCES, effective, paper_cluster

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-32b"
spec = get_config(arch).to_modelspec()
insts = {n: dataclasses.replace(i, device=effective(i.device))
         for n, i in AWS_INSTANCES.items()}
inv = paper_cluster()

plans = {
    "shuntserve": populate_cluster(spec, inv, insts, 763, 232, beam_k=2),
    "hexgen": hexgen_genetic(spec, inv, insts, 763, 232, pop_size=10,
                             generations=6),
    "alpaserve": alpaserve_dp(spec, inv, insts, 763, 232),
    "vllm": vllm_even(spec, inv, insts, 763, 232),
}
reqs = azure_conversation_like(duration_s=240, rate_rps=4.67, seed=0)
print(f"offline throughput on the paper's 24-GPU cluster ({arch}):")
for name, plan in plans.items():
    if not plan.pipelines:
        print(f"  {name:12s} -- infeasible")
        continue
    sim = ClusterSim(spec, plan.pipelines, FTConfig(use_spot=True))
    rps = sim.run(reqs, duration_s=240, offline=True).rps
    print(f"  {name:12s} {rps:5.2f} req/s   "
          f"({len(plan.pipelines)} pipelines, "
          f"${plan.price_hr(True):.2f}/h spot)")
