"""Placement-search speed — wall time and evaluations/sec of the DP+beam
optimizer (Alg. 1) on the paper's 24-GPU cluster and a many-type
heterogeneous cluster, at beam widths k in {1, 3, 8}.

This tracks the perf trajectory of the prefix-sum evaluation engine across
PRs: re-planning latency adds directly to spot-migration downtime (paper
§5; SpotServe/ThunderServe make the same point), so search wall time is a
first-class serving metric, not just an offline convenience.
"""

from __future__ import annotations

from typing import Dict

from benchmarks.common import (Rows, effective_instances, full_mode,
                               paper_inventory, save_json)
from repro.configs import get_config
from repro.core.placement import PlacementOptimizer


def run(rows: Rows) -> Dict:
    insts = effective_instances()
    out: Dict = {}
    clusters = {"24gpu_3type": (paper_inventory(), (1, 3, 8))}
    # the many-type cluster at k=8 is the paper's stress case; keep the
    # fast tier bounded at k<=3 unless REPRO_FULL=1
    manytype_ks = (1, 3, 8) if full_mode() else (1, 3)
    clusters["manytype"] = ({n: 1 for n in insts}, manytype_ks)
    for cluster_name, (inv, ks) in clusters.items():
        for arch in ("qwen3-32b", "llama-3.1-70b"):
            spec = get_config(arch).to_modelspec()
            series = []
            for k in ks:
                opt = PlacementOptimizer(spec, inv, insts, 763, 232,
                                         beam_k=k, max_stages=6)
                res = opt.search()
                evals_per_s = (res.evaluated / res.wall_time_s
                               if res.wall_time_s > 0 else 0.0)
                series.append({"k": k, "wall_s": res.wall_time_s,
                               "evaluated": res.evaluated,
                               "evals_per_s": evals_per_s,
                               "score": res.score,
                               "rps": res.throughput_rps})
                rows.add(f"search_speed/{cluster_name}/{arch}/k{k}",
                         res.wall_time_s * 1e6,
                         f"evals={res.evaluated} "
                         f"evals_per_s={evals_per_s:.0f} "
                         f"rps={res.throughput_rps:.3f}")
            out[f"{cluster_name}/{arch}"] = series
    save_json("search_speed.json", out)
    return out
