"""Paper Figs 13, 14, 15 / §7.2 — serving under the worst-case 50-minute
spot availability scenario: offline throughput, temporal online latency, and
cost efficiency for the five fault-tolerance variants."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import (Rows, calibrate_sim_efficiency,
                               effective_instances, full_mode,
                               paper_inventory, save_json)
from repro.cluster import (ClusterSim, FTConfig, azure_conversation_like,
                           generate_trace, interruption_events_for_window,
                           select_scenario)
from repro.cluster.spot_trace import PAPER_POOLS
from repro.configs import get_config
from repro.core import populate_cluster

VARIANTS = {
    "ondemand": FTConfig(use_spot=False),
    "nohandle": FTConfig(request_migration=False, concurrent_init=False),
    "request_migration": FTConfig(concurrent_init=False),
    "concurrent_init": FTConfig(request_migration=False),
    "shuntserve": FTConfig(),
}

WINDOW_MIN = 50


def scenario_events():
    trace = generate_trace(PAPER_POOLS, minutes=4320 if full_mode() else 1440,
                           seed=7)
    # score over the pools the evaluation cluster actually uses (§7.2)
    pools = list(paper_inventory())
    start, score, zero_frac = select_scenario(trace, dur_min=WINDOW_MIN,
                                              pools=pools)
    events = [e for e in interruption_events_for_window(
        trace, start, WINDOW_MIN) if e[1] in pools]
    return events, {"window_start_min": start, "score": score,
                    "zero_score_fraction": zero_frac,
                    "n_events": len(events)}


def run(rows: Rows) -> Dict:
    insts = effective_instances()
    inv = paper_inventory()
    events, scen_meta = scenario_events()
    rows.add("spot_scenario/selected", scen_meta["score"],
             f"zero_frac={scen_meta['zero_score_fraction']:.2f} "
             f"events={scen_meta['n_events']} (paper: 40.4pct zero)")
    duration = WINDOW_MIN * 60.0
    out: Dict = {"scenario": scen_meta, "offline": {}, "online": {},
                 "cost": {}}
    paper_rps = {"llama-3.1-70b": 1.53, "qwen3-32b": 4.59}
    for arch, online_rate in (("llama-3.1-70b", 0.8), ("qwen3-32b", 2.4)):
        spec = get_config(arch).to_modelspec()
        plan = populate_cluster(spec, inv, insts, 763, 232, beam_k=2)
        eff = calibrate_sim_efficiency(spec, plan.pipelines,
                                       paper_rps[arch])
        reqs_off = azure_conversation_like(duration_s=duration,
                                           rate_rps=4.67, seed=0)
        reqs_on = azure_conversation_like(duration_s=duration,
                                          rate_rps=online_rate, seed=1)
        off, on, cost = {}, {}, {}
        for name, ft in VARIANTS.items():
            ev = () if not ft.use_spot else events
            sim = ClusterSim(spec, plan.pipelines, ft, efficiency=eff)
            r = sim.run(reqs_off, duration_s=duration, events=ev,
                        offline=True)
            off[name] = {"rps": r.rps, "cost_usd": r.cost_usd,
                         "downtime_s": sum(r.downtime_s.values()),
                         "interruptions": r.interruptions}
            sim2 = ClusterSim(spec, plan.pipelines, ft, efficiency=eff)
            r2 = sim2.run(reqs_on, duration_s=duration, events=ev)
            # temporal 5-min trailing moving average of e2e latency (Fig 14)
            pts = sorted((x.finish_s, x.finish_s - x.req.arrival_s)
                         for x in r2.completed)
            temporal = []
            for t in np.arange(300, duration + 1, 150):
                win = [l for ts, l in pts if t - 300 <= ts <= t]
                if win:
                    temporal.append({"t": float(t),
                                     "mean": float(np.mean(win)),
                                     "p90": float(np.percentile(win, 90))})
            on[name] = {"mean_e2e": r2.mean("e2e"),
                        "p90_e2e": r2.percentile("e2e", 0.9),
                        "cost_usd": r2.cost_usd,
                        "temporal": temporal}
            cost[name] = r.cost_usd
        out["offline"][arch] = off
        out["online"][arch] = on
        out["cost"][arch] = cost
        rows.add(f"fault_tolerance/{arch}/offline_rps",
                 off["shuntserve"]["rps"] * 1e6,
                 "ondemand=%.2f nohandle=%.2f rm=%.2f ci=%.2f shunt=%.2f" % (
                     off["ondemand"]["rps"], off["nohandle"]["rps"],
                     off["request_migration"]["rps"],
                     off["concurrent_init"]["rps"],
                     off["shuntserve"]["rps"]))
        rows.add(f"fault_tolerance/{arch}/online_mean_e2e_s",
                 on["shuntserve"]["mean_e2e"] * 1e6,
                 "nohandle=%.1f shunt=%.1f ondemand=%.1f" % (
                     on["nohandle"]["mean_e2e"],
                     on["shuntserve"]["mean_e2e"],
                     on["ondemand"]["mean_e2e"]))
    save_json("fault_tolerance.json", out)
    return out


def cost_efficiency(out: Dict, rows: Rows) -> Dict:
    """Fig 15: cost per performance normalized to On-demand (lower=better).
    offline: cost/throughput; online: latency x cost."""
    eff: Dict = {}
    for arch in out["offline"]:
        off = out["offline"][arch]
        on = out["online"][arch]
        base_off = off["ondemand"]["cost_usd"] / max(off["ondemand"]["rps"],
                                                     1e-9)
        base_mean = on["ondemand"]["mean_e2e"] * on["ondemand"]["cost_usd"]
        base_p90 = on["ondemand"]["p90_e2e"] * on["ondemand"]["cost_usd"]
        eff[arch] = {}
        for name in off:
            e_off = (off[name]["cost_usd"] / max(off[name]["rps"], 1e-9)
                     ) / base_off
            e_mean = (on[name]["mean_e2e"] * on[name]["cost_usd"]) / base_mean
            e_p90 = (on[name]["p90_e2e"] * on[name]["cost_usd"]) / base_p90
            eff[arch][name] = {"offline": e_off, "online_mean": e_mean,
                               "online_p90": e_p90}
        s = eff[arch]["shuntserve"]
        rows.add(f"cost_efficiency/{arch}/shuntserve_offline_norm",
                 s["offline"] * 1e6,
                 f"reduction={100*(1-s['offline']):.1f}pct vs ondemand "
                 f"(paper: 31.9pct offline / 31.2pct online)")
    save_json("cost_efficiency.json", eff)
    return eff
