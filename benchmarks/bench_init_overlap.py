"""Paper Fig 16 / §7.2.4 — concurrent initialization time breakdown.

Components: node provisioning (virtual, paper-measured distribution), shared
tensor store load (REAL: cold weight materialization into the store), engine
init (REAL: building a fresh Engine attached to store weights — the paper's
key claim is that this needs no weight reload). Reports total vs grace
period and the store-attach speedup."""

from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import Rows, save_json
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, FTTimes, GlobalServer, TensorStore


def run(rows: Rows) -> Dict:
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    ft = FTTimes()

    # store load (cold): init + commit weights
    store = TensorStore()
    t0 = time.perf_counter()
    params = model.init(jax.random.PRNGKey(0))
    jax.block_until_ready(jax.tree.leaves(params)[0])
    store.put(cfg.name, "full", params)
    t_store_cold = time.perf_counter() - t0

    # engine init WITHOUT store first (fresh weight materialization) so the
    # attach path cannot borrow its compilation warm-up
    t0 = time.perf_counter()
    params2 = model.init(jax.random.PRNGKey(0))
    jax.block_until_ready(jax.tree.leaves(params2)[0])
    Engine(cfg, params2, max_batch=2, max_len=64)
    t_engine_cold = time.perf_counter() - t0

    # engine init WITH store (attach, no weight reload)
    t0 = time.perf_counter()
    attached = store.attach(cfg.name, "full")
    Engine(cfg, attached, max_batch=2, max_len=64)
    t_engine_attach = time.perf_counter() - t0

    # virtual-clock downtime: CI vs sequential (paper components)
    ci_total = ft.node_provision_s + max(ft.store_load_s, ft.engine_init_s)
    seq_total = ft.node_provision_s + ft.store_load_s + ft.engine_init_s
    downtime_ci = max(0.0, ci_total - ft.grace_period_s)
    downtime_seq = (max(ft.grace_period_s, ft.node_provision_s)
                    + ft.store_load_s + ft.engine_init_s
                    - ft.grace_period_s)

    out = {
        "paper_components_s": {"provision": ft.node_provision_s,
                               "store_load": ft.store_load_s,
                               "engine_init": ft.engine_init_s,
                               "grace": ft.grace_period_s},
        "ci_total_s": ci_total, "sequential_total_s": seq_total,
        "downtime_ci_s": downtime_ci, "downtime_seq_s": downtime_seq,
        "measured_local": {"store_cold_s": t_store_cold,
                           "engine_attach_s": t_engine_attach,
                           "engine_cold_s": t_engine_cold,
                           "attach_speedup": t_engine_cold
                           / max(t_engine_attach, 1e-9)},
    }
    rows.add("init_overlap/ci_total_s", ci_total * 1e6,
             f"downtime_ci={downtime_ci:.1f}s vs seq={downtime_seq:.1f}s "
             f"(paper: 111.3s total, near-zero downtime in 120s grace)")
    rows.add("init_overlap/engine_attach_speedup",
             t_engine_attach * 1e6,
             f"cold={t_engine_cold:.3f}s attach={t_engine_attach:.3f}s "
             f"speedup={out['measured_local']['attach_speedup']:.1f}x")
    save_json("init_overlap.json", out)
    return out
