"""CI benchmark-smoke gate: parse a ``benchmarks.run`` Rows CSV and fail
the build when a protected performance floor regresses.

  python -m benchmarks.check_smoke <rows.csv> [--baseline baselines.csv]

With ``--baseline``, additionally compare the TRACKED derived metrics
against a committed baseline CSV (same Rows format) and fail on any >20%
regression — trend tracking on top of the static floors below. Only
deterministic count-based ratios are tracked (admission capacity ratios,
prefill-token reduction, the routing $/token ratio): wall-time rows vary
with the CI machine and would flake; the static time budgets still bound
them. See benchmarks/README.md for re-baselining.

Enforced floors:
  * paper-cluster qwen3-32b placement search <= 10 s at every beam width
    (protects the PR-1 prefix-sum engine's 27x win);
  * bucketed admission >= 5x the seed (legacy) engine on the mixed-length
    32-request workload, with prefill traces bounded by the bucket count
    (protects the PR-2 shape-stable execution plane);
  * paged KV layout admits >= 1.5x the concurrent mixed-length requests of
    contig at equal cache bytes, paged decode tok/s within 20% of contig,
    and recovery decide() picks kv_restore when the store holds the blocks
    (protects the paged-KV refactor, bench_kv_paging.py);
  * demand-paged (lazy) allocation admits >= 1.2x the concurrent
    mixed-length requests of upfront reservation at equal pool bytes, with
    byte-identical greedy outputs across the grow and preempt/re-admit
    paths (protects the reservation-ledger refactor);
  * prefix sharing at a 0.5 share-ratio workload admits >= 1.5x the
    no-sharing engine at a tight pool OR cuts warm prefill tokens >= 40%,
    with byte-identical greedy outputs sharing on vs off, and at least one
    pipeline warm-up through the tensor store (protects the prefix-sharing
    KV cache, bench_prefix_share.py);
  * bucket-aware cost dispatch serves the mixed short/long workload at
    <= 0.85x the $/token of uniform dispatch with byte-identical greedy
    outputs, and the histogram $/token objective picks the cheap low-HBM
    instance for short-only traffic but high-HBM for the mixed histogram
    (protects length/cost-aware routing, bench_routing.py);
  * the discrete-event cluster simulator reproduces the closed-form
    metrics (rps / downtime / $) to 1e-6 on an idle topology, charges a
    >= 1.1x downtime penalty when two warm-ups contend for one store
    link, completes the 1000-node 2-region churn scenario (>= 50
    correlated reclaims) inside a wall-clock budget, and keeps the
    all-spot frontier cell cheaper than all-on-demand (protects the DES
    refactor, bench_cluster_sim.py);
  * hot-path kernel dispatches keep oracle-path chunk and decode tok/s
    above CPU-enforceable floors, direct-to-pool chunked prefill cuts
    dispatch count vs the contig transient+scatter baseline with
    byte-identical outputs, and — on a real accelerator only
    (``interp=0``) — the Pallas kernels run >= 1x their jnp oracles
    (protects the flash paged chunk-prefill kernel, bench_kernels.py).
"""

from __future__ import annotations

import re
import sys
from typing import Dict, List, Tuple

SEARCH_BUDGET_S = 10.0        # k<=3 paper-cluster search (PR-1 quoted 3.2s)
SEARCH_BUDGET_K8_S = 40.0     # k=8 stress row (seed took > 80s)
MIN_ADMIT_SPEEDUP = 5.0
MIN_PAGED_CAPACITY_RATIO = 1.5
MIN_LAZY_CAPACITY_RATIO = 1.2         # lazy vs upfront at equal pool bytes
MAX_PAGED_DECODE_REGRESSION = 0.20    # paged tok/s >= 0.8x contig
MIN_PREFIX_CAPACITY_RATIO = 1.5       # share vs no-share at a tight pool
MIN_PREFIX_WARM_REDUCTION = 0.40      # warm prefill-token cut at rho=0.5
MAX_ROUTING_COST_RATIO = 0.85         # bucket-aware $/token vs uniform
MIN_CHUNK_TOK_S = 10_000.0            # oracle paged chunk-attn, CPU floor
MIN_DECODE_TOK_S = 1_000.0            # oracle paged decode, CPU floor
MIN_PALLAS_SPEEDUP = 1.0              # only enforced when interp=0
MIN_CHUNK_DISPATCH_REDUCTION = 1.1    # direct vs transient+scatter ops
PARITY_TOL = 1e-6                     # DES vs closed form, idle topology
MIN_CONTENTION_RATIO = 1.1            # serialized warm-up downtime penalty
MIN_CORRELATED_DROPS = 50             # churn trace must exercise crunches
CHURN_BUDGET_S = 150.0                # 1000-node 30-min churn wall-clock
MIN_FRONTIER_SAVING = 1.0             # all-OD $ / all-spot $ must be > 1

# --baseline trend tracking: (row name, derived key, better direction).
# Deterministic count-based ratios ONLY — wall-time metrics flake across
# CI machines and stay guarded by the static budgets above.
BASELINE_TOLERANCE = 0.20
TRACKED = [
    ("kv_paging/capacity", "ratio", "higher"),
    ("kv_paging/lazy_capacity", "ratio", "higher"),
    ("prefix_share/capacity", "ratio", "higher"),
    ("prefix_share/identity", "reduction", "higher"),
    ("routing/cost", "ratio", "lower"),
    ("kernels/chunk_dispatch", "reduction", "higher"),
    ("cluster_sim/contention", "ratio", "higher"),
    ("cluster_sim/frontier", "saving", "higher"),
]


def parse_rows(text: str) -> List[Tuple[str, float, str]]:
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append((parts[0], us, parts[2] if len(parts) > 2 else ""))
    return rows


def derived_floats(derived: str) -> Dict[str, float]:
    return {k: float(v) for k, v in
            re.findall(r"(\w+)=([-+0-9.eE]+)x?\b", derived)}


def check(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    search = [(n, us) for n, us, _ in rows
              if n.startswith("search_speed/24gpu_3type/qwen3-32b/")]
    if not search:
        failures.append("no search_speed qwen3-32b rows found")
    for name, us in search:
        budget = SEARCH_BUDGET_K8_S if name.endswith("/k8") \
            else SEARCH_BUDGET_S
        if us > budget * 1e6:
            failures.append(
                f"{name}: {us/1e6:.1f}s > {budget:.0f}s budget")
    speed = [d for n, _, d in rows if n == "engine_throughput/admit_speedup"]
    if not speed:
        failures.append("no engine_throughput/admit_speedup row found")
    else:
        vals = derived_floats(speed[0])
        if vals.get("speedup", 0.0) < MIN_ADMIT_SPEEDUP:
            failures.append(
                f"admission speedup {vals.get('speedup')}x < "
                f"{MIN_ADMIT_SPEEDUP}x floor")
    for n, _, d in rows:
        if n == "engine_throughput/bucketed/admit":
            vals = derived_floats(d)
            buckets = [derived_floats(dd).get("buckets", 0.0)
                       for nn, _, dd in rows
                       if nn == "engine_throughput/admit_speedup"]
            if buckets and vals.get("retraces", 1e9) > buckets[0]:
                failures.append(
                    f"bucketed prefill retraces {vals.get('retraces')} "
                    f"exceed bucket count {buckets[0]}")
    failures += check_kv_paging(rows)
    failures += check_prefix_share(rows)
    failures += check_routing(rows)
    failures += check_kernels(rows)
    failures += check_cluster_sim(rows)
    errors = [n for n, _, _ in rows if n.endswith("/ERROR")]
    failures += [f"suite error row: {n}" for n in errors]
    return failures


def check_prefix_share(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    cap = [d for n, _, d in rows if n == "prefix_share/capacity"]
    ident = [d for n, _, d in rows if n == "prefix_share/identity"]
    if not cap or not ident:
        return ["no prefix_share/capacity or /identity rows found"]
    ratio = derived_floats(cap[0]).get("ratio", 0.0)
    ivals = derived_floats(ident[0])
    # the ISSUE-6 operating point: either lever alone justifies the cache
    if ratio < MIN_PREFIX_CAPACITY_RATIO \
            and ivals.get("reduction", 0.0) < MIN_PREFIX_WARM_REDUCTION:
        failures.append(
            f"prefix sharing capacity {ratio}x < "
            f"{MIN_PREFIX_CAPACITY_RATIO}x AND warm prefill reduction "
            f"{ivals.get('reduction')} < {MIN_PREFIX_WARM_REDUCTION}")
    if ivals.get("identical", 0.0) != 1.0:
        failures.append(
            "greedy outputs diverged with prefix sharing on vs off: "
            f"{ident[0]}")
    warm = [d for n, _, d in rows if n == "prefix_share/warmup"]
    if not warm:
        failures.append("no prefix_share/warmup row found")
    else:
        wvals = derived_floats(warm[0])
        if wvals.get("warmups", 0.0) < 1.0:
            failures.append(
                f"no pipeline prefix warm-up through the store: {warm[0]}")
    return failures


def check_routing(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    cost = [d for n, _, d in rows if n == "routing/cost"]
    if not cost:
        return ["no routing/cost row found"]
    vals = derived_floats(cost[0])
    if vals.get("ratio", 1e9) > MAX_ROUTING_COST_RATIO:
        failures.append(
            f"bucket-aware $/token ratio {vals.get('ratio')} > "
            f"{MAX_ROUTING_COST_RATIO}x uniform ceiling")
    if vals.get("identical", 0.0) != 1.0:
        failures.append(
            "greedy outputs diverged across dispatch policies: "
            f"{cost[0]}")
    mix = [d for n, _, d in rows if n == "routing/placement_mix"]
    if not mix:
        failures.append("no routing/placement_mix row found")
    else:
        mvals = derived_floats(mix[0])
        if mvals.get("short_picks_low", 0.0) != 1.0 \
                or mvals.get("mixed_picks_high", 0.0) != 1.0:
            failures.append(
                "histogram $/token objective picked the wrong instance "
                f"mix: {mix[0]}")
    return failures


def check_baseline(rows: List[Tuple[str, float, str]],
                   baseline: List[Tuple[str, float, str]]) -> List[str]:
    """Fail on >BASELINE_TOLERANCE regression of any TRACKED metric vs
    the committed baseline. A metric absent from the baseline is skipped
    with a note (commit a re-baseline to start tracking it); a metric
    present in the baseline but missing from the new rows is a failure
    (the suite silently stopped reporting it)."""
    failures = []

    def value_of(rs, name, key):
        for n, _, d in rs:
            if n == name:
                return derived_floats(d).get(key)
        return None

    for name, key, direction in TRACKED:
        base = value_of(baseline, name, key)
        new = value_of(rows, name, key)
        if base is None:
            print(f"[check_smoke] note: {name} {key}= not in baseline — "
                  "skipped (re-baseline to track it)")
            continue
        if new is None:
            failures.append(
                f"tracked row {name} ({key}=) missing from new rows")
            continue
        if direction == "higher":
            floor = base * (1.0 - BASELINE_TOLERANCE)
            if new < floor:
                failures.append(
                    f"{name}: {key}={new:.3f} regressed "
                    f">{BASELINE_TOLERANCE:.0%} below baseline {base:.3f}")
        else:
            ceil = base * (1.0 + BASELINE_TOLERANCE)
            if new > ceil:
                failures.append(
                    f"{name}: {key}={new:.3f} regressed "
                    f">{BASELINE_TOLERANCE:.0%} above baseline {base:.3f}")
    return failures


def check_kv_paging(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    cap = [d for n, _, d in rows if n == "kv_paging/capacity"]
    if not cap:
        failures.append("no kv_paging/capacity row found")
    else:
        ratio = derived_floats(cap[0]).get("ratio", 0.0)
        if ratio < MIN_PAGED_CAPACITY_RATIO:
            failures.append(
                f"paged admission capacity {ratio}x < "
                f"{MIN_PAGED_CAPACITY_RATIO}x contig floor")
    lazy = [d for n, _, d in rows if n == "kv_paging/lazy_capacity"]
    if not lazy:
        failures.append("no kv_paging/lazy_capacity row found")
    else:
        vals = derived_floats(lazy[0])
        if vals.get("ratio", 0.0) < MIN_LAZY_CAPACITY_RATIO:
            failures.append(
                f"lazy admission capacity {vals.get('ratio')}x < "
                f"{MIN_LAZY_CAPACITY_RATIO}x upfront floor")
        if vals.get("identical", 0.0) != 1.0:
            failures.append(
                "lazy greedy outputs diverged from upfront across "
                f"grow/preempt paths: {lazy[0]}")
    tok = {}
    for layout in ("contig", "paged"):
        d = [d for n, _, d in rows if n == f"kv_paging/{layout}/decode"]
        if not d:
            failures.append(f"no kv_paging/{layout}/decode row found")
        else:
            tok[layout] = derived_floats(d[0]).get("tok_s", 0.0)
    if len(tok) == 2 and tok["paged"] < \
            (1.0 - MAX_PAGED_DECODE_REGRESSION) * tok["contig"]:
        failures.append(
            f"paged decode {tok['paged']:.0f} tok/s regresses > "
            f"{MAX_PAGED_DECODE_REGRESSION:.0%} vs contig "
            f"{tok['contig']:.0f} tok/s")
    dec = [d for n, _, d in rows if n == "kv_paging/recovery_decide"]
    if not dec:
        failures.append("no kv_paging/recovery_decide row found")
    elif derived_floats(dec[0]).get("kv_restore", 0.0) != 1.0:
        failures.append(
            "recovery decide() did not pick kv_restore with resident "
            f"blocks: {dec[0]}")
    return failures


def check_kernels(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    floors = {"chunk": MIN_CHUNK_TOK_S, "decode": MIN_DECODE_TOK_S}
    for op, floor in floors.items():
        jnp_row = [d for n, _, d in rows if n == f"kernels/{op}/jnp"]
        pal_row = [d for n, _, d in rows if n == f"kernels/{op}/pallas"]
        if not jnp_row or not pal_row:
            failures.append(f"no kernels/{op}/jnp or /pallas rows found")
            continue
        tok_s = derived_floats(jnp_row[0]).get("tok_s", 0.0)
        if tok_s < floor:
            failures.append(
                f"oracle {op} {tok_s:.0f} tok/s < {floor:.0f} floor")
        pvals = derived_floats(pal_row[0])
        # interpret mode (CPU CI) is a correctness proxy, orders of
        # magnitude off compiled speed — the speedup floor only binds on
        # a real accelerator.
        if pvals.get("interp", 1.0) == 0.0 \
                and pvals.get("speedup", 0.0) < MIN_PALLAS_SPEEDUP:
            failures.append(
                f"pallas {op} kernel speedup {pvals.get('speedup')}x < "
                f"{MIN_PALLAS_SPEEDUP}x oracle floor on accelerator")
    disp = [d for n, _, d in rows if n == "kernels/chunk_dispatch"]
    if not disp:
        return failures + ["no kernels/chunk_dispatch row found"]
    dvals = derived_floats(disp[0])
    if dvals.get("reduction", 0.0) < MIN_CHUNK_DISPATCH_REDUCTION:
        failures.append(
            f"direct chunk dispatch reduction {dvals.get('reduction')}x < "
            f"{MIN_CHUNK_DISPATCH_REDUCTION}x floor vs transient+scatter")
    if dvals.get("identical", 0.0) != 1.0:
        failures.append(
            "greedy outputs diverged between direct-paged and contig "
            f"chunked prefill: {disp[0]}")
    if dvals.get("scatter", 0.0) <= 0.0:
        failures.append(
            f"contig baseline recorded no terminal scatters: {disp[0]}")
    return failures


def check_cluster_sim(rows: List[Tuple[str, float, str]]) -> List[str]:
    failures = []
    par = [(us, d) for n, us, d in rows if n == "cluster_sim/parity"]
    if not par:
        failures.append("no cluster_sim/parity row found")
    else:
        vals = derived_floats(par[0][1])
        worst = max(vals.get("rps_delta", 1e9),
                    vals.get("downtime_delta", 1e9),
                    vals.get("cost_delta", 1e9))
        if vals.get("ok", 0.0) != 1.0 or worst > PARITY_TOL:
            failures.append(
                f"DES diverged from closed form on an idle topology "
                f"(max delta {worst:.2e} > {PARITY_TOL:.0e}): {par[0][1]}")
    cont = [d for n, _, d in rows if n == "cluster_sim/contention"]
    if not cont:
        failures.append("no cluster_sim/contention row found")
    else:
        ratio = derived_floats(cont[0]).get("ratio", 0.0)
        if ratio < MIN_CONTENTION_RATIO:
            failures.append(
                f"store-link contention downtime ratio {ratio}x < "
                f"{MIN_CONTENTION_RATIO}x floor")
    churn = [(us, d) for n, us, d in rows if n == "cluster_sim/churn"]
    if not churn:
        failures.append("no cluster_sim/churn row found")
    else:
        us, d = churn[0]
        cvals = derived_floats(d)
        if us > CHURN_BUDGET_S * 1e6:
            failures.append(
                f"1000-node churn took {us/1e6:.1f}s > "
                f"{CHURN_BUDGET_S:.0f}s budget")
        if cvals.get("correlated", 0.0) < MIN_CORRELATED_DROPS:
            failures.append(
                f"churn trace had {cvals.get('correlated')} correlated "
                f"reclaims < {MIN_CORRELATED_DROPS} floor")
    front = [d for n, _, d in rows if n == "cluster_sim/frontier"]
    if not front:
        failures.append("no cluster_sim/frontier row found")
    else:
        fvals = derived_floats(front[0])
        if fvals.get("saving", 0.0) <= MIN_FRONTIER_SAVING:
            failures.append(
                f"frontier all-OD/all-spot saving {fvals.get('saving')}x "
                f"<= {MIN_FRONTIER_SAVING}x (spot discount lost)")
        if fvals.get("front", 0.0) <= 0.0:
            failures.append(f"empty pareto front: {front[0]}")
    return failures


def main() -> None:
    args = sys.argv[1:]
    baseline_path = None
    if "--baseline" in args:
        i = args.index("--baseline")
        baseline_path = args[i + 1]
        del args[i:i + 2]
    path = args[0]
    with open(path) as f:
        rows = parse_rows(f.read())
    failures = check(rows)
    if baseline_path:
        with open(baseline_path) as f:
            baseline = parse_rows(f.read())
        failures += check_baseline(rows, baseline)
    if failures:
        for f_ in failures:
            print(f"[check_smoke] FAIL: {f_}")
        sys.exit(1)
    print(f"[check_smoke] OK: {len(rows)} rows within budget")


if __name__ == "__main__":
    main()
