"""§Roofline deliverable — the full (arch x shape) baseline table from the
dry-run artifacts, single-pod mesh, plus bottleneck classification."""

from __future__ import annotations

import os
from typing import Dict, List

from benchmarks.common import Rows, save_json
from repro.launch.roofline import analyze_file, whats_next


def run(rows: Rows) -> Dict:
    path = os.path.join("results", "dryrun_all.json")
    if not os.path.exists(path):
        rows.add("roofline/missing_dryrun", 0.0,
                 "run: python -m repro.launch.dryrun --all --mesh both "
                 "--out results/dryrun_all.json")
        return {}
    cells = analyze_file(path)
    single = [c for c in cells if c.mesh == "16x16"]
    table: List[Dict] = []
    for c in sorted(single, key=lambda c: (c.arch, c.shape)):
        table.append({
            "arch": c.arch, "shape": c.shape, "step": c.step,
            "compute_s": c.compute_s, "memory_s": c.memory_s,
            "collective_s": c.collective_s, "bottleneck": c.bottleneck,
            "model_flops": c.model_flops,
            "useful_ratio": c.useful_ratio,
            "roofline_fraction": c.roofline_fraction,
            "peak_mem_gb": c.peak_mem_bytes / 1e9,
            "next": whats_next(c),
        })
    # aggregate row per step kind
    for step in ("train_step", "prefill_step", "serve_step"):
        sub = [t for t in table if t["step"] == step]
        if not sub:
            continue
        avg_frac = sum(t["roofline_fraction"] for t in sub) / len(sub)
        worst = min(sub, key=lambda t: t["roofline_fraction"])
        rows.add(f"roofline/{step}/avg_fraction", avg_frac * 1e6,
                 f"n={len(sub)} worst={worst['arch']}x{worst['shape']}"
                 f"@{worst['roofline_fraction']:.3f}")
    bnecks = {}
    for t in table:
        bnecks[t["bottleneck"]] = bnecks.get(t["bottleneck"], 0) + 1
    rows.add("roofline/bottleneck_mix", float(len(table)),
             " ".join(f"{k}={v}" for k, v in sorted(bnecks.items())))
    out = {"table": table, "multi_pod": [
        {"arch": c.arch, "shape": c.shape, "bottleneck": c.bottleneck,
         "compute_s": c.compute_s, "memory_s": c.memory_s,
         "collective_s": c.collective_s,
         "roofline_fraction": c.roofline_fraction}
        for c in cells if c.mesh == "2x16x16"]}
    save_json("roofline_table.json", out)
    return out
