"""Hot-path kernel benchmarks — flash paged chunk-prefill + paged decode.

Two questions, answered on whatever backend runs this:

  * raw op throughput: tokens/s of the paged chunk-attention and paged
    decode dispatches, jnp oracle vs the Pallas kernel.  On CPU the
    kernel runs in *interpret* mode (``interp=1`` in the derived row) —
    a correctness proxy, orders of magnitude off its compiled speed — so
    check_smoke.py enforces the ``speedup >= 1x`` floor only when
    ``interp=0`` (a real accelerator).  The oracle tok/s floors ARE
    CPU-enforceable and protect against dispatch-path bloat.
  * dispatch-count reduction of direct-to-pool chunked prefill: the
    contig baseline pays one terminal scatter per finished group on top
    of its chunk dispatches; the paged engine writes chunks straight
    into pool blocks (``chunk_direct``) and scatters never.  The counts
    are deterministic, so the reduction ratio is baseline-tracked.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, full_mode, save_json
from repro.configs import get_config
from repro.kernels import ops as kops
from repro.models import attention as mattn
from repro.models import build_model
from repro.serving import Engine, ServeRequest

# engine-scale shapes (a reduced-config chunk group); REPRO_FULL widens
B, C, NH, NKV, D = (4, 128, 8, 2, 64) if full_mode() else (2, 64, 4, 2, 64)
BLOCK, MB = (16, 32) if full_mode() else (16, 16)   # virtual len = BLOCK*MB
ITERS = 5


def _time(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)                       # compile + warm
    t0 = time.perf_counter()
    for _ in range(ITERS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / ITERS


def _paged_operands(rng):
    n_blocks = 1 + B * MB
    pk = jnp.asarray(rng.randn(n_blocks, BLOCK, NKV, D), jnp.float32)
    pv = jnp.asarray(rng.randn(n_blocks, BLOCK, NKV, D), jnp.float32)
    tbl = jnp.asarray(rng.permutation(B * MB).reshape(B, MB) + 1, jnp.int32)
    return pk, pv, tbl


def _chunk_ab(rng) -> Dict:
    """Paged chunk-attention: jnp gather oracle vs the scalar-prefetch
    Pallas kernel, tokens/s per dispatch."""
    pk, pv, tbl = _paged_operands(rng)
    q = jnp.asarray(rng.randn(B, C, NH, D), jnp.float32)
    base = jnp.asarray(BLOCK * MB - C, jnp.int32)
    q_pos = (jnp.broadcast_to(base, (B,))[:, None]
             + jnp.arange(C)[None]).astype(jnp.int32)
    oracle = jax.jit(
        lambda q, k, v, t, p: mattn.chunk_attention_paged(q, k, v, t, p))
    t_jnp = _time(oracle, q, pk, pv, tbl, q_pos)
    t_pal = _time(kops.chunk_attention_paged, q, pk, pv, tbl, base)
    return {"jnp_tok_s": B * C / t_jnp, "pallas_tok_s": B * C / t_pal,
            "jnp_s": t_jnp, "pallas_s": t_pal, "speedup": t_jnp / t_pal}


def _decode_ab(rng) -> Dict:
    """Paged decode: jnp gather oracle vs the block-table kernel."""
    pk, pv, tbl = _paged_operands(rng)
    q = jnp.asarray(rng.randn(B, 1, NH, D), jnp.float32)
    pos = jnp.asarray([BLOCK * MB - 1] * B, jnp.int32)
    oracle = jax.jit(
        lambda q, k, v, t, p: mattn.decode_attention_paged(q, k, v, t, p))
    t_jnp = _time(oracle, q, pk, pv, tbl, pos)
    t_pal = _time(kops.decode_attention_paged, q, pk, pv, tbl, pos)
    return {"jnp_tok_s": B / t_jnp, "pallas_tok_s": B / t_pal,
            "jnp_s": t_jnp, "pallas_s": t_pal, "speedup": t_jnp / t_pal}


def _dispatch_counts() -> Dict:
    """Deterministic A/B: chunk dispatches + terminal scatters on the same
    staggered workload, contig (transient cache + scatter) vs paged
    (direct in-place writes)."""
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    outs, stats = {}, {}
    for layout in ("contig", "paged"):
        eng = Engine(cfg, params, max_batch=4, max_len=64,
                     prefill_chunk=8, kv_layout=layout)
        rs = [ServeRequest(prompt=list(range(1 + i, 30 + 3 * i)),
                           max_new_tokens=4) for i in range(4)]
        eng.admit_many(rs[:2])
        eng.step()
        eng.admit_many(rs[2:])
        eng.drain()
        outs[layout] = [list(r.generated) for r in rs]
        stats[layout] = eng.stats
    contig_ops = (stats["contig"].prefill_chunks
                  + stats["contig"].chunk_scatters)
    paged_ops = stats["paged"].prefill_chunks + stats["paged"].chunk_scatters
    return {"direct": stats["paged"].chunk_direct,
            "scatter": stats["contig"].chunk_scatters,
            "contig_ops": contig_ops, "paged_ops": paged_ops,
            "reduction": contig_ops / max(paged_ops, 1),
            "identical": outs["paged"] == outs["contig"]}


def run(rows: Rows) -> Dict:
    rng = np.random.RandomState(7)
    interp = 1 if jax.default_backend() == "cpu" else 0
    out: Dict = {}
    ch = _chunk_ab(rng)
    out["chunk"] = ch
    rows.add("kernels/chunk/jnp", ch["jnp_s"] * 1e6,
             f"tok_s={ch['jnp_tok_s']:.0f}")
    rows.add("kernels/chunk/pallas", ch["pallas_s"] * 1e6,
             f"tok_s={ch['pallas_tok_s']:.0f} "
             f"speedup={ch['speedup']:.2f}x interp={interp}")
    de = _decode_ab(rng)
    out["decode"] = de
    rows.add("kernels/decode/jnp", de["jnp_s"] * 1e6,
             f"tok_s={de['jnp_tok_s']:.0f}")
    rows.add("kernels/decode/pallas", de["pallas_s"] * 1e6,
             f"tok_s={de['pallas_tok_s']:.0f} "
             f"speedup={de['speedup']:.2f}x interp={interp}")
    disp = _dispatch_counts()
    out["dispatch"] = disp
    rows.add("kernels/chunk_dispatch", 0.0,
             f"direct={disp['direct']} scatter={disp['scatter']} "
             f"contig_ops={disp['contig_ops']} "
             f"paged_ops={disp['paged_ops']} "
             f"reduction={disp['reduction']:.2f}x "
             f"identical={1 if disp['identical'] else 0}")
    save_json("kernels", out)
    return out
