"""Paper Fig 8 / §7.1.1 — serving performance estimation accuracy.

The paper validates its roofline estimator against TensorRT-LLM measurements
on A10G/L4/L40S. This container has one CPU, so the validation target is the
REAL JAX engine on CPU: we calibrate the CPU once (GEMM/GEMV/AllReduce —
exactly the paper's §7.1.5 protocol), then compare estimator predictions
against measured prefill/decode wall times across (model x batch x seq)
configurations and report MAPE.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Rows, save_json
from repro.configs import get_config
from repro.core.estimator import Placement, Stage, stage_latencies
from repro.hw.calibration import calibrate
from repro.hw.profiles import DeviceProfile, InstanceProfile
from repro.models import build_model


def _cpu_instance(cal) -> InstanceProfile:
    dev = DeviceProfile("cpu", 16, cal.eff_flops, cal.eff_mem_bw,
                        cal.net_alpha_s, cal.eff_net_bps, kind="cpu")
    return InstanceProfile("cpu-node", dev, 1, 1e-4, 1e9, 1.0, 0.3)


def _measure(model, params, batch: int, s_in: int, s_out: int
             ) -> Dict[str, float]:
    toks = jnp.zeros((batch, s_in), jnp.int32)
    prefill = jax.jit(lambda p, t: model.prefill(p, {"tokens": t},
                                                 max_len=s_in + s_out + 1))
    logits, cache = jax.block_until_ready(prefill(params, toks))
    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, toks))
    t_prefill = time.perf_counter() - t0
    step = jax.jit(model.decode_step)
    nxt = jnp.zeros((batch, 1), jnp.int32)
    _, cache = jax.block_until_ready(step(params, cache, nxt))
    t0 = time.perf_counter()
    iters = max(2, s_out)
    for _ in range(iters):
        _, cache = step(params, cache, nxt)
    jax.block_until_ready(cache["pos"])
    t_decode = (time.perf_counter() - t0) / iters * s_out
    return {"prefill_s": t_prefill, "decode_s": t_decode}


def _dispatch_overhead_s() -> float:
    """Per-jit-call dispatch overhead."""
    f = jax.jit(lambda x: x)
    x = jnp.zeros((8,), jnp.float32)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(50):
        out = f(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 50


def _per_op_overhead_s() -> float:
    """Per-HLO-op execution overhead INSIDE a program (the paper's §8
    'kernel launch overhead', which its Eq. 1 does not model; dominant for
    sub-saturation models). Calibrated from the slope of a jitted
    elementwise chain."""
    def chain(n):
        def f(x, w):
            for _ in range(n):
                x = jnp.tanh(x @ w)      # tiny dots: unfusable, ~no compute
            return x
        g = jax.jit(f, static_argnums=())
        x = jnp.zeros((8, 8), jnp.float32)
        w = jnp.eye(8, dtype=jnp.float32) * 0.5
        jax.block_until_ready(g(x, w))
        t0 = time.perf_counter()
        for _ in range(20):
            out = g(x, w)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 20
    t_long, t_short = chain(256), chain(32)
    return max((t_long - t_short) / (2 * 224), 1e-8)   # 2 ops per iter


# HLO ops per transformer layer (projections, rope, attention, norms, mlp,
# residuals) — derived from the compiled reduced-model module op counts.
OPS_PER_LAYER = {"dense": 34, "moe": 48, "ssm": 42, "hybrid": 44,
                 "vlm": 36, "audio": 40}


def run(rows: Rows) -> Dict:
    # calibrate at op sizes representative of the reduced models (the paper
    # calibrates per GPU type at its serving sizes and takes the median)
    cal = rows.timed(
        "estimator_accuracy/calibrate_cpu",
        lambda: calibrate(gemm_sizes=(128, 256, 512),
                          gemv_sizes=(256, 512, 1024)),
        lambda c: f"eff_flops={c.eff_flops:.3e}")
    alpha = _dispatch_overhead_s()
    alpha_op = _per_op_overhead_s()
    rows.add("estimator_accuracy/per_op_overhead_s", alpha_op * 1e6, "")
    inst = _cpu_instance(cal)
    records: List[Dict] = []
    errs = []
    for arch in ["internlm2-1.8b", "qwen2-0.5b", "mamba2-1.3b"]:
        cfg = get_config(arch).reduced()
        spec = cfg.to_modelspec()
        model = build_model(cfg, remat=False, attn_chunk=0, ssd_chunk=16)
        params = model.init(jax.random.PRNGKey(0))
        placement = Placement(spec, (Stage(inst, 1, spec.n_layers,
                                           first=True, last=True),))
        for batch in (1, 2, 4):
            for s_in, s_out in ((64, 16), (128, 16)):
                meas = _measure(model, params, batch, s_in, s_out)
                pre, dec = stage_latencies(spec, placement, batch, s_in,
                                           s_out)
                # Eq.1 + per-op overhead extension: ops ~= layers x
                # family constant (+logits/embed), once per prefill and per
                # decode iteration
                n_ops = (cfg.n_layers
                         * OPS_PER_LAYER.get(cfg.family, 34) + 8)
                est = {"prefill_s": sum(pre) + alpha + alpha_op * n_ops,
                       "decode_s": (sum(dec) + (alpha + alpha_op * n_ops)
                                    * s_out)}
                for phase in ("prefill_s", "decode_s"):
                    ape = abs(est[phase] - meas[phase]) / meas[phase]
                    errs.append(ape)
                records.append({"arch": arch, "batch": batch, "s_in": s_in,
                                "s_out": s_out, **{f"meas_{k}": v for k, v
                                                   in meas.items()},
                                **{f"est_{k}": v for k, v in est.items()}})
    mape = float(np.mean(errs)) * 100
    med_ape = float(np.median(errs)) * 100
    rows.add("estimator_accuracy/raw_mape_pct", mape,
             f"median_ape={med_ape:.1f}pct n={len(errs)} (no device fit)")
    # The paper fits per-device effective scalars once and reuses them
    # across every configuration (§7.1.5). Equivalent here: fit one
    # (prefill, decode) efficiency pair on a single held-in calibration
    # config (internlm2, batch=2, s=64) and validate on the other 34 cells.
    calib = next(r for r in records
                 if r["arch"] == "internlm2-1.8b" and r["batch"] == 2
                 and r["s_in"] == 64)
    scale = {ph: calib[f"meas_{ph}"] / calib[f"est_{ph}"]
             for ph in ("prefill_s", "decode_s")}
    errs_fit = []
    for r in records:
        if r is calib:
            continue
        for ph in ("prefill_s", "decode_s"):
            est = r[f"est_{ph}"] * scale[ph]
            errs_fit.append(abs(est - r[f"meas_{ph}"]) / r[f"meas_{ph}"])
    fit_mape = float(np.mean(errs_fit)) * 100
    fit_med = float(np.median(errs_fit)) * 100
    rows.add("estimator_accuracy/mape_pct", fit_mape,
             f"median_ape={fit_med:.1f}pct n={len(errs_fit)} after one-time "
             f"device fit (paper protocol; paper: 6.63pct on GPUs)")
    out = {"raw_mape_pct": mape, "mape_pct": fit_mape,
           "median_ape_pct": fit_med, "device_fit_scale": scale,
           "dispatch_overhead_s": alpha,
           "calibration": {"eff_flops": cal.eff_flops,
                           "eff_mem_bw": cal.eff_mem_bw,
                           "wall_s": cal.wall_time_s},
           "records": records}
    save_json("estimator_accuracy.json", out)
    return out
