"""Length-aware, cost-aware routing — $/token of bucket-aware dispatch vs
uniform on a heterogeneous two-pipeline cluster (virtual clock, real
engine compute).

The cluster pairs a SMALL pipeline (low-HBM analytical placement, tight
paged-KV pool, max_batch 2) with a BIG one (high-HBM placement, large
pool, max_batch 8). The workload mixes short chats with long-context
requests. Uniform dispatch splits the longs 50/50 — each long books
nearly the small pipeline's whole block pool, so its longs serialize and
stretch the makespan while the big pipeline idles. Bucket-aware cost
dispatch reads the per-(input-len, output-len) throughput tables
(``core.buckets``): the small placement's long-input row is infeasible
(Eq. 6 batch bound = 0), so every long shunts to the big pipeline and the
small one serves the short traffic it is cheapest at.

Both pipelines are rented for the full makespan, so

    $/token = sum_p price_spot_hr(p) * makespan / 3600 / tokens_out

and the bucket-aware/uniform $/token ratio equals the round-count ratio.
check_smoke.py enforces ratio <= 0.85 with byte-identical greedy outputs
across policies, and that the histogram $/token placement objective picks
the cheap low-HBM instance for short-only traffic but the high-HBM
instance once long-context traffic appears.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import numpy as np

from benchmarks.common import Rows, save_json
from repro.configs import get_config
from repro.core import (HistogramCostObjective, LengthBuckets, Placement,
                        PlacementOptimizer, Stage, workload_histogram)
from repro.core.modelspec import uniform_decoder
from repro.hw.profiles import DeviceProfile, InstanceProfile
from repro.models import build_model
from repro.serving import GlobalServer, ServeRequest

# Small-edge bucket grid matched to the reduced engines' sequence scale
# (the default grid tops out at 2048-token inputs — real prefills that
# long have no place in a smoke benchmark).
BUCKETS = LengthBuckets(in_edges=(16, 32, 64), out_edges=(4, 8, 16))

# Analytical spec the placements/bucket tables are scored on: tiny, so
# the Eq. 6 window sits at the same scale as the grid (~6 MB of weights,
# 2 KB KV/token -> a 7.25 MB device serves short contexts and zeroes out
# on the 64-token input row; a 64 MB device serves everything).
ROUTE_SPEC = uniform_decoder("route-bench", 2, 256, 4, 4, 1024, 2048)


def _inst(name: str, mem_gb: float, price_od: float,
          price_spot: float) -> InstanceProfile:
    dev = DeviceProfile(f"{name}-dev", mem_gb, 100e12, 800e9, 5e-6, 32e9)
    return InstanceProfile(name, dev, 1, 5e-5, 25e9 / 8, price_od,
                           price_spot, name)


# the big box costs 10x the small one — more than its ~8.7x short-bucket
# throughput edge, so shorts are cheapest on the small box while longs
# are only POSSIBLE on the big one
LOW_HBM = _inst("low-hbm", 0.00725, 1.0, 0.30)
HIGH_HBM = _inst("high-hbm", 0.064, 10.0, 3.00)


def _single(inst: InstanceProfile) -> Placement:
    return Placement(ROUTE_SPEC, (Stage(inst, 1, ROUTE_SPEC.n_layers,
                                        first=True, last=True),))


N_SHORT, N_LONG = 16, 8
SHORT = (12, 4)              # (prompt len, max_new) -> bucket (0, 0)
LONG = (60, 12)              # -> bucket (2, 2), infeasible on LOW_HBM


def _prompts(vocab: int) -> List[Tuple[List[int], int]]:
    """Deterministic [L, S, S] x8 arrival pattern: uniform round-robin
    alternates pipelines, so the longs split 4/4."""
    rng = np.random.RandomState(11)
    out: List[Tuple[List[int], int]] = []
    for _ in range(N_LONG):
        for s_in, s_out in (LONG, SHORT, SHORT):
            toks = (rng.randint(0, vocab - 1, size=s_in) + 1).tolist()
            out.append((toks, s_out))
    return out


def _run_policy(cfg, params, workload, dispatch: str) -> Dict:
    srv = GlobalServer(cfg, None, max_batch=8, max_len=80,
                       dispatch=dispatch, buckets=BUCKETS,
                       est_workload=(32, 8),
                       engine_kw={"kv_layout": "paged", "block_size": 4})
    # heterogeneous pools mirror the analytical HBM gap: one long request
    # (72-token ctx -> 18 blocks) nearly drains the small pipeline's pool
    srv.add_pipeline(params, ["small-0"], placement=_single(LOW_HBM),
                     engine_kw={"max_batch": 2, "n_blocks": 20})
    srv.add_pipeline(params, ["big-0"], placement=_single(HIGH_HBM),
                     engine_kw={"n_blocks": 256})
    reqs = [ServeRequest(prompt=list(p), max_new_tokens=m)
            for p, m in workload]
    placed = [srv.submit(r) for r in reqs]
    long_on_big = sum(1 for r, p in zip(reqs, placed)
                      if r.max_new_tokens == LONG[1] and p.pid == 1)
    rounds = 0
    while srv.pending() and rounds < 4000:
        srv.step()
        srv.tick()
        rounds += 1
    assert all(r.done for r in reqs), dispatch
    tokens = sum(len(r.generated) for r in reqs)
    price_hr = sum(p.placement.price_hr(spot=True) for p in srv.pipelines)
    cost = price_hr * srv.clock / 3600.0
    return {"rounds": rounds, "makespan_s": srv.clock, "tokens": tokens,
            "cost_usd": cost, "usd_per_mtok": cost / tokens * 1e6,
            "long_on_big": long_on_big,
            "outputs": [list(r.generated) for r in reqs]}


def _placement_mix(workload) -> Dict:
    """The $/token objective over the traffic histogram answers 'which
    instance serves this mix cheapest': short-only traffic picks the
    cheap low-HBM box; the mixed histogram forces high-HBM (the low box
    cannot serve the long bucket at all)."""
    insts = {i.name: i for i in (LOW_HBM, HIGH_HBM)}
    inv = {i.name: 1 for i in (LOW_HBM, HIGH_HBM)}
    pairs = [(len(p), m) for p, m in workload]
    picks = {}
    for label, pp in (("short", [q for q in pairs if q[1] == SHORT[1]]),
                      ("mixed", pairs)):
        hist = workload_histogram(pp, BUCKETS)
        obj = HistogramCostObjective(hist, BUCKETS)
        res = PlacementOptimizer(ROUTE_SPEC, inv, insts, 32, 8,
                                 objective=obj, beam_k=2,
                                 max_stages=1).search()
        picks[label] = {
            "placement": res.placement.describe() if res.placement else "",
            "usd_per_mtok": (obj.cost_per_token(res.placement) * 1e6
                             if res.placement else float("inf"))}
    return picks


def run(rows: Rows) -> Dict:
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    workload = _prompts(cfg.vocab)
    out: Dict = {}
    res = {pol: _run_policy(cfg, params, workload, pol)
           for pol in ("uniform", "cost", "throughput")}
    identical = (res["uniform"]["outputs"] == res["cost"]["outputs"]
                 == res["throughput"]["outputs"])
    for pol in res:
        res[pol].pop("outputs")
    out["policies"] = res
    out["identical"] = identical

    u = res["uniform"]
    rows.add("routing/uniform", 0.0,
             f"rounds={u['rounds']} makespan_s={u['makespan_s']:.3g} "
             f"usd_per_mtok={u['usd_per_mtok']:.3g} tokens={u['tokens']} "
             f"long_on_big={u['long_on_big']}")
    for pol in ("cost", "throughput"):
        r = res[pol]
        ratio = r["usd_per_mtok"] / u["usd_per_mtok"]
        res[pol]["ratio_vs_uniform"] = ratio
        rows.add(f"routing/{pol}", 0.0,
                 f"ratio={ratio:.3f} identical={1 if identical else 0} "
                 f"rounds={r['rounds']} "
                 f"usd_per_mtok={r['usd_per_mtok']:.3g} "
                 f"long_on_big={r['long_on_big']}")

    mix = _placement_mix(workload)
    out["placement_mix"] = mix
    short_low = 1 if "low-hbm" in mix["short"]["placement"] else 0
    mixed_high = 1 if "high-hbm" in mix["mixed"]["placement"] else 0
    rows.add("routing/placement_mix", 0.0,
             f"short_picks_low={short_low} mixed_picks_high={mixed_high} "
             f"short_usd_per_mtok={mix['short']['usd_per_mtok']:.3g} "
             f"mixed_usd_per_mtok={mix['mixed']['usd_per_mtok']:.3g}")

    save_json("routing", out)
    return out
