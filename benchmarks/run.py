"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Heavy sweeps run in reduced
form by default; REPRO_FULL=1 enables paper-scale parameters.

  Fig 5  -> bench_migration_tradeoff      Fig 13/14 -> bench_fault_tolerance
  Fig 8  -> bench_estimator_accuracy      Fig 15    -> cost_efficiency
  Fig 9/10 -> bench_placement             Fig 16    -> bench_init_overlap
  Fig 11 -> bench_beam_width              Table 4   -> bench_calibration
  §Roofline -> roofline_report            §4.2 search -> bench_search_speed
  §5 exec plane -> bench_engine_throughput
  DES cluster sim -> bench_cluster_sim
  paged KV layout -> bench_kv_paging
  length/cost routing -> bench_routing
  hot-path kernels -> bench_kernels
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import Rows


def main() -> None:
    rows = Rows()
    only = sys.argv[1] if len(sys.argv) > 1 else None
    suites = [
        ("calibration", "benchmarks.bench_calibration"),
        ("estimator_accuracy", "benchmarks.bench_estimator_accuracy"),
        ("migration_tradeoff", "benchmarks.bench_migration_tradeoff"),
        ("beam_width", "benchmarks.bench_beam_width"),
        ("search_speed", "benchmarks.bench_search_speed"),
        ("engine_throughput", "benchmarks.bench_engine_throughput"),
        ("kernels", "benchmarks.bench_kernels"),
        ("kv_paging", "benchmarks.bench_kv_paging"),
        ("prefix_share", "benchmarks.bench_prefix_share"),
        ("routing", "benchmarks.bench_routing"),
        ("placement", "benchmarks.bench_placement"),
        ("fault_tolerance", "benchmarks.bench_fault_tolerance"),
        ("cluster_sim", "benchmarks.bench_cluster_sim"),
        ("init_overlap", "benchmarks.bench_init_overlap"),
        ("roofline", "benchmarks.roofline_report"),
    ]
    ft_out = None
    for name, module in suites:
        if only and only != name:
            continue
        try:
            mod = __import__(module, fromlist=["run"])
            out = mod.run(rows)
            if name == "fault_tolerance":
                ft_out = out
        except Exception as e:
            traceback.print_exc()
            rows.add(f"{name}/ERROR", 0.0, repr(e))
    if ft_out and (not only or only == "fault_tolerance"):
        try:
            from benchmarks.bench_fault_tolerance import cost_efficiency
            cost_efficiency(ft_out, rows)
        except Exception as e:
            traceback.print_exc()
            rows.add("cost_efficiency/ERROR", 0.0, repr(e))
    print("name,us_per_call,derived")
    rows.emit()


if __name__ == "__main__":
    main()
