"""Discrete-event cluster simulator suite: closed-form parity in the
uncontended limit, store-link contention under simultaneous warm-ups,
1000-node multi-region churn wall-clock, and the cost-vs-SLO frontier.

Rows (enforced by check_smoke):
  cluster_sim/parity      — max |DES - closed form| over scenario metrics
                            (rps / total downtime / $), must stay <= 1e-6
  cluster_sim/contention  — downtime ratio, two simultaneous warm-ups on
                            one store link vs the uncontended closed form
                            (deterministic; tracked, floor 1.1x)
  cluster_sim/churn       — 1000 pipelines, 2 regions, correlated spot
                            reclaims from a crunchy multi-region trace;
                            wall-clock budgeted, >= 50 correlated drops
  cluster_sim/frontier    — spot-mix x grace x policy sweep; tracked
                            saving = all-OD $ / all-spot $ (> 1)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

from benchmarks.common import (Rows, effective_instances, full_mode,
                               paper_inventory, save_json)
from repro.cluster import (ClusterSim, FTConfig, RegionSpec, Topology,
                           azure_conversation_like,
                           correlated_interruption_count,
                           generate_multi_region_trace, pareto_front,
                           sweep_frontier)
from repro.cluster.spot_trace import PoolModel
from repro.configs import get_config
from repro.core import Placement, Stage, populate_cluster
from repro.core.modelspec import uniform_decoder
from repro.hw.profiles import DeviceProfile, InstanceProfile

TINY = uniform_decoder("sim-4l", 4, 2048, 16, 16, 8192, 32000)


def _inst(name: str) -> InstanceProfile:
    dev = DeviceProfile(f"{name}-dev", 24.0, 100e12, 800e9, 5e-6, 32e9)
    return InstanceProfile(name, dev, 1, 5e-5, 25e9 / 8, 2.0, 0.7, name)


def _single(inst) -> Placement:
    return Placement(
        TINY, (Stage(inst, 1, TINY.n_layers, first=True, last=True),))


PL_A = _single(_inst("sim-a"))
PL_B = _single(_inst("sim-b"))


def parity(rows: Rows) -> Dict:
    """DES vs closed form on the paper cluster: the uncontended-limit
    equivalence the refactor promises (full matrix in tests)."""
    spec = get_config("qwen3-32b").to_modelspec()
    plan = populate_cluster(spec, paper_inventory(), effective_instances(),
                            763, 232, beam_k=1)
    pool = plan.pipelines[0].stages[0].instance.name
    events = [(120.0, pool, -1), (300.0, pool, -1)]
    scenarios = {
        "shunt": FTConfig(),
        "no_ci": FTConfig(concurrent_init=False),
        "hybrid_kv": FTConfig(recovery_policy="hybrid",
                              kv_store_migration=True),
    }
    reqs = azure_conversation_like(duration_s=600.0, rate_rps=3.0, seed=3)
    deltas = {"rps": 0.0, "downtime": 0.0, "cost": 0.0}
    t0 = time.perf_counter()
    for ft in scenarios.values():
        base = ClusterSim(spec, plan.pipelines, ft).run(
            reqs, 600.0, events=events)
        des = ClusterSim(spec, plan.pipelines, ft, network=Topology()).run(
            reqs, 600.0, events=events)
        deltas["rps"] = max(deltas["rps"], abs(des.rps - base.rps))
        deltas["downtime"] = max(deltas["downtime"],
                                 abs(des.total_downtime_s
                                     - base.total_downtime_s))
        deltas["cost"] = max(deltas["cost"],
                             abs(des.cost_usd - base.cost_usd))
    us = (time.perf_counter() - t0) * 1e6
    ok = int(all(d <= 1e-6 for d in deltas.values()))
    rows.add("cluster_sim/parity", us,
             f"ok={ok} scenarios={len(scenarios)} "
             f"rps_delta={deltas['rps']:.2e} "
             f"downtime_delta={deltas['downtime']:.2e} "
             f"cost_delta={deltas['cost']:.2e}")
    return {"ok": ok, **deltas}


def contention(rows: Rows) -> Dict:
    """Two replacements warming from one store link at the same instant:
    serialized transfers extend real downtime past the closed form."""
    ft = FTConfig(grace_period_s=30.0, node_provision_s=40.0,
                  store_load_s=60.0, engine_init_s=30.0)
    reqs = azure_conversation_like(duration_s=400.0, rate_rps=0.5, seed=0)
    events = [(100.0, "sim-a", -2)]
    base = ClusterSim(TINY, [PL_A, PL_A], ft).run(
        reqs, 400.0, events=events)
    des = ClusterSim(TINY, [PL_A, PL_A], ft, network=Topology()).run(
        reqs, 400.0, events=events)
    ratio = des.total_downtime_s / max(base.total_downtime_s, 1e-9)
    wait = des.link_stats["store:local"]["wait_s"]
    rows.add("cluster_sim/contention", 0.0,
             f"ratio={ratio:.3f}x base_s={base.total_downtime_s:.1f} "
             f"des_s={des.total_downtime_s:.1f} wait_s={wait:.1f}")
    return {"ratio": ratio, "base_s": base.total_downtime_s,
            "des_s": des.total_downtime_s}


def churn(rows: Rows) -> Dict:
    """Scale row: 1000 pipelines across 2 regions driven by a crunchy
    multi-region availability trace (correlated reclaims by
    construction). The wall-clock budget protects the event core's
    O(E log E) behavior at the paper's 100-1000-node operating range."""
    n = 1000 if not full_mode() else 2000
    half = n // 4  # per pool per region
    pools = {
        "sim-a": PoolModel("sim-a", half, 0.004, 0.05, 0.4),
        "sim-b": PoolModel("sim-b", half, 0.004, 0.05, 0.4),
    }
    regions = [RegionSpec("us", pools, crunch_per_min=0.04),
               RegionSpec("eu", pools, crunch_per_min=0.04)]
    minutes = 30
    trace = generate_multi_region_trace(regions, minutes=minutes, seed=11)
    events = trace.events()
    n_corr = correlated_interruption_count(events)
    pls, regs = [], []
    for i in range(n):
        pls.append(PL_A if i % 2 == 0 else PL_B)
        regs.append("us" if i < n // 2 else "eu")
    sim = ClusterSim(TINY, pls, FTConfig(), network=Topology(),
                     regions=regs)
    reqs = azure_conversation_like(duration_s=minutes * 60.0,
                                   rate_rps=30.0, seed=6)
    t0 = time.perf_counter()
    res = sim.run(reqs, minutes * 60.0, events=events)
    wall = time.perf_counter() - t0
    rows.add("cluster_sim/churn", wall * 1e6,
             f"nodes={n} events={len(events)} correlated={n_corr} "
             f"interruptions={res.interruptions} "
             f"completed={len(res.completed)} transfers={res.transfers} "
             f"wall_s={wall:.1f}")
    return {"nodes": n, "correlated": n_corr, "wall_s": wall,
            "interruptions": res.interruptions}


def frontier(rows: Rows) -> Dict:
    """Cost-vs-SLO sweep: spot mix x grace x recovery policy -> $/Mtok
    vs p99 TTFT/TPOT. The tracked saving is the all-OD / all-spot cost
    ratio at the base cell (spot discount must survive interruptions)."""
    reqs = azure_conversation_like(duration_s=300.0, rate_rps=1.0, seed=4)
    events = [(60.0, "sim-a", -1), (150.0, "sim-a", -1)]
    t0 = time.perf_counter()
    pts = sweep_frontier(
        TINY, [PL_A, PL_A], reqs, 300.0, events=events,
        spot_fracs=(0.0, 0.5, 1.0), graces=(30.0, 120.0),
        policies=("recompute", "hybrid"), network_factory=Topology)
    us = (time.perf_counter() - t0) * 1e6
    front = pareto_front(pts)
    by = {(p.spot_frac, p.grace_s, p.policy): p for p in pts}
    od = by[(0.0, 30.0, "recompute")]
    spot = by[(1.0, 30.0, "recompute")]
    saving = od.cost_usd / max(spot.cost_usd, 1e-9)
    best = min(front, key=lambda p: p.cost_per_mtok)
    rows.add("cluster_sim/frontier", us,
             f"points={len(pts)} front={len(front)} saving={saving:.3f}x "
             f"best_usd_per_mtok={best.cost_per_mtok:.4f} "
             f"best_p99_ttft_s={best.p99_ttft_s:.3f}")
    save_json("cluster_sim_frontier.json",
              [dataclasses.asdict(p) for p in pts])
    return {"points": len(pts), "front": len(front), "saving": saving}


def run(rows: Rows) -> Dict:
    out = {"parity": parity(rows), "contention": contention(rows),
           "churn": churn(rows), "frontier": frontier(rows)}
    save_json("cluster_sim.json", out)
    return out
