"""Shared benchmark helpers: effective instance profiles, cluster setups,
CSV row collection, JSON result persistence."""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Tuple

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def effective_instances():
    from repro.hw import AWS_INSTANCES, TPU_INSTANCES, effective
    out = {}
    for n, i in {**AWS_INSTANCES, **TPU_INSTANCES}.items():
        out[n] = dataclasses.replace(i, device=effective(i.device))
    return out


def paper_inventory():
    from repro.hw import paper_cluster
    return paper_cluster()


def save_json(name: str, payload: Any) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def load_json(name: str) -> Any:
    with open(os.path.join(RESULTS_DIR, name)) as f:
        return json.load(f)


class Rows:
    """Collects ``name,us_per_call,derived`` CSV rows."""

    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, us_per_call, derived))

    def timed(self, name: str, fn: Callable[[], Any], derived_fn=None):
        t0 = time.perf_counter()
        out = fn()
        us = (time.perf_counter() - t0) * 1e6
        derived = derived_fn(out) if derived_fn else ""
        self.add(name, us, derived)
        return out

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.1f},{derived}")


def full_mode() -> bool:
    return os.environ.get("REPRO_FULL", "0") == "1"


def calibrate_sim_efficiency(spec, pipelines, paper_rps: float,
                             n_probe: int = 1500) -> float:
    """One-time simulator calibration: probe the plan's raw (roofline)
    offline throughput, then derate so ShuntServe's absolute number matches
    the paper's measured §7.1.2 value. Ratios across systems/variants come
    from the model, not the calibration."""
    from repro.cluster import ClusterSim, FTConfig, azure_conversation_like
    reqs = azure_conversation_like(duration_s=600, rate_rps=n_probe / 600,
                                   seed=9)[:n_probe]
    sim = ClusterSim(spec, pipelines, FTConfig(use_spot=True))
    raw = sim.run(reqs, duration_s=36000, offline=True).makespan_rps
    return min(1.0, paper_rps / max(raw, 1e-9))
