"""Execution-plane engine throughput — admission (prefill) tok/s, retrace
count, and decode tok/s on a mixed-length workload.

Compares the v2 bucketed/batched admission path against the seed engine's
per-request batch-1 path (``admission="legacy"``): the seed traces one
prefill per distinct prompt length and scatters the cache key-by-key in
Python, so admission — which bounds how fast surviving pipelines absorb
migration re-prefill load (SpotServe/ThunderServe observation) — is orders
of magnitude below the roofline. The bucketed engine must show >= 5x
admission throughput with a trace count bounded by the bucket count
(enforced by benchmarks/check_smoke.py in CI).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import Rows, save_json
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, ServeRequest

N_REQUESTS = 32
MAX_NEW = 2
MAX_LEN = 64


def _workload(cfg, seed: int):
    rng = np.random.RandomState(seed)
    lens = rng.randint(4, 49, size=N_REQUESTS)
    return [ServeRequest(
        prompt=rng.randint(0, cfg.vocab, size=int(n)).tolist(),
        max_new_tokens=MAX_NEW) for n in lens]


def _admit_and_decode(cfg, params, admission: str) -> Dict:
    eng = Engine(cfg, params, max_batch=N_REQUESTS, max_len=MAX_LEN,
                 admission=admission)
    reqs = _workload(cfg, seed=7)
    prompt_toks = sum(len(r.prompt) for r in reqs)
    t0 = time.perf_counter()
    admitted = eng.admit_many(reqs)
    t_admit = time.perf_counter() - t0
    assert len(admitted) == N_REQUESTS
    t0 = time.perf_counter()
    eng.drain()
    t_decode = time.perf_counter() - t0
    dec_toks = eng.stats.tokens_out - N_REQUESTS   # first tokens <- prefill
    return {
        "admission": admission,
        "admit_s": t_admit,
        "admit_tok_s": prompt_toks / t_admit,
        "decode_tok_s": dec_toks / max(t_decode, 1e-9),
        "prefill_retraces": eng.stats.prefill_retraces,
        "prefill_batches": eng.stats.prefill_batches,
        "bucket_count": len(eng.bucket_lens()),
    }


def _chunked_admission(cfg, params) -> Dict:
    """Migration-recompute shape: long contexts admitted chunk-by-chunk
    while short live requests keep decoding (head-of-line bound)."""
    eng = Engine(cfg, params, max_batch=8, max_len=MAX_LEN,
                 prefill_chunk=16)
    rng = np.random.RandomState(11)
    live = [ServeRequest(prompt=rng.randint(0, cfg.vocab, 6).tolist(),
                         max_new_tokens=12) for _ in range(4)]
    eng.admit_many(live)
    migrated = []
    for _ in range(4):
        r = ServeRequest(prompt=rng.randint(0, cfg.vocab, 40).tolist(),
                         max_new_tokens=16)
        r.generated = rng.randint(0, cfg.vocab, 8).tolist()
        migrated.append(r)
    t0 = time.perf_counter()
    eng.admit_many(migrated)
    eng.drain()
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "prefill_chunks": eng.stats.prefill_chunks,
            "decode_steps": eng.stats.decode_steps}


def run(rows: Rows) -> Dict:
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    out: Dict = {}
    for admission in ("legacy", "bucketed"):
        r = _admit_and_decode(cfg, params, admission)
        out[admission] = r
        rows.add(f"engine_throughput/{admission}/admit",
                 r["admit_s"] * 1e6,
                 f"tok_s={r['admit_tok_s']:.0f} "
                 f"retraces={r['prefill_retraces']} "
                 f"batches={r['prefill_batches']}")
        rows.add(f"engine_throughput/{admission}/decode", 0.0,
                 f"tok_s={r['decode_tok_s']:.0f}")
    speedup = (out["legacy"]["admit_s"] / out["bucketed"]["admit_s"]
               if out["bucketed"]["admit_s"] > 0 else 0.0)
    out["admit_speedup"] = speedup
    rows.add("engine_throughput/admit_speedup", 0.0,
             f"speedup={speedup:.1f}x "
             f"buckets={out['bucketed']['bucket_count']}")
    out["chunked"] = _chunked_admission(cfg, params)
    rows.add("engine_throughput/chunked/admit",
             out["chunked"]["wall_s"] * 1e6,
             f"chunks={out['chunked']['prefill_chunks']} "
             f"decode_steps={out['chunked']['decode_steps']}")
    save_json("engine_throughput.json", out)
    return out
